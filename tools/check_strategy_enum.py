#!/usr/bin/env python
"""CI guard: no strategy-name matching outside the registry module.

The strategy registry (``src/repro/core/strategies.py``) is the ONLY place
allowed to know strategy names; every engine must dispatch on registered
capabilities (``strat.compresses``, ``strat.needs_residuals``,
``strat.weighting``, ``strat.overlap_weighted``, ``strat.wire``, ...).
This is what makes registry-only strategies (e.g. ``qtopk``) drop into all
five engines without editing them — and this script is what keeps it true.

Scans ``src/`` and ``benchmarks/`` (tests may pin names: they assert parity
of specific strategies) for comparisons against a ``strategy`` variable::

    strategy == ...     strategy != ...
    strategy in (...)   strategy in [...]   strategy not in ...

Exits nonzero listing offending ``path:line`` sites.

    python tools/check_strategy_enum.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

SCAN_DIRS = ("src", "benchmarks")
EXEMPT = {pathlib.PurePosixPath("src/repro/core/strategies.py")}

# `<something>strategy` identifier (spec.strategy, cfg.strategy, strategy)
# followed by an equality or membership test against literals
_PAT = re.compile(
    r"\bstrategy\s*(?:==|!=|(?:not\s+)?in\s*[(\[{])")


def check(root: pathlib.Path) -> list[str]:
    bad: list[str] = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root)
            if pathlib.PurePosixPath(rel.as_posix()) in EXEMPT:
                continue
            for lineno, line in enumerate(
                    path.read_text().splitlines(), start=1):
                code = line.split("#", 1)[0]
                if _PAT.search(code):
                    bad.append(f"{rel.as_posix()}:{lineno}: {line.strip()}")
    return bad


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    bad = check(root)
    if bad:
        print("strategy-name matching outside the registry module "
              "(dispatch on registry capabilities instead — see "
              "src/repro/core/strategies.py):")
        for b in bad:
            print(f"  {b}")
        return 1
    print(f"OK: no strategy enum comparisons in {'/'.join(SCAN_DIRS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
