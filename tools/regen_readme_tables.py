#!/usr/bin/env python
"""Regenerate the README's strategies/engines tables from the strategy
registry (``repro.core.strategies``), so docs cannot drift from code: a new
``strategies.register(...)`` call shows up in the README by re-running

    PYTHONPATH=src python tools/regen_readme_tables.py          # rewrite
    PYTHONPATH=src python tools/regen_readme_tables.py --check  # CI drift gate

Tables are replaced between marker comments::

    <!-- registry:strategies:begin --> ... <!-- registry:strategies:end -->
    <!-- registry:engines:begin -->    ... <!-- registry:engines:end -->
    <!-- registry:kernels:begin -->    ... <!-- registry:kernels:end -->

The strategies table is rendered straight from the registered capability
records; the engines table lists the registry's consumers (every engine
dispatches on capabilities only — enforced by tools/check_strategy_enum.py);
the kernels table prices every megakernel-capable strategy's HBM passes
(repro.roofline.kernel_bytes analytic DMA model) and wire stream against
the idx32+f32 reference pair.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core import strategies  # noqa: E402


#: the registry consumers — kept here, next to the registry-driven
#: table, so one command regenerates both
ENGINE_ROWS = [
    ("`legacy`", "per-client eager loop", "simulation MLP",
     "`fed/server.py`"),
    ("`fused`", "1 jit dispatch per round", "simulation MLP, flat `[n]`",
     "`fed/round_step.py`"),
    ("`scan`", "1 `lax.scan` per simulation",
     "flat `[n]` + `[C, n]` EF carry", "`engine.make_sim_scan`"),
    ("`pop_scan`", "1 `lax.scan` per simulation",
     "flat `[n]` + dense `[P + 1, n]` per-client EF carry (small-P "
     "reference)", "`engine.make_sim_scan(population=P)`"),
    ("`population`", "1 jit dispatch per round, state streamed per cohort",
     "flat `[n]` + out-of-core sparse client store, O(C·n + P·(n−k_min))",
     "`fed/population.py`"),
    ("`async` (`fl_train --engine async`)",
     "event-driven; wave-batched train dispatch (≤ log2(max(K, M)) + 1 "
     "compiles) + 1 jit dispatch per buffer flush",
     "flat `[n]` + version ring `[V, n]` + sparse out-of-core client "
     "store + K-slot buffer, staleness-discounted OPWA, crash-safe "
     "(DESIGN.md §11–§12)",
     "`fed/async_engine.py`"),
    ("mesh `round` (`fl_train --engine round`)", "1 jit dispatch per round",
     "real sharded arch, params pytree", "`fed/mesh_round.py`"),
    ("mesh `scan` (`fl_train` default)", "1 `lax.scan` per checkpoint chunk",
     "params pytree + per-leaf `[C, *leaf]` EF carry",
     "`engine.make_mesh_sim_scan`"),
    ("mesh population (`fl_train --population P --cohort C`)",
     "1 jit dispatch per round, state streamed per cohort",
     "real arch, params pytree + flat-wire client store",
     "`mesh_round.make_population_round_step`"),
]


def _table(header: list[str], rows: list[list[str]]) -> str:
    lines = ["| " + " | ".join(header) + " |",
             "|" + "---|" * len(header)]
    lines += ["| " + " | ".join(r) + " |" for r in rows]
    return "\n".join(lines)


def strategies_table() -> str:
    rows = []
    for name in strategies.names():
        s = strategies.get(name)
        rows.append([
            f"`{name}`",
            s.carry,
            s.selector,
            f"`{s.value_codec.__name__}`" if s.value_codec else "—",
            s.weighting + (" + OPWA" if s.overlap_weighted else ""),
            s.wire.kind,
            ("yes" + (f" ({s.kernel_codec} codec)" if s.kernel_codec else "")
             if s.megakernel else "no"),
            s.description,
        ])
    return _table(["name", "carry", "selector", "value codec", "weighting",
                   "wire format", "megakernel", "description"], rows)


#: representative merge shape for the kernels table (matches the largest
#: BENCH_kernels.json cell) and the survivor fraction the wire column is
#: priced at
KERNEL_TABLE_SHAPE = (32, 65536)
KERNEL_TABLE_CR = 0.1


def kernels_table() -> str:
    from repro.roofline import megakernel_hbm_bytes, wire_stream_bytes
    c, n = KERNEL_TABLE_SHAPE
    k = int(n * KERNEL_TABLE_CR)
    rows = []
    for name in strategies.names():
        s = strategies.get(name)
        if not s.megakernel:
            continue
        hbm = megakernel_hbm_bytes(c, n, name)
        wire = wire_stream_bytes(name, n, k)
        rows.append([
            f"`{name}`",
            f"{hbm['passes']:.1f}",
            "—" if s.kernel_codec is None
            else f"`{s.kernel_codec}` ([C, 1] scale column)",
            wire["kind"],
            ("1" if wire["pair_ratio"] == 1.0
             else f"**{wire['pair_bytes']:g}/8**"),
            f"{wire['total_ratio']:.3f}",
        ])
    return _table(
        [f"strategy (C={c}, n={n})", "kernel HBM passes", "kernel codec",
         "wire format", "survivor bytes vs idx32+f32",
         f"total wire ratio @ cr={KERNEL_TABLE_CR:g}"], rows)


def engines_table() -> str:
    return _table(["engine", "granularity", "model / carry", "module"],
                  [list(r) for r in ENGINE_ROWS])


def splice(text: str, tag: str, body: str) -> str:
    begin = f"<!-- registry:{tag}:begin -->"
    end = f"<!-- registry:{tag}:end -->"
    pat = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.DOTALL)
    if not pat.search(text):
        raise SystemExit(f"README is missing the {begin} / {end} markers")
    return pat.sub(f"{begin}\n{body}\n{end}", text)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the README tables are stale")
    args = ap.parse_args()
    readme = ROOT / "README.md"
    old = readme.read_text()
    new = splice(old, "strategies", strategies_table())
    new = splice(new, "engines", engines_table())
    new = splice(new, "kernels", kernels_table())
    if args.check:
        if new != old:
            print("README tables are stale — run "
                  "PYTHONPATH=src python tools/regen_readme_tables.py")
            return 1
        print("OK: README tables match the registry")
        return 0
    if new != old:
        readme.write_text(new)
        print("README.md tables regenerated from the registry")
    else:
        print("README.md tables already current")
    return 0


if __name__ == "__main__":
    sys.exit(main())
