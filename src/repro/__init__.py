"""repro: bandwidth-aware + overlap-weighted compressed distributed training
framework (BCRS + OPWA, ICPP 2024) on JAX for multi-pod TPU."""

__version__ = "1.0.0"
