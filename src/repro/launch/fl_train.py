"""Mesh-parallel federated training driver — the paper's system end-to-end:
clients on the batch mesh axes, BCRS per-round CR schedule, OPWA
aggregation, straggler deadline + elastic cohort, checkpoint/restart.

The round program (``fed.mesh_round.make_fl_round_step``) is a thin adapter
over the shared compression substrate (``fed.engine`` /
``core.compression.topk_compress_dynamic``) — the same traced-k selection
and OPWA merge the simulation engines run, applied per leaf so TP-sharded
tensors stay sharded.

    PYTHONPATH=src python -m repro.launch.fl_train --arch stablelm-1.6b \
        --reduced --rounds 10 --clients 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCH_IDS, get_config
from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.data import synthetic_lm_tokens
from repro.fed.mesh_round import make_fl_round_step
from repro.ft import FailureInjector, renormalize_coefficients
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--cr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--gamma", type=float, default=3.0)
    ap.add_argument("--overlap-d", type=int, default=1,
                    help="OPWA required degree of overlap D")
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_flat = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    v_bytes = 4.0 * n_flat

    round_fn = jax.jit(make_fl_round_step(
        model, lr_local=args.lr, eta=1.0, gamma=args.gamma,
        overlap_d=args.overlap_d))

    links = cost_model.sample_links(args.clients, rng)
    fracs = np.full(args.clients, 1.0 / args.clients)
    injector = FailureInjector(p_fail=args.fail_prob, seed=args.seed)
    times = cost_model.TimeAccumulator()

    start = 0
    if args.checkpoint_dir and ckpt.latest_step(args.checkpoint_dir) is not None:
        params, start, _ = ckpt.restore(args.checkpoint_dir, params)
        print(f"[fl] resumed from round {start}")

    for rnd in range(start, args.rounds):
        sched = bcrs_mod.make_schedule(links, fracs, v_bytes, args.cr,
                                       args.alpha)
        alive = injector.survivors(rnd, args.clients)
        coeffs = renormalize_coefficients(sched.coefficients, alive)
        toks = synthetic_lm_tokens(
            args.clients * args.local_steps * args.batch, args.seq + 1,
            cfg.vocab_size, rng).reshape(
                args.clients, args.local_steps, args.batch, args.seq + 1)
        batches = {"tokens": jnp.asarray(toks[..., :-1]),
                   "labels": jnp.asarray(toks[..., 1:])}
        params, loss = round_fn(params, batches,
                                jnp.asarray(coeffs, jnp.float32),
                                jnp.asarray(sched.crs, jnp.float32))
        times.add(cost_model.round_times(links, v_bytes, sched.crs))
        print(f"[fl] round {rnd} loss {float(loss):.4f} "
              f"alive {int(alive.sum())}/{args.clients} "
              f"round_time {times.per_round[-1].actual:.2f}s "
              f"CRs [{sched.crs.min():.3f},{sched.crs.max():.3f}]")
        if args.checkpoint_dir:
            ckpt.save(args.checkpoint_dir, rnd + 1, params,
                      extra={"arch": args.arch})
    print(f"[fl] done; accumulated comm time {times.actual:.1f}s "
          f"(straggler-free min would be {times.min:.1f}s)")


if __name__ == "__main__":
    main()
