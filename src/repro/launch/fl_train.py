"""Mesh-parallel federated training driver — the paper's system end-to-end:
clients on the batch mesh axes, BCRS per-round CR schedule, OPWA
aggregation, EF residual carrying, failure/straggler-aware cohorts,
checkpoint/restart — lowered into ONE compiled multi-round program.

The whole trajectory runs as ``engine.make_mesh_sim_scan``: the (possibly
TP/FSDP-sharded) params pytree and the per-leaf EF residual pytree thread
through a donated ``lax.scan`` carry, and everything the host decides per
round — cohort composition (``fed.simulation.plan_cohort``, the SAME
planner the simulation engines use), failure survivors, straggler arrivals,
and the BCRS schedule (``core.bcrs.make_schedule_batch``, one vectorized
call for all R rounds instead of one ``make_schedule`` per round) — is
precomputed as stacked ``[R, C]`` xs arrays. The scan is chunked at
checkpoint boundaries: one compile per distinct chunk length, one dispatch
per chunk, params + EF residuals persisted at every boundary
(``--engine round`` keeps the legacy one-jit-per-round dispatch loop as the
bit-parity reference).

All per-round randomness (synthetic client batches) is drawn from
round-indexed rng streams, so a resumed run consumes bit-identical data to
an uninterrupted one (tests/test_mesh_scan.py asserts restart bit-exactness
including the EF residual state).

    PYTHONPATH=src python -m repro.launch.fl_train --arch stablelm-1.6b \
        --reduced --rounds 10 --clients 8
"""
from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCH_IDS, get_config
from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.core import strategies as strat_mod
from repro.core.aggregation import AggregationConfig
from repro.data import synthetic_lm_tokens
from repro.fed import mesh_round as mesh_mod
from repro.fed import population as pop_mod
from repro.fed import engine as engine_mod
from repro.fed.mesh_round import make_mesh_round_step
from repro.fed.simulation import _link_columns, cohort_slots, plan_cohort
from repro.ft import FailureInjector, StragglerPolicy
from repro.models import Model

#: scan-chunk cap when no checkpoint cadence is configured — keeps the
#: device-resident per-chunk batch buffers O(MAX_CHUNK) instead of O(rounds)
MAX_CHUNK_ROUNDS = 32
#: default cadence when a checkpoint dir is set without --checkpoint-every:
#: bounded crash-loss window (the pre-scan driver saved every round; every
#: round would defeat the scan, 4 keeps the window small while amortizing)
DEFAULT_CHECKPOINT_EVERY = 4


@dataclass
class FLTrainConfig:
    """Everything the driver needs (the CLI below is a thin veneer)."""
    arch: str = "stablelm-1.6b"
    rounds: int = 10
    clients: int = 8
    participation: float = 1.0
    local_steps: int = 2
    batch: int = 4
    seq: int = 128
    strategy: str = "bcrs_opwa"
    cr: float = 0.05
    alpha: float = 1.0
    gamma: float = 3.0
    overlap_d: int = 1          # OPWA required degree of overlap D
    lr: float = 5e-2
    eta: float = 1.0
    reduced: bool = False
    fail_prob: float = 0.0
    over_selection: float = 0.0  # rho > 0 enables straggler over-selection
    checkpoint_dir: str = ""
    checkpoint_every: int = 0    # rounds per scan chunk; 0 = auto-capped
    engine: str = "scan"         # "scan" | "round" | "async"
    # ----------------- engine="async" (FedBuff buffered) knobs -----------
    async_buffer_k: int = 0      # 0 -> the cohort slot count
    async_concurrency: int = 0   # 0 -> min(2K, clients - K)
    async_alpha: float = 0.5     # staleness-discount exponent
    async_stall_s: float = float("inf")   # partial-flush deadline
    async_p_fail: float = 0.0    # per-attempt mid-transfer failure prob
    async_timeout_s: float = float("inf")
    async_version_ring: int = 8  # retained-version ring depth V (waves)
    async_batch_dispatch: bool = True   # False = per-dispatch baseline
    async_store_chunk: int = 4096       # sparse-store clients per chunk
    population: int = 0          # > 0: streaming-cohort mode over P clients
    cohort: int = 0              # cohort slots C (population mode; 0 ->
                                 # --clients is reused as the cohort size)
    use_kernel: object = "auto"
    seed: int = 0
    verbose: bool = True

    def __post_init__(self):
        strat_mod.get(self.strategy)   # config-time error, names listed
        if self.population > 0:
            if self.cohort <= 0:
                self.cohort = self.clients
            if self.cohort > self.population:
                raise ValueError(
                    f"cohort {self.cohort} exceeds population "
                    f"{self.population}")

    @property
    def n_registered(self) -> int:
        """Registered client count: the population in streaming mode, the
        (dense-state) client count otherwise."""
        return self.population if self.population > 0 else self.clients

    @property
    def c_slots(self) -> int:
        """Static cohort slot count every padded plan array is sized with."""
        if self.population > 0:
            return self.cohort
        return cohort_slots(self.clients, self.participation)


@dataclass
class RoundPlan:
    """Host-precomputed per-round xs arrays for the executed rounds.

    Everything is padded to ``c_max`` cohort slots (active marks the real
    prefix) so every round shares one static shape; ``rounds`` holds the
    executed round numbers (rounds whose whole cohort died are absent — the
    scan carry is untouched by construction, matching the per-round
    engines' ``continue``)."""
    rounds: List[int]
    selected: np.ndarray     # [T, C] i32, -1 at padded slots
    active: np.ndarray       # [T, C] bool
    weights: np.ndarray      # [T, C] f32 (0 at padded slots)
    crs: np.ndarray          # [T, C] f32 (comm/compression ratio per client)
    step_mask: np.ndarray    # [T, C, S] bool


def _build_plan(cfg: FLTrainConfig, rng, fracs_all, links, v_bytes,
                acfg: AggregationConfig,
                failure: Optional[FailureInjector],
                straggler: Optional[StragglerPolicy]) -> RoundPlan:
    """Plan every round before training starts: cohorts through the shared
    ``plan_cohort`` (one rng stream, consumed in round order — restart-
    invariant because the whole plan is rebuilt identically at startup),
    then the BCRS schedule for ALL rounds in one vectorized
    ``make_schedule_batch`` call (the per-round ``make_schedule`` this
    replaces was loop-invariant whenever the cohort was).

    In population mode the same plan shape comes out, but every per-round
    quantity is O(C): the cohort is an absolute budget (``cfg.cohort``
    passed through ``plan_cohort``'s ``cohort=`` override), failure
    survivors are drawn per sampled id (``sparse_failures``), and the link
    columns are O(C) ``LinkArrays`` slices — the whole-run plan is
    O(rounds x C) regardless of P."""
    pop_mode = cfg.population > 0
    c_max = cfg.c_slots
    plans = []
    for rnd in range(cfg.rounds):
        p = plan_cohort(rnd, rng, n_clients=cfg.n_registered,
                        participation=cfg.participation, fracs_all=fracs_all,
                        links=links, v_bytes=v_bytes, acfg=acfg,
                        failure=failure, straggler=straggler,
                        cohort=cfg.cohort if pop_mode else None,
                        sparse_failures=pop_mode)
        if p is not None:
            plans.append((rnd, *p))
    t = len(plans)
    selected = np.full((t, c_max), -1, np.int32)
    active = np.zeros((t, c_max), bool)
    fr_pad = np.zeros((t, c_max), np.float64)
    # harmless placeholders at padded slots (they never reach the schedule
    # max or the merge: active gates them everywhere)
    bw = np.ones((t, c_max), np.float64)
    lat = np.zeros((t, c_max), np.float64)
    for i, (rnd, sel, fr) in enumerate(plans):
        c_r = len(sel)
        selected[i, :c_r] = sel
        active[i, :c_r] = True
        fr_pad[i, :c_r] = fr
        bw[i, :c_r], lat[i, :c_r] = _link_columns(links, sel)

    strat = strat_mod.get(cfg.strategy)
    if strat.weighting == "bcrs":
        crs, coeffs, _ = bcrs_mod.make_schedule_batch(
            bw, lat, fr_pad, v_bytes, cfg.cr, cfg.alpha, active=active)
        weights = coeffs.astype(np.float32)
        crs = crs.astype(np.float32)
    else:
        weights = fr_pad.astype(np.float32)
        # plan.crs are SELECTION ratios (they feed k_for_ratio_traced in the
        # round body); wire pricing is applied at accounting time
        cr_sel = cfg.cr if strat.compresses else 1.0
        crs = np.where(active, np.float32(cr_sel), np.float32(0.0))

    step_mask = np.zeros((t, c_max, cfg.local_steps), bool)
    step_mask[active] = True
    return RoundPlan(rounds=[p[0] for p in plans], selected=selected,
                     active=active, weights=weights, crs=crs,
                     step_mask=step_mask)


def _round_batches(cfg: FLTrainConfig, vocab: int, rnd: int,
                   c_max: int) -> Dict[str, np.ndarray]:
    """Synthetic LM batches for one round, drawn from a round-indexed rng
    stream — independent of resume point and of which earlier rounds were
    skipped, so checkpoint/restart consumes bit-identical data."""
    r = np.random.default_rng((cfg.seed, 104_729, rnd))
    toks = synthetic_lm_tokens(
        c_max * cfg.local_steps * cfg.batch, cfg.seq + 1, vocab, r).reshape(
            c_max, cfg.local_steps, cfg.batch, cfg.seq + 1)
    return {"tokens": toks[..., :-1], "labels": toks[..., 1:]}


def _stack_batches(cfg: FLTrainConfig, vocab: int, rounds: List[int],
                   c_max: int) -> Dict[str, jax.Array]:
    per = [_round_batches(cfg, vocab, rnd, c_max) for rnd in rounds]
    return {k: jnp.asarray(np.stack([b[k] for b in per])) for k in per[0]}


def run(cfg: FLTrainConfig) -> dict:
    """Train per ``cfg``; returns {params, residuals, losses,
    executed_rounds, wall_per_round, chunk_rounds, times, resumed_from}."""
    model_cfg = get_config(cfg.arch)
    if cfg.reduced:
        model_cfg = model_cfg.reduced()
    model = Model(model_cfg)
    rng = np.random.default_rng(cfg.seed)
    params = model.init(jax.random.PRNGKey(cfg.seed))
    n_flat = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    v_bytes = 4.0 * n_flat
    c_max = cfg.c_slots
    strat = strat_mod.get(cfg.strategy)
    ef = strat.needs_residuals

    acfg = AggregationConfig(strategy=cfg.strategy, cr=cfg.cr,
                             alpha=cfg.alpha, gamma=cfg.gamma,
                             overlap_d=cfg.overlap_d,
                             use_kernel=cfg.use_kernel)
    if cfg.population > 0:
        # registry columns, not P Python objects: every per-round read
        # downstream is an O(C) slice
        links = cost_model.sample_link_arrays(cfg.population, rng)
    else:
        links = cost_model.sample_links(cfg.clients, rng)
    fracs_all = np.full(cfg.n_registered, 1.0 / cfg.n_registered)
    failure = (FailureInjector(p_fail=cfg.fail_prob, seed=cfg.seed)
               if cfg.fail_prob > 0 else None)
    straggler = (StragglerPolicy(over_selection=cfg.over_selection)
                 if cfg.over_selection > 0 else None)
    if cfg.engine == "async":
        return _run_async(cfg, model, model_cfg, params, links, strat,
                          acfg, fracs_all, n_flat, v_bytes)
    plan = _build_plan(cfg, rng, fracs_all, links, v_bytes, acfg,
                       failure, straggler)
    times = cost_model.TimeAccumulator()
    if cfg.population > 0:
        return _run_population(cfg, model, model_cfg, params, plan, links,
                               strat, n_flat, v_bytes, times)

    residuals = (engine_mod.init_mesh_residuals(params, c_max) if ef
                 else jnp.zeros((0,), jnp.float32))
    start, resumed_from = 0, None
    if cfg.checkpoint_dir and ckpt.latest_step(cfg.checkpoint_dir) is not None:
        like = {"params": params, "residuals": residuals}
        try:
            # strict=False: a residual-free checkpoint (e.g. strategy
            # switched to eftopk) resumes with fresh residuals
            tree, start, _extra = ckpt.restore(cfg.checkpoint_dir, like,
                                               strict=False)
            params, residuals = tree["params"], tree["residuals"]
        except ckpt.LayoutMismatch:
            # legacy layout: the pre-scan driver checkpointed the bare
            # params pytree at the top level (a shape-drifted leaf raises
            # plain ValueError above and must NOT reach this fallback)
            params, start, _extra = ckpt.restore(cfg.checkpoint_dir, params)
        resumed_from = start
        if cfg.verbose:
            print(f"[fl] resumed from round {start}")

    todo = [i for i, rnd in enumerate(plan.rounds) if rnd >= start]
    # checkpoint_every=0 still bounds the chunk: each chunk's batches are
    # materialized device-resident as xs, so an uncapped chunk would make a
    # long run O(rounds) in batch memory for zero benefit past the point
    # where dispatch overhead is amortized; with a checkpoint dir the
    # default cadence also bounds the crash-loss window
    if cfg.checkpoint_every > 0:
        chunk = cfg.checkpoint_every
    elif cfg.checkpoint_dir:
        chunk = DEFAULT_CHECKPOINT_EVERY
    else:
        chunk = min(max(len(todo), 1), MAX_CHUNK_ROUNDS)

    losses: List[float] = []
    wall_per_round: List[float] = []
    chunk_rounds: List[int] = []
    kw = dict(strategy=cfg.strategy, eta=cfg.eta, gamma=cfg.gamma,
              overlap_d=cfg.overlap_d, use_kernel=cfg.use_kernel)

    def save(next_round: int) -> None:
        if cfg.checkpoint_dir:
            tree = {"params": params, "residuals": residuals}
            ckpt.save(cfg.checkpoint_dir, next_round, tree,
                      extra={"arch": cfg.arch, "strategy": cfg.strategy})

    def account_and_log(i: int, loss: float, wall: float) -> None:
        rnd = plan.rounds[i]
        sel = plan.selected[i][plan.active[i]]
        links_sel = [links[c] for c in sel]
        # selection CRs priced through the declared wire format (identity
        # for idx32+f32 strategies, dense 1.0 for fedavg — the driver's
        # legacy accounting — and honestly packed for e.g. qtopk)
        crs_wire = strat.wire.cr_eff(plan.crs[i][plan.active[i]], n_flat)
        times.add(cost_model.round_times(links_sel, v_bytes, crs_wire))
        losses.append(loss)
        wall_per_round.append(wall)
        if cfg.verbose:
            crs_act = plan.crs[i][plan.active[i]]
            print(f"[fl] round {rnd} loss {loss:.4f} "
                  f"cohort {len(sel)}/{cfg.clients} "
                  f"round_time {times.per_round[-1].actual:.2f}s "
                  f"CRs [{crs_act.min():.3f},{crs_act.max():.3f}]")

    if cfg.engine == "scan":
        sim = engine_mod.make_mesh_sim_scan(model.loss_fn, params,
                                            lr=cfg.lr, **kw)
        compiled: Dict[int, object] = {}
        pos = 0
        while pos < len(todo):
            idx = todo[pos:pos + chunk]
            xs = {"batches": _stack_batches(cfg, model_cfg.vocab_size,
                                            [plan.rounds[i] for i in idx],
                                            c_max),
                  "step_mask": jnp.asarray(plan.step_mask[idx]),
                  "active": jnp.asarray(plan.active[idx]),
                  "weights": jnp.asarray(plan.weights[idx]),
                  "crs": jnp.asarray(plan.crs[idx])}
            # AOT-compile once per distinct chunk length; the jit cache
            # makes equal-length chunks ONE executable, so wall_per_round
            # reports steady-state dispatch cost
            if len(idx) not in compiled:
                compiled[len(idx)] = sim.compile(params, residuals, xs)
            t0 = time.perf_counter()
            out = compiled[len(idx)](params, residuals, xs)
            jax.block_until_ready(out["params"])
            wall = (time.perf_counter() - t0) / len(idx)
            params, residuals = out["params"], out["residuals"]
            for j, i in enumerate(idx):
                account_and_log(i, float(out["ys"]["loss"][j]), wall)
            chunk_rounds.append(len(idx))
            save(plan.rounds[idx[-1]] + 1)
            pos += len(idx)
    elif cfg.engine == "round":
        step = make_mesh_round_step(model.loss_fn, lr_local=cfg.lr, **kw)
        for pos, i in enumerate(todo):
            batches = {k: jnp.asarray(v) for k, v in _round_batches(
                cfg, model_cfg.vocab_size, plan.rounds[i], c_max).items()}
            t0 = time.perf_counter()
            params, residuals, loss = step(
                params, residuals if ef else None, batches,
                jnp.asarray(plan.step_mask[i]), jnp.asarray(plan.weights[i]),
                jnp.asarray(plan.crs[i]), jnp.asarray(plan.active[i]))
            jax.block_until_ready(params)
            wall = time.perf_counter() - t0
            if not ef:
                residuals = jnp.zeros((0,), jnp.float32)
            account_and_log(i, float(loss), wall)
            chunk_rounds.append(1)
            if (pos + 1) % chunk == 0 or pos == len(todo) - 1:
                save(plan.rounds[i] + 1)
    else:
        raise ValueError(f"unknown engine {cfg.engine!r}")

    if cfg.verbose:
        print(f"[fl] done; accumulated comm time {times.actual:.1f}s "
              f"(straggler-free min would be {times.min:.1f}s)")
    return {"params": params, "residuals": residuals, "losses": losses,
            "executed_rounds": [plan.rounds[i] for i in todo],
            "wall_per_round": wall_per_round, "chunk_rounds": chunk_rounds,
            "times": times, "resumed_from": resumed_from}


def _run_async(cfg: FLTrainConfig, model, model_cfg, params, links, strat,
               acfg: AggregationConfig, fracs_all, n_flat: int,
               v_bytes: float) -> dict:
    """FedBuff-style async buffered training on the real model: the
    simulation's ``fed.async_engine`` loop, in flat parameter space, with
    counter-keyed synthetic LM batches per dispatch (restart-invariant, like
    the sync driver's round-indexed streams). ``cfg.rounds`` counts buffer
    flushes; crash-safe state (params, per-client EF store, buffer,
    in-flight uploads) persists through ``cfg.checkpoint_dir`` and a rerun
    resumes bit-exactly.

    Dispatches batch into padded vmapped waves (one train-program jit call
    per wave shape bucket — docs/DESIGN.md §12) unless
    ``cfg.async_batch_dispatch`` is off. With ``cfg.population > 0`` the
    loop runs at streaming-population scale: O(C) cohort selection over P
    registered clients (``LinkArrays`` columns), per-client EF residuals in
    a sparse out-of-core ``population.ClientStateStore`` gathered only for
    the flushed buffer members, snapshotted chunk-wise through the
    checkpointer. Sharded (TP/FSDP) async is future work — this path trains
    single-device like the simulation engines."""
    from repro.core import aggregation as agg_mod
    from repro.core.compression import flatten_tree, k_for_ratio
    from repro.fed import async_engine as async_mod
    from repro.fed import population as pop_mod

    flat0, unravel = flatten_tree(params)
    times = cost_model.TimeAccumulator()
    n_reg = cfg.n_registered
    k_buf = cfg.async_buffer_k or cfg.c_slots
    m_conc = cfg.async_concurrency or max(1, min(2 * k_buf, n_reg - k_buf))
    fracs_norm = np.asarray(fracs_all, np.float64)
    fracs_norm = fracs_norm / fracs_norm.sum()
    if strat.weighting == "bcrs" and isinstance(links,
                                                cost_model.LinkArrays):
        # population mode: the vectorized whole-population schedule (no P
        # Python ClientLink objects — the _build_plan convention)
        crs_b, coeffs_b, _ = bcrs_mod.make_schedule_batch(
            links.bandwidth_bps[None], links.latency_s[None],
            fracs_norm[None], v_bytes, cfg.cr, cfg.alpha)
        crs_all, coeffs_all = crs_b[0], coeffs_b[0]
    else:
        crs_all, coeffs_all, _info = agg_mod.round_schedule(
            acfg, n_reg, fracs_norm, links, v_bytes)
    crs_arr = np.asarray(crs_all, np.float64)
    if strat.compresses and np.all(crs_arr == crs_arr.flat[0]):
        # uniform schedule (data weighting): one k, not P k_for_ratio calls
        ks_all = np.full((n_reg,),
                         k_for_ratio(n_flat, float(crs_arr.flat[0])),
                         np.int32)
    else:
        ks_all = agg_mod.ks_for_schedule(n_flat, crs_all, acfg)
    cr_eff_all = np.broadcast_to(np.asarray(
        strat.wire.cr_eff(crs_arr, n_flat), np.float64), (n_reg,))

    ef = strat.needs_residuals
    store = None
    if ef and cfg.population > 0:
        layout = strat.residual_layout
        width = (pop_mod.residual_width(n_flat, int(ks_all.min()))
                 if layout == "topk_complement" else 0)
        store = pop_mod.ClientStateStore(
            n_reg, n_flat, layout=layout, width=width,
            chunk_clients=min(cfg.async_store_chunk, n_reg))
        merge = async_mod.make_async_merge_step(
            acfg, eta=cfg.eta,
            residual_layout=("topk_complement"
                             if layout == "topk_complement" else "rows"),
            width=width)
    else:
        merge = async_mod.make_async_merge_step(acfg, eta=cfg.eta)

    wave_train = async_mod.make_wave_train_step(
        model.loss_fn, params, lr=cfg.lr,
        make_batches=lambda x: {"tokens": x["tokens"],
                                "labels": x["labels"]},
        strategy=cfg.strategy)
    smask_row = np.ones((cfg.local_steps,), bool)

    def batch_plan(client: int, uid: int) -> Dict[str, np.ndarray]:
        r = np.random.default_rng((cfg.seed, async_mod.BATCH_TAG, uid))
        toks = synthetic_lm_tokens(
            cfg.local_steps * cfg.batch, cfg.seq + 1, model_cfg.vocab_size,
            r).reshape(cfg.local_steps, cfg.batch, cfg.seq + 1)
        return {"tokens": toks[..., :-1], "labels": toks[..., 1:],
                "step_mask": smask_row}

    def on_flush(flush_idx: int, flat, rt: cost_model.RoundTime) -> None:
        times.add(rt)
        if cfg.verbose:
            print(f"[fl] flush {flush_idx} buffer {k_buf} "
                  f"interval {rt.actual:.2f}s slowest_upload {rt.max:.2f}s")

    def extra_state() -> dict:
        return {"times": [[float(t.actual), float(t.max), float(t.min)]
                          for t in times.per_round]}

    def load_extra(extra: dict) -> None:
        for a, mx, mn in extra.get("times", []):
            times.add(cost_model.RoundTime(a, mx, mn))

    ckpt_every = (cfg.checkpoint_every
                  or (DEFAULT_CHECKPOINT_EVERY if cfg.checkpoint_dir else 0))
    loop = async_mod.BufferedAsyncLoop(
        n_clients=n_reg, n_params=n_flat, buffer_k=k_buf,
        concurrency=m_conc, target_flushes=cfg.rounds, seed=cfg.seed,
        alpha=cfg.async_alpha, stall_s=cfg.async_stall_s,
        p_fail=cfg.async_p_fail,
        retry=cost_model.RetryPolicy(timeout_s=cfg.async_timeout_s),
        links=links, v_bytes=v_bytes, cr_eff_all=cr_eff_all, ks_all=ks_all,
        coeff_table=(coeffs_all if strat.weighting == "bcrs" else None),
        fracs_all=fracs_all, merge=merge, wave_train=wave_train,
        batch_plan=batch_plan, on_flush=on_flush,
        batch_dispatch=cfg.async_batch_dispatch,
        version_ring=cfg.async_version_ring, residual_store=store,
        checkpoint_dir=cfg.checkpoint_dir or None,
        checkpoint_every=ckpt_every, extra_state=extra_state,
        load_extra=load_extra)
    flat = loop.run(jnp.asarray(flat0))
    if cfg.verbose:
        print(f"[fl] done; accumulated virtual wall {times.actual:.1f}s "
              f"over {loop.flushes} flushes "
              f"({loop.train_calls} train dispatches / "
              f"{loop.train_rows} client updates)")
    return {"params": unravel(flat), "residuals": loop.store, "losses": [],
            "executed_rounds": list(range(loop.flushes)),
            "wall_per_round": [], "chunk_rounds": [], "times": times,
            "resumed_from": None, "async_loop": loop}


def _run_population(cfg: FLTrainConfig, model, model_cfg, params, plan,
                    links, strat, n_flat: int, v_bytes: float,
                    times) -> dict:
    """Streaming-cohort training over a population far larger than the
    cohort: per-client EF residuals live in a ``population.ClientStateStore``
    (sparse ``(idx32, f32)`` pairs for "topk_complement" strategies, chunked
    rows for "dense" ones) instead of a device-resident per-slot carry, and
    each round gathers just the sampled cohort's rows into the ONE compiled
    ``mesh_round.make_population_round_step`` program, scattering the
    updated rows back afterwards. Round state is O(C x n + touched-chunks),
    never O(P x n).

    Checkpoints persist ``{"params"}`` plus a per-step client-store snapshot
    (``clients_step_<N>/`` next to ``step_<N>.msgpack``, pruned in lockstep
    with the main retention), so a resumed run is bit-exact with an
    uninterrupted one including every client's residual."""
    ef = strat.needs_residuals
    layout = strat.residual_layout if ef else None
    c_max = cfg.c_slots
    if layout == "topk_complement":
        # every retained count the plan can emit bounds the residual nnz
        cr_min = (float(plan.crs[plan.active].min())
                  if plan.active.any() else cfg.cr)
        width = mesh_mod.mesh_residual_width(params, cr_min)
    else:
        width = 0

    store: Optional[pop_mod.ClientStateStore] = None
    start, resumed_from = 0, None
    if cfg.checkpoint_dir and ckpt.latest_step(cfg.checkpoint_dir) is not None:
        tree, start, extra = ckpt.restore(cfg.checkpoint_dir,
                                          {"params": params}, strict=False)
        params = tree["params"]
        man = (extra or {}).get("client_store")
        if ef and man is not None:
            if layout == "topk_complement" and man["width"] != width:
                raise ValueError(
                    f"client-store snapshot has sparse width {man['width']} "
                    f"but the rebuilt plan needs {width} — the plan (rounds/"
                    "cr/seed) changed across the restart")
            store = pop_mod.ClientStateStore.restore(
                cfg.checkpoint_dir, start, man,
                spill_dir=os.path.join(cfg.checkpoint_dir, "client_spill"))
        resumed_from = start
        if cfg.verbose:
            print(f"[fl] resumed from round {start} "
                  f"(population {cfg.population})")
    if ef and store is None:
        store = pop_mod.ClientStateStore(
            cfg.population, n_flat, layout=layout, width=width,
            chunk_clients=min(4096, cfg.population))

    step = mesh_mod.make_population_round_step(
        model.loss_fn, params, lr_local=cfg.lr, eta=cfg.eta,
        strategy=cfg.strategy, gamma=cfg.gamma, overlap_d=cfg.overlap_d,
        use_kernel=cfg.use_kernel, width=width)

    def save(next_round: int) -> None:
        if not cfg.checkpoint_dir:
            return
        extra = {"arch": cfg.arch, "strategy": cfg.strategy,
                 "population": cfg.population}
        if store is not None:
            extra["client_store"] = store.save(cfg.checkpoint_dir,
                                               next_round)
        ckpt.save(cfg.checkpoint_dir, next_round, {"params": params},
                  extra=extra)
        if store is not None:
            # retention just ran on the step files; drop the client
            # snapshots whose step it pruned
            pop_mod.prune_client_snapshots(
                cfg.checkpoint_dir, ckpt.list_steps(cfg.checkpoint_dir))

    todo = [i for i, rnd in enumerate(plan.rounds) if rnd >= start]
    if cfg.checkpoint_every > 0:
        chunk = cfg.checkpoint_every
    elif cfg.checkpoint_dir:
        chunk = DEFAULT_CHECKPOINT_EVERY
    else:
        chunk = max(len(todo), 1)
    losses: List[float] = []
    wall_per_round: List[float] = []
    zero_wire = jnp.zeros((0,), jnp.float32)   # carry="none" placeholder
    for pos, i in enumerate(todo):
        sel = plan.selected[i][plan.active[i]]
        c_r = len(sel)
        batches = {k: jnp.asarray(v) for k, v in _round_batches(
            cfg, model_cfg.vocab_size, plan.rounds[i], c_max).items()}
        if ef:
            gathered = store.gather(sel)
            bufs = []
            for a in gathered:      # zero-pad the cohort to the static slots
                buf = np.zeros((c_max,) + a.shape[1:], a.dtype)
                buf[:c_r] = a
                bufs.append(jnp.asarray(buf))
            wire = tuple(bufs) if layout == "topk_complement" else bufs[0]
        else:
            wire = zero_wire
        t0 = time.perf_counter()
        params, wire, loss, overflow = step(
            params, wire, batches, jnp.asarray(plan.step_mask[i]),
            jnp.asarray(plan.weights[i]), jnp.asarray(plan.crs[i]),
            jnp.asarray(plan.active[i]))
        loss = float(loss)          # blocks: wall includes the round
        wall = time.perf_counter() - t0
        if ef:
            if bool(overflow):
                raise RuntimeError(
                    f"round {plan.rounds[i]}: EF residual outgrew the "
                    f"sparse width {width}")
            arrays = wire if isinstance(wire, tuple) else (wire,)
            store.scatter(sel, tuple(np.asarray(a)[:c_r] for a in arrays))
        links_sel = [links[c] for c in sel]
        crs_wire = strat.wire.cr_eff(plan.crs[i][plan.active[i]], n_flat)
        times.add(cost_model.round_times(links_sel, v_bytes, crs_wire))
        losses.append(loss)
        wall_per_round.append(wall)
        if cfg.verbose:
            print(f"[fl] round {plan.rounds[i]} loss {loss:.4f} "
                  f"cohort {c_r}/{cfg.population} "
                  f"round_time {times.per_round[-1].actual:.2f}s")
        if (pos + 1) % chunk == 0 or pos == len(todo) - 1:
            save(plan.rounds[i] + 1)

    if cfg.verbose:
        print(f"[fl] done; accumulated comm time {times.actual:.1f}s "
              f"(straggler-free min would be {times.min:.1f}s)")
    return {"params": params, "residuals": store, "losses": losses,
            "executed_rounds": [plan.rounds[i] for i in todo],
            "wall_per_round": wall_per_round,
            "chunk_rounds": [1] * len(todo), "times": times,
            "resumed_from": resumed_from, "store": store}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--strategy", choices=strat_mod.names(),
                    default="bcrs_opwa")
    ap.add_argument("--cr", type=float, default=0.05)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--gamma", type=float, default=3.0)
    ap.add_argument("--overlap-d", type=int, default=1,
                    help="OPWA required degree of overlap D")
    ap.add_argument("--lr", type=float, default=5e-2)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fail-prob", type=float, default=0.0)
    ap.add_argument("--over-selection", type=float, default=0.0,
                    help="straggler over-selection rho (0 disables)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="rounds per scan chunk / checkpoint cadence "
                         "(0 = auto chunking, checkpoint at chunk ends)")
    ap.add_argument("--engine", choices=("scan", "round", "async"),
                    default="scan")
    ap.add_argument("--async-buffer-k", type=int, default=0,
                    help="async merge buffer size K (0 = cohort slots)")
    ap.add_argument("--async-concurrency", type=int, default=0,
                    help="async in-flight dispatches M (0 = min(2K, N-K))")
    ap.add_argument("--async-alpha", type=float, default=0.5,
                    help="staleness-discount exponent")
    ap.add_argument("--async-stall", type=float, default=float("inf"),
                    help="partial-flush stall deadline in seconds")
    ap.add_argument("--async-p-fail", type=float, default=0.0,
                    help="per-attempt mid-transfer upload failure prob")
    ap.add_argument("--async-timeout", type=float, default=float("inf"),
                    help="per-upload hard deadline in seconds")
    ap.add_argument("--async-version-ring", type=int, default=8,
                    help="retained-parameter-version ring depth V for "
                         "batched wave dispatch")
    ap.add_argument("--async-sequential-dispatch", action="store_true",
                    help="disable batched wave dispatch (per-upload jit "
                         "baseline)")
    ap.add_argument("--population", type=int, default=0,
                    help="registered client count P for streaming-cohort "
                         "mode (0 = dense-state mode over --clients)")
    ap.add_argument("--cohort", type=int, default=0,
                    help="cohort slots C in population mode "
                         "(0 = reuse --clients)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(FLTrainConfig(
        arch=args.arch, rounds=args.rounds, clients=args.clients,
        participation=args.participation, local_steps=args.local_steps,
        batch=args.batch, seq=args.seq, strategy=args.strategy, cr=args.cr,
        alpha=args.alpha, gamma=args.gamma, overlap_d=args.overlap_d,
        lr=args.lr, reduced=args.reduced, fail_prob=args.fail_prob,
        over_selection=args.over_selection,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, engine=args.engine,
        population=args.population, cohort=args.cohort,
        async_buffer_k=args.async_buffer_k,
        async_concurrency=args.async_concurrency,
        async_alpha=args.async_alpha, async_stall_s=args.async_stall,
        async_p_fail=args.async_p_fail, async_timeout_s=args.async_timeout,
        async_version_ring=args.async_version_ring,
        async_batch_dispatch=not args.async_sequential_dispatch,
        seed=args.seed))


if __name__ == "__main__":
    main()
