import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and record memory/cost/collective evidence.

  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Writes one JSON per cell under experiments/dryrun/<mesh>/<arch>__<shape>[__step].json
with memory_analysis, cost_analysis, collective summary, and roofline terms.
The XLA_FLAGS line above MUST run before any jax import (device count locks
on first init) — and only here: smoke tests/benches keep 1 device.
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, applicability, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell
from repro.roofline import analysis as roofline
from repro.roofline import hlo_cost


def _analyze_compiled(compiled, mesh):
    """Per-device (flops, bytes, wire-ici dict, wire-dcn dict) from the
    compiled HLO via the trip-count-aware cost model (roofline/hlo_cost)."""
    pod_size = 256 if "pod" in mesh.axis_names else None
    cost = hlo_cost.analyze_hlo(compiled.as_text(), mesh.size, pod_size)
    ici, dcn = cost.by_kind()
    return cost.flops, cost.bytes, ici, dcn


def run_cell(arch: str, shape_name: str, mesh, mesh_tag: str,
             step: str = "auto", out_dir: str = "experiments/dryrun",
             verbose: bool = True, overrides=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicability(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "step": step}
    if not ok:
        rec.update(skipped=True, reason=reason)
        _write(rec, out_dir, mesh_tag, arch, shape_name, step)
        if verbose:
            print(f"[skip] {arch} × {shape_name} ({mesh_tag}): {reason}")
        return rec
    try:
        t0 = time.time()
        cell = build_cell(arch, shape_name, mesh, step, overrides=overrides)
        with mesh:
            jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mflops = roofline.model_flops(cfg, shape)
        per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
        # HLO cost with while-loop trip multipliers (XLA cost_analysis counts
        # loop bodies once; see roofline/hlo_cost.py + EXPERIMENTS.md)
        t1 = time.time()
        flops, nbytes, ici, dcn = _analyze_compiled(compiled, mesh)
        t_extrap = time.time() - t1
        ici_s = sum(ici.values()) / roofline.ICI_BW
        dcn_s = sum(dcn.values()) / roofline.DCN_BW
        rf = roofline.Roofline(
            compute_s=flops / roofline.PEAK_FLOPS,
            memory_s=nbytes / roofline.HBM_BW,
            collective_s=ici_s + dcn_s,
            flops_per_device=flops,
            bytes_per_device=nbytes,
            wire_bytes_per_device=sum(ici.values()) + sum(dcn.values()),
            model_flops_global=mflops,
            hlo_total_flops_global=flops * mesh.size,
            n_devices=mesh.size,
            coll_by_kind={**{f"ici/{k}": v for k, v in ici.items()},
                          **{f"dcn/{k}": v for k, v in dcn.items()}},
            n_collectives=-1,
        )
        rec.update(
            skipped=False, step=cell.meta["step"],
            n_params=cell.meta["n_params"], n_active=cell.meta["n_active"],
            n_micro=cell.meta.get("n_micro"),
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            hlo_analysis_s=round(t_extrap, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_bytes": per_dev_bytes,
                "per_device_gib": round(per_dev_bytes / 2**30, 3),
                "fits_16gib": per_dev_bytes < 16 * 2**30,
            },
            cost={"flops_per_device": flops,
                  "bytes_per_device": nbytes},
            model_flops_global=mflops,
            roofline=rf.to_dict(),
        )
        if verbose:
            print(f"[ok]   {arch} × {shape_name} ({mesh_tag}, {rec['step']}): "
                  f"{rec['memory']['per_device_gib']} GiB/dev "
                  f"(fits={rec['memory']['fits_16gib']}), "
                  f"dom={rf.dominant}, frac={rf.compute_fraction:.3f}, "
                  f"compile {t_compile:.1f}s hlo {t_extrap:.1f}s")
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec.update(skipped=False, ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} ({mesh_tag}): "
                  f"{type(e).__name__}: {e}")
    rec.setdefault("ok", "error" not in rec)
    _write(rec, out_dir, mesh_tag, arch, shape_name, step)
    return rec


def _write(rec, out_dir, mesh_tag, arch, shape_name, step):
    d = os.path.join(out_dir, mesh_tag)
    os.makedirs(d, exist_ok=True)
    suffix = "" if step == "auto" else f"__{step}"
    path = os.path.join(d, f"{arch}__{shape_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--step", default="auto",
                    choices=["auto", "train", "train_compressed", "prefill",
                             "serve", "fl_round"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod1", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pod2", make_production_mesh(multi_pod=True)))

    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    n_fail = 0
    for tag, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh, tag, args.step, args.out)
                if not rec.get("skipped") and not rec.get("ok", True):
                    n_fail += 1
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")
    print("all requested cells passed")


if __name__ == "__main__":
    main()
