"""Production mesh construction.

Functions only (no module-level jax device state): importing this module
never initializes devices, so smoke tests keep their single CPU device.
"""
from __future__ import annotations

import jax


def _axis_types_kw(n: int) -> dict:
    """jax >= 0.5 wants explicit Auto axis types; older jax lacks the enum
    (Auto is the implicit default there)."""
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kw(len(axes)))


def make_mesh_from_spec(shape, axes):
    """Arbitrary mesh for scale-out (e.g. (8, 32, 16) = 4096 chips)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_types_kw(len(axes)))
