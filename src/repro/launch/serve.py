"""Serving driver: batched prefill + decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    max_len = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, max_len,
                             jnp.float32 if args.reduced else jnp.bfloat16)
    decode = jax.jit(model.decode_step)

    # prefill by stepping the decoder over the prompt (cache-exact; a fused
    # prefill path exists for the dry-run via model.prefill)
    prompt = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    t0 = time.time()
    logits = None
    for pos in range(args.prompt_len):
        logits, cache = decode(params, cache,
                               jnp.asarray(prompt[:, pos], jnp.int32),
                               jnp.int32(pos))
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, toks,
                               jnp.int32(args.prompt_len + i))
        toks = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
        out.append(np.asarray(toks))
    t_gen = time.time() - t0
    gen = np.stack(out, 1)
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prefill {args.prompt_len} tok in {t_prefill:.2f}s; "
          f"generated {gen.shape[1]} tok in {t_gen:.2f}s "
          f"({args.batch * gen.shape[1] / max(t_gen, 1e-9):.1f} tok/s)")
    print("[serve] sample tokens:", gen[0, :16].tolist())
    assert np.isfinite(np.asarray(logits)).all()


if __name__ == "__main__":
    main()
