"""Distributed training driver (single process; multi-host launch uses the
same entry point via jax.distributed — see README).

Fault tolerance: resumes from the latest checkpoint automatically; atomic
writes make crash-mid-save safe; ``--compressed-pods`` turns on the
hierarchical BCRS/OPWA gradient sync over the pod axis (the paper's
technique applied to multi-pod DP — docs/DESIGN.md §2).

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 100 --batch 8 --seq 256 --reduced --checkpoint-dir ckpt/
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import ARCH_IDS, get_config
from repro.core.bcrs import pod_link_schedule
from repro.data import synthetic_lm_tokens
from repro.dist.grad_sync import (init_compressed_state,
                                  make_compressed_train_step, make_train_step)
from repro.models import Model
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--compressed-pods", type=int, default=0,
                    help="N>=2: hierarchical BCRS sync across N virtual pods")
    ap.add_argument("--wire-cr", type=float, default=0.05)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.compressed_pods and not args.compressed_pods >= 2:
        ap.error(f"--compressed-pods must be >= 2 (got {args.compressed_pods})")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    rng = np.random.default_rng(args.seed)
    opt = make_optimizer(args.optimizer, args.lr)

    params = model.init(jax.random.PRNGKey(args.seed))
    # compressed sync carries per-pod error-feedback residuals in opt_state
    opt_state = (init_compressed_state(opt, params, n_pods=args.compressed_pods)
                 if args.compressed_pods else opt.init(params))
    start_step = 0
    if args.checkpoint_dir and ckpt.latest_step(args.checkpoint_dir) is not None:
        try:
            (params, opt_state), start_step, extra = ckpt.restore(
                args.checkpoint_dir, (params, opt_state))
        except KeyError as e:
            raise SystemExit(
                f"[train] checkpoint in {args.checkpoint_dir} does not match "
                f"the current optimizer-state structure (missing {e}); it was "
                f"likely written with a different --compressed-pods / "
                f"--optimizer setting") from e
        print(f"[train] resumed from step {start_step}")

    if args.compressed_pods:
        n_pods = args.compressed_pods
        step_fn = jax.jit(make_compressed_train_step(
            model, opt, n_pods=n_pods, wire_cr=args.wire_cr, gamma=2.0))
        # heterogeneous virtual DCN links -> BCRS per-pod CRs
        n_flat = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        crs = pod_link_schedule([100.0 / (i + 1) for i in range(n_pods)],
                                v_bytes=4 * n_flat, cr_star=args.wire_cr / 2,
                                cr_max=args.wire_cr)
        pod_crs = jnp.asarray(crs, jnp.float32)
        pod_coeffs = jnp.full((n_pods,), 1.0 / n_pods, jnp.float32)
        print(f"[train] compressed pod sync: CRs={np.round(crs, 4)}")
    else:
        step_fn = jax.jit(make_train_step(model, opt))

    t0 = time.time()
    for step in range(start_step, args.steps):
        toks = synthetic_lm_tokens(args.batch, args.seq + 1, cfg.vocab_size, rng)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.normal(0, 1, (args.batch, args.seq, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            v = cfg.vision
            batch["patches"] = jnp.asarray(
                rng.normal(0, 1, (args.batch, v.n_patches, v.d_vision)), jnp.float32)
        if args.compressed_pods:
            params, opt_state, metrics = step_fn(params, opt_state, batch,
                                                 pod_crs, pod_coeffs)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
        if (args.checkpoint_dir and args.checkpoint_every
                and (step + 1) % args.checkpoint_every == 0):
            ckpt.save(args.checkpoint_dir, step + 1, (params, opt_state),
                      extra={"arch": args.arch})
    print("[train] done")


if __name__ == "__main__":
    main()
