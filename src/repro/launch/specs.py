"""Per-cell step builders for the dry-run / launchers.

``build_cell(arch, shape, mesh, step)`` returns the jittable step, its
abstract inputs (ShapeDtypeStruct — no allocation), and in/out shardings.

Step selection by shape kind: train -> train_step, prefill -> prefill,
decode/long_decode -> serve_step. ``fl_round`` lowers the mesh-parallel FL
round (the paper's technique) for any train-shape cell; ``train_compressed``
lowers the hierarchical compressed-pod-sync step (beyond-paper).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import sharding as shd
from repro.dist.grad_sync import make_compressed_train_step, make_train_step
from repro.fed.mesh_round import make_fl_round_step
from repro.models import Model
from repro.optim import make_optimizer

SDS = jax.ShapeDtypeStruct


class Cell(NamedTuple):
    fn: Any
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def batch_abstract(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": SDS((b, s), jnp.int32), "labels": SDS((b, s), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        v = cfg.vision
        out["patches"] = SDS((b, v.n_patches, v.d_vision), jnp.bfloat16)
    return out


def _named(mesh, tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _scalar_specs(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def depth_variants(cfg: ModelConfig):
    """Two reduced-depth configs for HLO-cost extrapolation (XLA counts
    while-loop bodies once; cost is linear in the scanned unit count m:
    cost(m) = top + m*body). Returns ((ovr_a, m_a), (ovr_b, m_b), m_full)."""
    if cfg.family == "vlm":
        v = cfg.vision
        per = cfg.n_layers // v.n_cross_layers
        return (({"n_layers": per, "vision": dataclasses.replace(v, n_cross_layers=1)}, 1),
                ({"n_layers": 2 * per, "vision": dataclasses.replace(v, n_cross_layers=2)}, 2),
                v.n_cross_layers)
    if cfg.family == "encdec":
        e = cfg.encdec
        return (({"n_layers": 2, "encdec": dataclasses.replace(e, n_enc_layers=2)}, 2),
                ({"n_layers": 4, "encdec": dataclasses.replace(e, n_enc_layers=4)}, 4),
                cfg.n_layers)
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        return (({"n_layers": fd + 1}, 1), ({"n_layers": fd + 3}, 3),
                cfg.n_layers - fd)
    if cfg.family == "hybrid":
        return (({"n_layers": 2, "global_layers": (0,)}, 2),
                ({"n_layers": 4, "global_layers": (0,)}, 4), cfg.n_layers)
    return (({"n_layers": 2}, 2), ({"n_layers": 4}, 4), cfg.n_layers)


def choose_n_micro(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Gradient-accumulation factor bounding activation memory: target
    per-device tokens per microbatch (tighter for FSDP archs, whose HBM is
    dominated by params+grads)."""
    msh = dict(mesh.shape)
    n_batch = msh.get("pod", 1) * msh.get("data", 1)
    b_loc = max(shape.global_batch // n_batch, 1)
    tokens_per_dev = b_loc * shape.seq_len
    fsdp = cfg.n_params() >= cfg.fsdp_threshold
    target = 4096 if fsdp else 16384
    if cfg.family == "hybrid":   # parallel attn+SSM branches double the
        target = 8192            # per-token activation footprint
    if cfg.family == "moe" and fsdp:
        # FSDP expert-weight all-gathers repeat per microbatch and dominate
        # the collective term — fewer/larger microbatches trade activation
        # memory for a ~1/n_micro cut in weight-gather wire (§Perf iter 6)
        target = 8192
    n_micro = 1
    while (tokens_per_dev // n_micro > target
           and n_micro * 2 <= shape.global_batch
           and shape.global_batch % (n_micro * 2) == 0):
        n_micro *= 2
    return n_micro


def build_cell(arch: str, shape_name: str, mesh, step: str = "auto",
               *, optimizer: str = "sgd", lr: float = 1e-2,
               fl_local_steps: int = 2, compressed_cr: float = 0.01,
               overrides: Optional[dict] = None,
               n_micro: Optional[int] = None) -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    rules = shd.make_rules(cfg, shape, mesh)
    shd.set_rules(rules)
    model = Model(cfg)
    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, params_abs)
    pshard = _named(mesh, pspecs)

    if step == "auto":
        step = {"train": "train", "prefill": "prefill",
                "decode": "serve", "long_decode": "serve"}[shape.kind]

    meta = {"arch": arch, "shape": shape_name, "step": step,
            "n_params": cfg.n_params(), "n_active": cfg.n_active_params(),
            "n_devices": mesh.size}

    if step in ("train", "train_compressed"):
        opt = make_optimizer(optimizer, lr)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        if jax.tree.leaves(opt_abs):
            # optimizer state follows the param specs (ZeRO-style)
            oshard = _named(mesh, _opt_specs_like(opt_abs, pspecs))
        else:
            oshard = opt_abs
        batch_abs = batch_abstract(cfg, shape)
        bshard = _named(mesh, shd.batch_specs(cfg, batch_abs))
        if step == "train":
            nm = n_micro if n_micro is not None else choose_n_micro(cfg, shape, mesh)
            meta["n_micro"] = nm
            # the micro-scan body (fwd+bwd over one microbatch) is counted
            # once by HLO cost analysis but runs n_micro times
            meta["cost_multiplier"] = nm
            fn = make_train_step(model, opt, n_micro=nm,
                                 grad_shardings=pshard)
            args = (params_abs, opt_abs, batch_abs)
            metrics_abs = jax.eval_shape(fn, *args)[2]
            return Cell(fn, args, (pshard, oshard, bshard),
                        (pshard, oshard, _scalar_specs(mesh, metrics_abs)),
                        (0, 1), meta)
        n_pods = max(dict(mesh.shape).get("pod", 1), 2)
        # single-pod: compress across 2 data halves (same machinery)
        fn = make_compressed_train_step(model, opt, n_pods=n_pods,
                                        wire_cr=compressed_cr, gamma=2.0)
        crs_abs = SDS((n_pods,), jnp.float32)
        coef_abs = SDS((n_pods,), jnp.float32)
        args = (params_abs, opt_abs, batch_abs, crs_abs, coef_abs)
        metrics_abs = jax.eval_shape(fn, *args)[2]
        rshard = NamedSharding(mesh, P())
        return Cell(fn, args, (pshard, oshard, bshard, rshard, rshard),
                    (pshard, oshard, _scalar_specs(mesh, metrics_abs)),
                    (0, 1), meta)

    if step == "prefill":
        batch_abs = batch_abstract(cfg, shape)
        bshard = _named(mesh, shd.batch_specs(cfg, batch_abs))

        def fn(params, batch):
            return model.prefill(params, batch)[0]

        logit_shard = NamedSharding(mesh, rules.logical(("batch", "vocab")))
        return Cell(fn, (params_abs, batch_abs), (pshard, bshard),
                    logit_shard, (), meta)

    if step == "serve":
        b = shape.global_batch
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len, jnp.bfloat16))
        cspecs = shd.cache_specs(cfg, cache_abs)
        cshard = _named(mesh, cspecs)
        tok_abs = SDS((b,), jnp.int32)
        pos_abs = SDS((), jnp.int32)
        tshard = NamedSharding(mesh, rules.logical(("batch",)))
        sshard = NamedSharding(mesh, P())

        def fn(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        logit_shard = NamedSharding(mesh, rules.logical(("batch", "vocab")))
        return Cell(fn, (params_abs, cache_abs, tok_abs, pos_abs),
                    (pshard, cshard, tshard, sshard),
                    (logit_shard, cshard), (1,), meta)

    if step == "fl_round":
        n_clients = rules.batch_size()
        # cap per-client/step batch: one client maps to one data slice, so
        # its whole local batch lands on 16 chips — bound the activations
        bs = min(max(shape.global_batch // n_clients, 1), 4)
        cb = {"tokens": SDS((n_clients, fl_local_steps, bs, shape.seq_len),
                            jnp.int32),
              "labels": SDS((n_clients, fl_local_steps, bs, shape.seq_len),
                            jnp.int32)}
        if cfg.family == "encdec":
            cb["frames"] = SDS((n_clients, fl_local_steps, bs, shape.seq_len,
                                cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            v = cfg.vision
            cb["patches"] = SDS((n_clients, fl_local_steps, bs, v.n_patches,
                                 v.d_vision), jnp.bfloat16)
        cbspec = jax.tree.map(
            lambda l: P(*((rules.batch_axes,) + (None,) * (len(l.shape) - 1))),
            cb)
        cbshard = _named(mesh, cbspec)
        coef_abs = SDS((n_clients,), jnp.float32)
        crs_abs = SDS((n_clients,), jnp.float32)
        vshard = NamedSharding(mesh, P())
        fn = make_fl_round_step(model, lr_local=lr)
        meta["n_clients"] = n_clients
        # local-steps scan body counted once by HLO cost analysis
        meta["cost_multiplier"] = fl_local_steps
        return Cell(fn, (params_abs, cb, coef_abs, crs_abs),
                    (pshard, cbshard, vshard, vshard),
                    (pshard, NamedSharding(mesh, P())), (0,), meta)

    raise ValueError(f"unknown step {step!r}")


def _opt_specs_like(opt_abs, pspecs):
    """Optimizer-state specs mirroring param specs (momentum/adam trees)."""
    if isinstance(opt_abs, dict) and "m" in opt_abs:   # adamw
        return {"m": pspecs, "v": pspecs, "t": P()}
    return pspecs                                       # momentum
