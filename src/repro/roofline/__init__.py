from repro.roofline.analysis import (DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS,
                                     CollectiveSummary, Roofline, analyze,
                                     model_flops, parse_collectives)

__all__ = ["analyze", "parse_collectives", "model_flops", "Roofline",
           "CollectiveSummary", "PEAK_FLOPS", "HBM_BW", "ICI_BW", "DCN_BW"]
