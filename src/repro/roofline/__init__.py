from repro.roofline.analysis import (DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS,
                                     CollectiveSummary, Roofline, analyze,
                                     model_flops, parse_collectives)
from repro.roofline.kernel_bytes import (megakernel_hbm_bytes,
                                         merge_traffic_ratio,
                                         unfused_merge_bytes,
                                         wire_stream_bytes)

__all__ = ["analyze", "parse_collectives", "model_flops", "Roofline",
           "CollectiveSummary", "PEAK_FLOPS", "HBM_BW", "ICI_BW", "DCN_BW",
           "megakernel_hbm_bytes", "unfused_merge_bytes",
           "merge_traffic_ratio", "wire_stream_bytes"]
