"""Roofline terms from a compiled (dry-run) artifact.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = sum over collective ops of wire_bytes_per_device / link_bw

``cost_analysis()`` on an SPMD executable reports *per-device* flops/bytes
(verified empirically). Collective bytes are NOT in cost_analysis: we parse
the compiled HLO text, reconstruct each op's replica groups (including the
``[G,N]<=[dims]T(perm)`` iota form), apply ring-algorithm wire factors, and
classify intra-pod (ICI) vs cross-pod (DCN) by whether a group spans pods.

Hardware model (TPU v5e-like, per the assignment):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI;
    25 GB/s/chip cross-pod DCN (assumption, documented).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_RESULT_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _parse_groups(line: str, n_devices: int) -> List[np.ndarray]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        flat = ids.reshape(-1)
        return [flat[i * n:(i + 1) * n] for i in range(g)]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        groups = []
        for part in re.findall(r"\{([^}]*)\}", m.group(1)):
            ids = [int(x) for x in part.split(",") if x.strip()]
            groups.append(np.array(ids))
        return groups
    return [np.arange(n_devices)]  # default: all devices


@dataclass
class CollectiveOp:
    kind: str
    bytes_result: int
    group_size: int
    cross_pod: bool
    wire_bytes_per_device: float


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes_per_device for o in self.ops)

    def seconds(self) -> float:
        return sum(o.wire_bytes_per_device / (DCN_BW if o.cross_pod else ICI_BW)
                   for o in self.ops)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0.0) + o.wire_bytes_per_device
        return out


def parse_collectives(hlo_text: str, n_devices: int,
                      pod_size: Optional[int] = None) -> CollectiveSummary:
    """pod_size: devices per pod (None -> single pod, nothing is cross-pod)."""
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        kind = None
        for k in _COLL_KINDS:
            # match the op name after '=' (e.g. "f32[8] all-reduce(" or
            # "all-reduce-start("), not metadata mentions
            if re.search(rf"\s{k}(-start)?\(", stripped):
                kind = k
                break
        if kind is None or stripped.startswith("ROOT %fusion"):
            continue
        if re.match(r"(ROOT )?%?\w+[\w.-]* = ", stripped) is None:
            continue
        lhs = stripped.split(" = ", 1)[1]
        result_part = lhs.split(f" {kind}")[0]
        rbytes = sum(_shape_bytes(d, s) for d, s in _RESULT_RE.findall(result_part))
        if rbytes == 0:
            continue
        groups = _parse_groups(stripped, n_devices)
        n = max(len(g) for g in groups)
        if n <= 1:
            continue
        cross = False
        if pod_size:
            for g in groups:
                if len(set(int(i) // pod_size for i in g)) > 1:
                    cross = True
                    break
        # ring-algorithm wire bytes per device
        if kind == "all-reduce":
            wire = 2.0 * (n - 1) / n * rbytes
        elif kind == "all-gather":
            wire = (n - 1) / n * rbytes          # result = gathered size
        elif kind == "reduce-scatter":
            wire = (n - 1) * rbytes              # result = scattered shard
        elif kind == "all-to-all":
            wire = (n - 1) / n * rbytes
        else:  # collective-permute
            wire = float(rbytes)
        summary.ops.append(CollectiveOp(kind, rbytes, n, cross, wire))
    return summary


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_global: float
    hlo_total_flops_global: float
    n_devices: int
    coll_by_kind: Dict[str, float]
    n_collectives: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: useful-compute time / bound step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        useful = self.model_flops_global / self.n_devices / PEAK_FLOPS
        return useful / t

    @property
    def model_flops_ratio(self) -> float:
        if self.hlo_total_flops_global <= 0:
            return 0.0
        return self.model_flops_global / self.hlo_total_flops_global

    @property
    def hbm_fraction(self) -> float:
        """memory-term share of the bound step time (the roofline target for
        decode steps, which are HBM-bound by construction)."""
        t = self.step_time_s
        return self.memory_s / t if t > 0 else 0.0

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "compute_fraction": self.compute_fraction,
            "hbm_fraction": self.hbm_fraction,
            "model_flops_ratio": self.model_flops_ratio,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "coll_by_kind": self.coll_by_kind,
            "n_collectives": self.n_collectives,
        }


def analyze(cost: dict, hlo_text: str, n_devices: int,
            model_flops_global: float,
            pod_size: Optional[int] = None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text, n_devices, pod_size)
    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=coll.seconds(),
        flops_per_device=flops,
        bytes_per_device=nbytes,
        wire_bytes_per_device=coll.total_wire_bytes,
        model_flops_global=model_flops_global,
        hlo_total_flops_global=flops * n_devices,
        n_devices=n_devices,
        coll_by_kind=coll.by_kind(),
        n_collectives=len(coll.ops),
    )


def model_flops(cfg, shape_cfg) -> float:
    """MODEL_FLOPS per the assignment: 6·N·D train (N_active for MoE);
    2·N_active·B per decoded token; 2·N_active·B·S prefill."""
    n_active = cfg.n_active_params()
    if shape_cfg.kind == "train":
        return 6.0 * n_active * shape_cfg.global_batch * shape_cfg.seq_len
    if shape_cfg.kind == "prefill":
        return 2.0 * n_active * shape_cfg.global_batch * shape_cfg.seq_len
    return 2.0 * n_active * shape_cfg.global_batch   # decode: one token
