"""HBM-traffic accounting for the client-merge hot path: the traced-k Pallas
megakernel pipeline vs the unfused XLA lowering of ``aggregate_updates``.

Two complementary accountings, compared in ``BENCH_kernels.json``:

  * ``megakernel_hbm_bytes`` — the kernel pipeline's DMA traffic, computed
    analytically from its grid/block structure. Pallas fetches every
    declared input block and flushes every output block once per grid step,
    so the byte count is exact by construction (it is the same model
    ``pl.CostEstimate`` uses): threshold-find streams the [C, n] operands
    once per bisection sweep; fused-merge reads them once more and writes
    only the aggregate (plus the EF residual tile).

  * ``unfused_merge_bytes`` — the jnp path, measured from the compiled HLO
    via ``repro.roofline.hlo_cost.analyze_hlo``. XLA's own
    ``cost_analysis()`` counts while-loop bodies ONCE regardless of trip
    count, hiding 32x of the traced-k bisection's traffic — exactly the
    distortion hlo_cost exists to undo — so the trip-count-aware number is
    the honest unfused baseline. The uncorrected ``cost_analysis`` number is
    reported alongside it for transparency.

Both accountings are per logical execution of the merge (one cohort, one
round) on one device.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import strategies as strat_mod
from repro.roofline.hlo_cost import analyze_hlo

_F32 = 4
_I32 = 4
_U32 = 4


def _pad_to(n: int, tile: int) -> int:
    return n + ((-n) % tile)


def megakernel_hbm_bytes(c: int, n: int, strategy: str) -> dict:
    """Analytic DMA bytes of the two-kernel pipeline for one [C, n] merge.

    Returns ``{"threshold", "merge", "total", "passes"}`` where ``passes``
    is total / (C*n*4) — logical full reads of the update matrix.

    The strategy's registered capabilities drive the accounting: the EF
    residual stream follows ``needs_residuals``, the codec scale streams
    (threshold-find's [C, 1] absmax write, fused-merge's [C, 1] scales
    read) follow ``kernel_codec``, and strategies that declare
    ``megakernel=False`` (dense exchange, or codecs without a registered
    kernel lowering) are rejected rather than priced with a model that does
    not match their lowering.
    """
    from repro.kernels.fused_merge import TILE_N as MERGE_TILE
    from repro.kernels.threshold_find import SWEEPS
    strat = strat_mod.get(strategy)
    if not strat.megakernel:
        raise ValueError(
            f"strategy {strategy!r} does not route through the megakernel "
            f"pipeline (megakernel=False); its traffic is not modeled here")
    ef = strat.needs_residuals
    codec = strat.kernel_codec is not None
    n_pad = _pad_to(n, MERGE_TILE)  # one padding serves both kernels
    mat = c * n_pad * _F32
    n_ops = 2 if ef else 1          # (updates[, residuals]) streamed tiles
    # threshold-find: every sweep streams the [C, n] operand tiles; the
    # [C, 1] ks/lo/threshold scalars ride along once per grid step
    thresh = SWEEPS * n_ops * mat + c * (_I32 + _U32)
    if codec:
        thresh += c * _F32          # [C, 1] absmax (the quantizer scale)
    # fused merge: one read of the operands + per-grid-step [C, 1] columns,
    # one write of the [1, n] aggregate (+ the [C, n] EF residual update)
    merge = n_ops * mat + n_pad * _F32 + c * (_U32 + 2 * _F32)
    if codec:
        merge += c * _F32           # [C, 1] scales column read
    if ef:
        merge += mat                # new_residuals write
    total = thresh + merge
    return {"threshold": float(thresh), "merge": float(merge),
            "total": float(total), "passes": total / (c * n * _F32)}


def wire_stream_bytes(strategy: str, n: int, k: int) -> dict:
    """Bytes-on-the-wire pricing of one client's upload under the
    strategy's registered ``WireFormat``, against the idx32+f32 reference
    pair (8 B/survivor).

    ``pair_ratio`` is the PER-SURVIVOR value+index stream ratio — the
    number the packed formats are judged on (int8: (4+1)/8 = 5/8; int4:
    (4+0.5)/8 = 9/16); the per-message scale rides in ``overhead_bytes``
    and is amortized over k in ``total_ratio`` (a bitmask stream, priced
    per coordinate, lands there too).
    """
    wire = strat_mod.get(strategy).wire
    if wire.dense:
        raise ValueError(
            f"strategy {strategy!r} exchanges dense tensors; survivor-"
            "stream pricing is meaningless (see cost_model."
            "uncompressed_round)")
    ref_pair = 8.0                  # idx32 + f32
    pair = wire.index_bytes + wire.value_bytes
    total = wire.bytes_on_wire(n, k)
    return {"kind": wire.kind,
            "pair_bytes": pair,
            "pair_ratio": pair / ref_pair,
            "overhead_bytes": wire.overhead_bytes,
            "mask_bits": wire.mask_bits,
            "bytes_on_wire": float(total),
            "ref_bytes": ref_pair * k,
            "total_ratio": float(total) / (ref_pair * k)}


def unfused_merge_bytes(spec, c: int, n: int,
                        platform: Optional[str] = None) -> dict:
    """Trip-count-aware HBM bytes of the unfused (jnp) ``aggregate_updates``
    lowering for a [C, n] merge, plus XLA's uncorrected ``cost_analysis``
    number. ``spec``: a ``fed.engine.ClientUpdateSpec`` with
    ``use_kernel=False``.
    """
    from repro.fed.engine import aggregate_updates
    assert not spec.use_kernel, "baseline must be the jnp lowering"
    u = jnp.zeros((c, n), jnp.float32)
    w = jnp.ones((c,), jnp.float32) / c
    ks = jnp.ones((c,), jnp.int32)
    args = [u, w, ks]
    if spec.needs_residuals:
        fn = jax.jit(lambda u, w, ks, r: aggregate_updates(
            spec, u, w, ks, residuals=r))
        args.append(jnp.zeros((c, n), jnp.float32))
    else:
        fn = jax.jit(lambda u, w, ks: aggregate_updates(spec, u, w, ks))
    compiled = fn.lower(*args).compile()
    cost = analyze_hlo(compiled.as_text(), 1)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    return {"total": float(cost.bytes),
            "passes": cost.bytes / (c * n * _F32),
            "xla_cost_analysis": xla_bytes,
            "xla_cost_analysis_passes": xla_bytes / (c * n * _F32)}


def merge_traffic_ratio(spec, c: int, n: int) -> dict:
    """unfused / kernel HBM-byte ratio for one [C, n] merge (>= 3x is the
    acceptance bar for the megakernel pipeline)."""
    kern = megakernel_hbm_bytes(c, n, spec.strategy)
    base = unfused_merge_bytes(spec, c, n)
    return {"c": c, "n": n, "strategy": spec.strategy,
            "kernel": kern, "unfused": base,
            "ratio": base["total"] / kern["total"]}
