"""HLO-text cost model with while-loop trip-count multipliers.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE regardless of trip
count, which silently hides ~L× of a scanned transformer's cost. This module
parses the compiled (per-device SPMD) HLO text instead:

  * builds the computation call graph (ENTRY -> while bodies -> nested),
  * reads each while op's ``known_trip_count`` backend config,
  * counts dot FLOPs per computation (matmuls dominate TPU compute),
  * counts bytes at fusion boundaries (operands+results of top-level ops,
    NOT ops inside fused computations — a post-fusion traffic estimate),
  * attributes collectives (with ring wire factors) per computation,

then multiplies everything by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.roofline.analysis import (_DTYPE_BYTES, _parse_groups)

_COMP_HEADER = re.compile(r"^(ENTRY )?%([\w.-]+)\s*\(.*\{\s*$")
# "... = TYPE opname(operands..." — TYPE may be a tuple with layouts; the op
# name is the first lowercase token directly followed by '('
_OP_RE = re.compile(
    r"^\s*(?:ROOT )?%([\w.-]+) = (.*?) ([a-z][\w.-]*)\((.*)$")
_SKIP_BYTES_OPS = {"while", "tuple", "get-tuple-element", "parameter",
                   "bitcast", "after-all", "opt-barrier", "conditional"}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[\\":{\s]+n[\\":\s]+(\d+)')
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply)=%([\w.-]+)")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class CompCost:
    dot_flops: float = 0.0
    bytes: float = 0.0
    colls: List[Tuple[str, int, int, bool]] = field(default_factory=list)
    # (kind, result_bytes, group_size, cross_pod)
    calls: List[Tuple[str, str, int]] = field(default_factory=list)
    # (callee, kind: while|fusion|other, trip)


@dataclass
class HLOCost:
    flops: float
    bytes: float
    collectives: List[Tuple[str, float, int, bool]]
    # (kind, wire_bytes/dev, group_size, cross_pod)

    def wire_bytes(self) -> float:
        return sum(w for _, w, _, _ in self.collectives)

    def by_kind(self):
        ici, dcn = {}, {}
        for k, w, _, cross in self.collectives:
            d = dcn if cross else ici
            d[k] = d.get(k, 0.0) + w
        return ici, dcn


def _split_computations(text: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    name = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m:
            name = m.group(2)
            comps[name] = []
            if m.group(1):
                entry = name
        elif name is not None:
            comps[name].append(line)
    return comps, entry


def _parse_computation(lines: List[str], n_devices: int,
                       pod_size: Optional[int] = None) -> CompCost:
    cost = CompCost()
    shapes: Dict[str, str] = {}
    for line in lines:
        s = line.strip()
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        shapes[name] = type_str
        if op.startswith("constant"):
            continue
        if op in _SKIP_BYTES_OPS:
            if op == "while":
                tm = _TRIP_RE.search(s)
                trip = int(tm.group(1)) if tm else 1
                for callee in _CALLED_RE.findall(s):
                    cost.calls.append((callee, "while", trip))
            continue
        # ---- called computations
        if op == "fusion":
            cm = re.search(r"calls=%([\w.-]+)", s)
            if cm:
                cost.calls.append((cm.group(1), "fusion", 1))
            # traffic at the fusion boundary
            cost.bytes += _shape_bytes(type_str)
            for ref in _OPERAND_RE.findall(rest.split(", calls=")[0]):
                cost.bytes += _shape_bytes(shapes.get(ref, ""))
            continue
        # ---- collectives
        matched_coll = None
        for k in _COLL_KINDS:
            if op == k or op == k + "-start":
                matched_coll = k
                break
        if matched_coll:
            groups = _parse_groups(s, n_devices)
            n = max(len(g) for g in groups) if groups else 1
            cross = False
            if pod_size:
                for g in groups:
                    if len(set(int(i) // pod_size for i in g)) > 1:
                        cross = True
                        break
            rbytes = _shape_bytes(type_str)
            if n > 1 and rbytes:
                cost.colls.append((matched_coll, rbytes, n, cross))
            cost.bytes += rbytes * 2  # read + write
            continue
        if op.endswith("-done"):
            continue
        # ---- dot flops
        if op == "dot":
            out_elems = 1
            for d in _shape_dims(type_str):
                out_elems *= d
            cdm = _CDIMS_RE.search(s)
            k_elems = 1
            if cdm:
                refs = _OPERAND_RE.findall(rest)
                if refs:
                    lhs_dims = _shape_dims(shapes.get(refs[0], ""))
                    for ci in cdm.group(1).split(","):
                        if ci and int(ci) < len(lhs_dims):
                            k_elems *= lhs_dims[int(ci)]
            cost.dot_flops += 2.0 * out_elems * k_elems
        # ---- generic op traffic (operands + result)
        cost.bytes += _shape_bytes(type_str)
        for ref in _OPERAND_RE.findall(rest):
            if ref in shapes:
                cost.bytes += _shape_bytes(shapes[ref])
    return cost


def analyze_hlo(text: str, n_devices: int,
                pod_size: Optional[int] = None) -> HLOCost:
    comps, entry = _split_computations(text)
    parsed = {name: _parse_computation(lines, n_devices, pod_size)
              for name, lines in comps.items()}
    if entry is None:
        entry = next((n for n in comps if n.startswith("main")), None)
    if entry is None:
        return HLOCost(0.0, 0.0, [])

    flops = 0.0
    nbytes = 0.0
    colls: List[Tuple[str, float, int, bool]] = []

    def visit(name: str, mult: float, seen: tuple):
        nonlocal flops, nbytes
        if name not in parsed or name in seen:
            return
        c = parsed[name]
        flops += mult * c.dot_flops
        nbytes += mult * c.bytes
        for kind, rbytes, n, cross in c.colls:
            if kind == "all-reduce":
                wire = 2.0 * (n - 1) / n * rbytes
            elif kind == "all-gather":
                wire = (n - 1) / n * rbytes
            elif kind == "reduce-scatter":
                wire = (n - 1.0) * rbytes
            elif kind == "all-to-all":
                wire = (n - 1) / n * rbytes
            else:
                wire = float(rbytes)
            colls.append((kind, mult * wire, n, cross))
        for callee, kind, trip in c.calls:
            if kind == "while":
                visit(callee, mult * trip, seen + (name,))
            elif kind == "fusion":
                # fused dots still execute: count flops only (bytes at the
                # boundary were counted at the call site)
                fc = parsed.get(callee)
                if fc is not None:
                    flops += mult * fc.dot_flops
                    for fcallee, fkind, ftrip in fc.calls:
                        if fkind == "while":
                            visit(fcallee, mult * ftrip, seen + (name,))
            else:
                visit(callee, mult, seen + (name,))

    visit(entry, 1.0, ())
    return HLOCost(flops, nbytes, colls)
