"""Atomic, restartable checkpointing for pytrees of jax/np arrays.

Format: one msgpack file per step holding {path -> (dtype, shape, raw bytes)}
plus metadata and a CRC32 integrity digest. Writes go to a temp file and are
``os.replace``d into place (atomic on POSIX), so a crash mid-write never
corrupts the latest checkpoint. Retention keeps the newest K steps.

bf16 arrays round-trip via ml_dtypes (a jax dependency).
"""
from __future__ import annotations

import os
import re
import warnings
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # bf16 numpy dtype
    import ml_dtypes
    _BF16 = np.dtype(ml_dtypes.bfloat16)
except Exception:  # pragma: no cover
    _BF16 = None

_CKPT_RE = re.compile(r"^step_(\d+)\.msgpack$")


class LayoutMismatch(ValueError):
    """A ``strict=False`` restore found NO leaf of the requested structure
    in the checkpoint — the tree layouts are unrelated (e.g. a legacy
    checkpoint from before a driver re-keyed its state). Distinct from the
    plain ``ValueError`` a shape-drifted leaf raises, so callers can fall
    back on layout changes without masking genuine config mismatches."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _dtype_str(a: np.ndarray) -> str:
    return "bfloat16" if _BF16 is not None and a.dtype == _BF16 else a.dtype.str


def _np_dtype(s: str):
    return _BF16 if s == "bfloat16" else np.dtype(s)


def save(ckpt_dir: str, step: int, tree, extra: Optional[dict] = None,
         keep: Optional[int] = 3) -> str:
    """Write ``step_<step>.msgpack`` atomically. ``keep`` retains the newest
    K steps; ``keep=None`` disables retention entirely (keep every file) —
    the population client-state store uses one file per chunk with the chunk
    id as the step, where pruning "old steps" would delete live clients."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    payload: Dict[str, Any] = {"step": step, "extra": extra or {}, "leaves": {}}
    crc = 0
    for key in sorted(flat):
        a = np.ascontiguousarray(flat[key])
        raw = a.tobytes()
        crc = zlib.crc32(raw, crc)
        payload["leaves"][key] = {"dtype": _dtype_str(a),
                                  "shape": list(a.shape), "data": raw}
    payload["crc32"] = crc
    final = os.path.join(ckpt_dir, f"step_{step}.msgpack")
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    _apply_retention(ckpt_dir, keep)
    return final


def _apply_retention(ckpt_dir: str, keep: Optional[int]) -> None:
    if keep is None:
        return
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f"step_{s}.msgpack"))
        except OSError:
            pass


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _CKPT_RE.match(name)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _load_validated(path: str) -> Dict[str, Any]:
    """Read + integrity-validate one checkpoint file. Any way a file can be
    broken on disk — truncated mid-write, garbled payload, wrong structure,
    or failing the CRC32 digest — surfaces as a single ``IOError`` here, so
    ``restore_latest_valid`` has one exception class that means "this file
    is corrupt" as opposed to "this file disagrees with your config"
    (``ValueError`` / ``LayoutMismatch``, which must never be masked)."""
    try:
        with open(path, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)
        if (not isinstance(payload, dict) or "crc32" not in payload
                or "leaves" not in payload or "step" not in payload):
            raise IOError(f"checkpoint {path} has a malformed payload")
        crc = 0
        for key in sorted(payload["leaves"]):
            crc = zlib.crc32(payload["leaves"][key]["data"], crc)
        if crc != payload["crc32"]:
            raise IOError(f"checkpoint {path} failed CRC32 integrity check")
    except IOError:
        raise
    except Exception as e:   # msgpack unpack errors on truncated/garbled data
        raise IOError(f"checkpoint {path} is unreadable: {e}") from e
    return payload


def restore_latest_valid(ckpt_dir: str, like, strict: bool = True
                         ) -> Tuple[Any, int, dict]:
    """``restore`` that degrades gracefully on corruption: walk the steps
    newest-first and restore the newest file that passes integrity
    validation, warning (not crashing) about each corrupt one skipped. A
    torn ``save`` cannot corrupt older steps (atomic ``os.replace`` + one
    file per step), so falling back one step recovers the run at the cost
    of the lost tail. Raises ``FileNotFoundError`` only when no intact
    checkpoint exists at all; config mismatches (``ValueError`` /
    ``LayoutMismatch``) still propagate — they mean every file would
    disagree with the caller, not that the newest is damaged."""
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    for step in reversed(steps):
        path = os.path.join(ckpt_dir, f"step_{step}.msgpack")
        try:
            _load_validated(path)
        except IOError as e:
            warnings.warn(f"skipping corrupt checkpoint {path}: {e}",
                          RuntimeWarning, stacklevel=2)
            continue
        return restore(ckpt_dir, like, step=step, strict=strict)
    raise FileNotFoundError(
        f"all {len(steps)} checkpoints in {ckpt_dir} failed integrity "
        f"validation")


def restore(ckpt_dir: str, like, step: Optional[int] = None,
            strict: bool = True) -> Tuple[Any, int, dict]:
    """Restore into the structure of ``like``. Returns (tree, step, extra).
    Verifies the CRC32 digest; raises on corruption.

    ``strict=False`` keeps a leaf's ``like`` value when the checkpoint has
    no entry for it (instead of raising) — e.g. resuming an eftopk FL run
    whose checkpoint predates EF-residual persistence starts with fresh
    residuals rather than refusing to load the params. A checkpoint that
    shares NO leaf with ``like`` still raises (:class:`LayoutMismatch`):
    that is a tree layout mismatch, and silently returning ``like``
    untouched would let a driver "resume" from fresh weights while
    skipping the restored step count. A leaf that matches by key but not
    by shape raises a plain ``ValueError`` (config drift, never a
    fallback case)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step}.msgpack")
    payload = _load_validated(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    matched = 0
    for p, leaf in leaves_p:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
        if not strict and key not in payload["leaves"]:
            out.append(jnp.asarray(leaf))
            continue
        matched += 1
        rec = payload["leaves"][key]
        if not strict and tuple(rec["shape"]) != tuple(np.shape(leaf)):
            # partial restore is for MISSING leaves, not reshaped ones: a
            # shape drift (e.g. EF residuals saved for a different cohort
            # size) must fail here with a named error, not later inside a
            # compiled program
            raise ValueError(
                f"checkpoint leaf {key!r} has shape {tuple(rec['shape'])} "
                f"but the requested structure expects "
                f"{tuple(np.shape(leaf))} — config mismatch "
                f"(e.g. cohort/pod count changed between save and resume)")
        a = np.frombuffer(rec["data"], dtype=_np_dtype(rec["dtype"]))
        out.append(jnp.asarray(a.reshape(rec["shape"])))
    if leaves_p and matched == 0:
        raise LayoutMismatch(
            f"checkpoint {path} shares no leaves with the requested "
            f"structure (checkpoint keys like "
            f"{sorted(payload['leaves'])[:3]}…) — tree layout mismatch, "
            f"not a partial restore")
    return (jax.tree_util.tree_unflatten(treedef, out), payload["step"],
            payload["extra"])
