from repro.checkpoint.checkpointer import (LayoutMismatch, latest_step,
                                           list_steps, restore, save)

__all__ = ["save", "restore", "latest_step", "list_steps", "LayoutMismatch"]
