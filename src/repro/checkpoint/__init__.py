from repro.checkpoint.checkpointer import (LayoutMismatch, latest_step,
                                           list_steps, restore,
                                           restore_latest_valid, save)

__all__ = ["save", "restore", "restore_latest_valid", "latest_step",
           "list_steps", "LayoutMismatch"]
