"""Global model-lowering flags.

COST_EXACT: set (only) by the dry-run's cost-measurement compiles. XLA's
cost_analysis counts while-loop bodies ONCE regardless of trip count, so
rolled scans (layers, attention q-chunks, GLA chunks, FL local steps) hide
their true FLOPs/bytes/collectives. In cost-exact mode every scan is fully
unrolled (``unroll=length``) at small layer depths; the dry-run then fits
cost(m) = top + m·body over two depths and evaluates at the full depth.
Never enabled for the memory/fits compile (rolled scans are the production
lowering).
"""

COST_EXACT = False


def scan_unroll(length: int) -> int:
    """unroll arg for lax.scan at the given trip count."""
    return length if COST_EXACT else 1
