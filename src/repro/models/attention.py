"""Attention: GQA/MHA/MQA with causal / sliding-window / bidirectional / cross
variants, q-chunked (flash-style memory profile) for long sequences, plus
single-token decode against a (possibly sequence-sharded) KV cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models import flags
from repro.models.layers import apply_rope, dense_init, mm

NEG_INF = -1e9


# ---------------------------------------------------------------- params
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, (d_model, n_heads * head_dim), dtype),
        "wk": dense_init(ks[1], d_model, (d_model, n_kv_heads * head_dim), dtype),
        "wv": dense_init(ks[2], d_model, (d_model, n_kv_heads * head_dim), dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def qkv_proj(p, x: jax.Array, n_heads: int, n_kv_heads: int, head_dim: int):
    """x: [B, S, d] -> q [B,S,H,D], k/v [B,S,Hkv,D]."""
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, s, n_heads, head_dim),
            k.reshape(b, s, n_kv_heads, head_dim),
            v.reshape(b, s, n_kv_heads, head_dim))


# ---------------------------------------------------------------- core attend
def _mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """q_pos: [Sq], k_pos: [Sk] -> bool [Sq, Sk] (True = attend)."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _attend_block(q, k, v, mask, scale):
    """q [B,Sq,H,Dqk]; k [B,Sk,Hkv,Dqk]; v [B,Sk,Hkv,Dv] (Dv may differ).

    bf16 inputs feed the MXU directly (f32 scores via preferred accumulation
    — halves the q/k/v HBM traffic vs up-casting; §Perf iteration 1);
    softmax stays f32; probs are cast back to the input dtype for the PV
    matmul (standard flash-attention practice)."""
    b, sq, h, d = q.shape
    hkv, dv = k.shape[2], v.shape[-1]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    if q.dtype == jnp.bfloat16:
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=jnp.float32) * scale
    else:
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(q.dtype), v,
                     preferred_element_type=q.dtype)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def attend(q, k, v, *, causal: bool = True, window: Optional[int] = None,
           q_offset: int = 0, chunk: int = 512) -> jax.Array:
    """Full attention, q-chunked when Sq > chunk to bound score memory.

    q: [B,Sq,H,D]; k,v: [B,Sk,Hkv,D]. FLOP count equals the unmasked product
    (causal masking does not reduce compiled FLOPs — standard for TPU).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    if sk >= 16384:  # long-context prefill: smaller q-chunks bound the
        chunk = min(chunk, 256)  # [B,H,chunk,Sk] score tiles
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    q_pos_all = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    if sq <= chunk:
        return _attend_block(q, k, v, _mask(q_pos_all, k_pos, causal, window), scale)

    pad = (-sq) % chunk
    if pad:  # non-divisible Sq (e.g. MTP's S-1): pad queries, slice back
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sq_p = sq + pad
    n_chunks = sq_p // chunk
    q_chunks = q.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 2, 3, 4)

    # checkpoint the chunk body: without it the scan stacks every chunk's
    # [B,H,chunk,Sk] scores/softmax/mask for backward (flash-attention-style
    # memory profile: backward recomputes scores one chunk at a time)
    @jax.checkpoint
    def chunk_attend(qc, kk, vv, idx):
        q_pos = q_offset + idx * chunk + jnp.arange(chunk)
        return _attend_block(qc, kk, vv, _mask(q_pos, k_pos, causal, window),
                             scale)

    def body(_, xs):
        qc, idx = xs
        return None, chunk_attend(qc, k, v, idx)

    _, outs = jax.lax.scan(body, None, (q_chunks, jnp.arange(n_chunks)),
                           unroll=flags.scan_unroll(n_chunks))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, h, v.shape[-1])
    return out[:, :sq] if pad else out


def decode_attend(q, k_cache, v_cache, pos, *, window: Optional[int] = None):
    """Single-token decode. q: [B,H,D]; caches [B,S,Hkv,D]; pos: scalar int.

    Works with a sequence-sharded cache: the softmax reduction over S lowers
    to small per-(B,H) collectives when S is sharded over the model axis.
    """
    b, h, d = q.shape
    s, hkv, dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[-1]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    qg = q.reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    k_pos = jnp.arange(s)
    valid = k_pos <= pos
    if window is not None:
        valid &= k_pos > pos - window
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dv).astype(q.dtype)


# ---------------------------------------------------------------- module-level
def self_attention(p, x, *, cfg, positions, causal=True, window=None,
                   rope=True, chunk=512):
    """Pre-projected full self-attention for train/prefill. x: [B,S,d].

    q is explicitly head-sharded over the model axis (XLA pads non-divisible
    head counts like qwen's 40/16): without the constraint the partitioner
    splits head_dim instead and every score matmul needs a partial-sum
    all-reduce of the [B,H,Sq,Sk] scores — §Perf iteration 2."""
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = qkv_proj(p, x, h, hkv, hd)
    q = constrain(q, ("batch", None, "heads", None))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = attend(q, k, v, causal=causal, window=window, chunk=chunk)
    return mm(out.reshape(x.shape[0], x.shape[1], h * hd), p["wo"])


def cross_attention(p, x, memory, *, cfg, chunk=512):
    """x: [B,Sq,d] attends to memory [B,Sk,d]; no mask, no rope."""
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, sq, _ = x.shape
    sk = memory.shape[1]
    q = (x @ p["wq"]).reshape(b, sq, h, hd)
    k = (memory @ p["wk"]).reshape(b, sk, hkv, hd)
    v = (memory @ p["wv"]).reshape(b, sk, hkv, hd)
    out = attend(q, k, v, causal=False, window=None, chunk=chunk)
    return mm(out.reshape(b, sq, h * hd), p["wo"])


def decode_self_attention(p, x, k_cache, v_cache, pos, *, cfg, window=None,
                          rope=True):
    """One-token self-attn with cache update.

    x: [B,d]; caches [B,S,Hkv,D]. Returns (out [B,d], new_k, new_v).
    RoPE is applied at write time for k (absolute positions).
    """
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    x1 = x[:, None, :]
    q, k, v = qkv_proj(p, x1, h, hkv, hd)
    if rope:
        posa = jnp.full((1,), pos)
        q = apply_rope(q, posa, cfg.rope_theta)
        k = apply_rope(k, posa, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, axis=1)
    out = decode_attend(q[:, 0], k_cache, v_cache, pos, window=window)
    return out.reshape(b, h * hd) @ p["wo"], k_cache, v_cache


def decode_cross_attention(p, x, ck_cache, cv_cache, *, cfg):
    """One-token cross-attn against precomputed memory KV. x: [B,d]."""
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b = x.shape[0]
    q = (x @ p["wq"]).reshape(b, h, hd)
    s = ck_cache.shape[1]
    out = decode_attend(q, ck_cache, cv_cache, jnp.asarray(s - 1), window=None)
    return out.reshape(b, h * hd) @ p["wo"]
