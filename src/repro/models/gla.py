"""Chunked gated linear attention — the TPU-native form of RWKV6's WKV
recurrence and Mamba-2/SSD's selective scan (see docs/DESIGN.md §2).

Recurrence (per batch b, head h; Dk = key dim, Dv = value dim):

    S_t = diag(exp(g_t)) S_{t-1} + k_t ⊗ v_t          (g_t <= 0)
    o_t = r_t · S_{t-1} + (r_t · (u ⊙ k_t)) v_t        [rwkv mode, bonus u]
    o_t = r_t · S_t                                    [ssd mode, inclusive]

The chunked algorithm factors decay products as exp of *differences* of
cumulative log-decay, which are always <= 0 within a chunk — numerically safe
in f32 with no range tricks. Intra-chunk pairwise terms use an explicit
[c, c, Dk] log-space tensor for vector decay (exact) and a plain matmul with a
[c, c] decay matrix for scalar decay (MXU-aligned).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags


def _chunk_vector(r, k, v, g, u, s0, inclusive: bool):
    """One chunk, per-channel decay. r,k,g: [B,H,c,Dk]; v: [B,H,c,Dv];
    u: [H,Dk] or None; s0: [B,H,Dk,Dv]."""
    c = r.shape[2]
    cin = jnp.cumsum(g, axis=2)                      # inclusive cumsum
    cex = cin - g                                     # exclusive
    qdec = cin if inclusive else cex                  # decay applied to queries
    # inter-chunk: (r ⊙ exp(qdec)) · S0
    r_dec = r * jnp.exp(qdec)
    o = jnp.einsum("bhcd,bhde->bhce", r_dec, s0)
    # intra-chunk pairwise: A[i,j] = sum_d r[i,d] k[j,d] exp(qdec[i,d]-cin[j,d])
    diff = qdec[:, :, :, None, :] - cin[:, :, None, :, :]      # [B,H,c,c,Dk]
    diff = jnp.minimum(diff, 0.0)                     # j>i region masked below
    w = jnp.exp(diff)
    scores = jnp.einsum("bhid,bhjd,bhijd->bhij", r, k, w)
    i_idx = jnp.arange(c)
    mask = (i_idx[:, None] >= i_idx[None, :]) if inclusive else (i_idx[:, None] > i_idx[None, :])
    scores = jnp.where(mask, scores, 0.0)
    o = o + jnp.einsum("bhij,bhje->bhie", scores, v)
    if u is not None:  # rwkv bonus: current token contributes via u
        bonus = jnp.einsum("bhcd,hd,bhcd->bhc", r, u, k)
        o = o + bonus[..., None] * v
    # state update: S' = diag(exp(cin_last)) S0 + sum_j exp(cin_last - cin_j) k_j ⊗ v_j
    cl = cin[:, :, -1:, :]                            # [B,H,1,Dk]
    k_dec = k * jnp.exp(cl - cin)
    s1 = jnp.exp(cl[:, :, 0, :, None]) * s0 + jnp.einsum("bhcd,bhce->bhde", k_dec, v)
    return o, s1


def _chunk_scalar(r, k, v, g, u, s0, inclusive: bool):
    """One chunk, per-head scalar decay. g: [B,H,c]; u: [H,Dk] or None."""
    c = r.shape[2]
    cin = jnp.cumsum(g, axis=2)
    qdec = cin if inclusive else cin - g
    r_dec = r * jnp.exp(qdec)[..., None]
    o = jnp.einsum("bhcd,bhde->bhce", r_dec, s0)
    dmat = jnp.exp(jnp.minimum(qdec[:, :, :, None] - cin[:, :, None, :], 0.0))
    scores = jnp.einsum("bhid,bhjd->bhij", r, k) * dmat
    i_idx = jnp.arange(c)
    mask = (i_idx[:, None] >= i_idx[None, :]) if inclusive else (i_idx[:, None] > i_idx[None, :])
    scores = jnp.where(mask, scores, 0.0)
    o = o + jnp.einsum("bhij,bhje->bhie", scores, v)
    if u is not None:  # bonus: current token weighted by u
        bonus = jnp.einsum("bhcd,hd,bhcd->bhc", r, u, k)
        o = o + bonus[..., None] * v
    cl = cin[:, :, -1:]
    k_dec = k * jnp.exp(cl - cin)[..., None]
    s1 = jnp.exp(cl)[..., None] * s0 + jnp.einsum("bhcd,bhce->bhde", k_dec, v)
    return o, s1


def chunked_gla(r, k, v, g, *, u: Optional[jax.Array] = None,
                chunk: int = 64, inclusive: bool = False,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Sequence-parallel gated linear attention.

    r, k: [B, H, T, Dk]; v: [B, H, T, Dv];
    g: log-decay, [B, H, T, Dk] (vector) or [B, H, T] (scalar), g <= 0.
    u: [H, Dk] rwkv bonus (vector mode only). inclusive=True -> SSD semantics.
    Returns (o [B, H, T, Dv], final_state [B, H, Dk, Dv]). Computation in f32.
    """
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    scalar = g.ndim == 3
    f32 = lambda x: x.astype(jnp.float32)
    r, k, v, g = f32(r), f32(k), f32(v), f32(g)
    if u is not None:
        u = f32(u)
    if initial_state is None:
        s = jnp.zeros((b, h, dk, dv), jnp.float32)
    else:
        s = f32(initial_state)
    assert t % chunk == 0, f"T={t} not divisible by chunk={chunk}"
    nc = t // chunk

    def split(x):  # [B,H,T,...] -> [nc,B,H,c,...]
        return x.reshape(b, h, nc, chunk, *x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    rs, ks, vs, gs = split(r), split(k), split(v), split(g)

    # checkpoint the chunk body: the scan would otherwise stack every
    # chunk's [c,c,(Dk)] pairwise tensors for backward
    @jax.checkpoint
    def chunk_fn(s_c, rc, kc, vc, gc):
        if scalar:
            return _chunk_scalar(rc, kc, vc, gc, u, s_c, inclusive)
        return _chunk_vector(rc, kc, vc, gc, u, s_c, inclusive)

    def body(s_c, xs):
        rc, kc, vc, gc = xs
        o, s_n = chunk_fn(s_c, rc, kc, vc, gc)
        return s_n, o

    s_final, outs = jax.lax.scan(body, s, (rs, ks, vs, gs),
                                 unroll=flags.scan_unroll(nc))
    o = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, t, dv)
    return o, s_final


def gla_decode(r, k, v, g, state, *, u: Optional[jax.Array] = None,
               inclusive: bool = False) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrent step. r,k,g: [B,H,Dk] (g scalar: [B,H]);
    v: [B,H,Dv]; state: [B,H,Dk,Dv]. Returns (o [B,H,Dv], new_state)."""
    f32 = lambda x: x.astype(jnp.float32)
    r, k, v, g, state = f32(r), f32(k), f32(v), f32(g), f32(state)
    decay = jnp.exp(g)
    if g.ndim == 2:  # scalar per head
        decay = decay[..., None]
    kv = k[..., :, None] * v[..., None, :]
    if inclusive:
        state = decay[..., None] * state + kv
        o = jnp.einsum("bhd,bhde->bhe", r, state)
    else:
        eff = state + (u[None, :, :, None] * kv if u is not None else 0.0)
        o = jnp.einsum("bhd,bhde->bhe", r, eff)
        state = decay[..., None] * state + kv
    return o, state


def reference_recurrence(r, k, v, g, *, u=None, inclusive=False,
                         initial_state=None):
    """O(T) sequential oracle for tests. Same shapes as chunked_gla."""
    b, h, t, dk = r.shape
    dv = v.shape[-1]
    s = (jnp.zeros((b, h, dk, dv), jnp.float32) if initial_state is None
         else initial_state.astype(jnp.float32))

    def body(s, xs):
        rt, kt, vt, gt = xs
        o, s = gla_decode(rt, kt, vt, gt, s, u=u, inclusive=inclusive)
        return s, o

    xs = tuple(x.transpose(2, 0, 1, *range(3, x.ndim)) for x in (r, k, v, g))
    s, outs = jax.lax.scan(body, s, xs)
    return outs.transpose(1, 2, 0, 3), s
