"""RWKV-6 (Finch) blocks: data-dependent-decay time-mix via chunked GLA,
plus squared-ReLU channel-mix. Faithful to arXiv:2404.05892 including the
5-way data-dependent token-shift (ddlerp) and the per-channel decay LoRA.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.gla import chunked_gla, gla_decode
from repro.models.layers import dense_init, group_norm_heads

MAA_RANK = 32


def init_time_mix(key, d_model: int, rwkv_cfg, dtype):
    c = d_model
    ks = jax.random.split(key, 10)
    return {
        "mu_x": jnp.zeros((c,), jnp.float32),
        # static lerp weights for w,k,v,r,g
        "mu": jnp.zeros((5, c), jnp.float32),
        "maa_w1": dense_init(ks[0], c, (c, 5 * MAA_RANK), dtype),
        "maa_w2": dense_init(ks[1], MAA_RANK, (5, MAA_RANK, c), dtype),
        "decay_base": jnp.full((c,), -6.0, jnp.float32),   # omega
        "decay_w1": dense_init(ks[2], c, (c, rwkv_cfg.decay_lora), dtype),
        "decay_w2": dense_init(ks[3], rwkv_cfg.decay_lora, (rwkv_cfg.decay_lora, c), dtype),
        "bonus_u": jnp.zeros((c,), jnp.float32),
        "wr": dense_init(ks[4], c, (c, c), dtype),
        "wk": dense_init(ks[5], c, (c, c), dtype),
        "wv": dense_init(ks[6], c, (c, c), dtype),
        "wg": dense_init(ks[7], c, (c, c), dtype),
        "wo": dense_init(ks[8], c, (c, c), dtype),
        "ln_scale": jnp.ones((c,), jnp.float32),
        "ln_bias": jnp.zeros((c,), jnp.float32),
    }


def init_channel_mix(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d_model,), jnp.float32),
        "mu_r": jnp.zeros((d_model,), jnp.float32),
        "wk": dense_init(ks[0], d_model, (d_model, d_ff), dtype),
        "wv": dense_init(ks[1], d_ff, (d_ff, d_model), dtype),
        "wr": dense_init(ks[2], d_model, (d_model, d_model), dtype),
    }


def _shift(x: jax.Array) -> jax.Array:
    """Token shift: x[t] -> x[t-1] (zeros at t=0). x: [B,S,C]."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]


def _ddlerp(p, x: jax.Array, sx: jax.Array):
    """Data-dependent 5-way lerp -> (xw, xk, xv, xr, xg). sx = shift(x) - x."""
    xxx = x + sx * p["mu_x"].astype(x.dtype)
    mm = jnp.tanh(xxx @ p["maa_w1"])                                # [B,S,5R]
    b, s, _ = mm.shape
    mm = mm.reshape(b, s, 5, MAA_RANK)
    mus = jnp.einsum("bsfr,frc->fbsc", mm, p["maa_w2"].astype(mm.dtype))
    outs = []
    for i in range(5):
        w = (p["mu"][i].astype(x.dtype) + mus[i].astype(x.dtype))
        outs.append(x + sx * w)
    return outs  # w, k, v, r, g order


def _decay(p, xw: jax.Array) -> jax.Array:
    """Per-channel log-decay g <= 0: w = exp(-exp(omega + lora(xw)))."""
    lora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    return -jnp.exp(p["decay_base"] + lora.astype(jnp.float32))


def _heads(x, n_heads, hs):
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    return x.reshape(b, s, n_heads, hs).transpose(0, 2, 1, 3)


def apply_time_mix(p, x: jax.Array, *, n_heads: int, rwkv_cfg,
                   chunk=None) -> jax.Array:
    """Train/prefill WKV. x: [B,S,C] -> [B,S,C]."""
    b, s, c = x.shape
    hs = rwkv_cfg.head_size
    sx = _shift(x) - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    g_log = _decay(p, xw)                                           # [B,S,C]
    r = _heads(xr @ p["wr"], n_heads, hs)
    k = _heads(xk @ p["wk"], n_heads, hs)
    v = _heads(xv @ p["wv"], n_heads, hs)
    gate = jax.nn.silu(xg @ p["wg"])
    g = _heads(g_log, n_heads, hs)
    u = p["bonus_u"].reshape(n_heads, hs)
    o, _ = chunked_gla(r, k, v, g, u=u, chunk=chunk or rwkv_cfg.chunk,
                       inclusive=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, c).astype(x.dtype)
    o = group_norm_heads(o, p["ln_scale"], p["ln_bias"], n_heads)
    return (o * gate) @ p["wo"]


def apply_channel_mix(p, x: jax.Array) -> jax.Array:
    sx = _shift(x) - x
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"])


def init_rwkv_cache(batch: int, d_model: int, n_heads: int, rwkv_cfg):
    hs = rwkv_cfg.head_size
    return {
        "tm_x": jnp.zeros((batch, d_model), jnp.float32),   # prev token (time-mix)
        "cm_x": jnp.zeros((batch, d_model), jnp.float32),   # prev token (channel-mix)
        "wkv": jnp.zeros((batch, n_heads, hs, hs), jnp.float32),
    }


def decode_time_mix(p, x: jax.Array, cache, *, n_heads: int, rwkv_cfg
                    ) -> Tuple[jax.Array, dict]:
    """One-token recurrent WKV. x: [B,C]."""
    b, c = x.shape
    hs = rwkv_cfg.head_size
    sx = cache["tm_x"].astype(x.dtype) - x
    x3, sx3 = x[:, None, :], sx[:, None, :]
    xw, xk, xv, xr, xg = _ddlerp(p, x3, sx3)
    g_log = _decay(p, xw)[:, 0]                              # [B,C]
    r = (xr[:, 0] @ p["wr"]).reshape(b, n_heads, hs)
    k = (xk[:, 0] @ p["wk"]).reshape(b, n_heads, hs)
    v = (xv[:, 0] @ p["wv"]).reshape(b, n_heads, hs)
    gate = jax.nn.silu(xg[:, 0] @ p["wg"])
    g = g_log.reshape(b, n_heads, hs)
    u = p["bonus_u"].reshape(n_heads, hs)
    o, wkv = gla_decode(r, k, v, g, cache["wkv"], u=u, inclusive=False)
    o = o.reshape(b, c).astype(x.dtype)
    o = group_norm_heads(o, p["ln_scale"], p["ln_bias"], n_heads)
    out = (o * gate) @ p["wo"]
    return out, {"tm_x": x.astype(jnp.float32), "cm_x": cache["cm_x"], "wkv": wkv}


def decode_channel_mix(p, x: jax.Array, cache) -> Tuple[jax.Array, jax.Array]:
    sx = cache["cm_x"].astype(x.dtype) - x
    xk = x + sx * p["mu_k"].astype(x.dtype)
    xr = x + sx * p["mu_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (h @ p["wv"]), x.astype(jnp.float32)
