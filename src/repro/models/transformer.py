"""Model assembly for all assigned architecture families.

Functional style: ``Model(cfg)`` exposes ``init`` / ``loss_fn`` / ``prefill`` /
``decode_step`` / ``init_cache``. Layer stacks carry a leading ``[L, ...]``
axis and run under ``lax.scan`` (compact HLO, bounded compile time at 61+
layers), with ``jax.checkpoint`` remat for training.

Families: dense (stablelm/yi/qwen), moe (+MLA for deepseek; +MTP), hybrid
(hymba: parallel GQA-SWA + SSD branches), ssm (rwkv6), encdec (whisper),
vlm (llama-3.2-vision: 4-self + 1-cross supergroups).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import flags
from repro.models import attention as attn
from repro.models import mamba, mla, moe, rwkv6
from repro.models.layers import (apply_mlp, cross_entropy, dense_init,
                                 embed_init, embed_lookup, init_mlp,
                                 layer_norm, pad_vocab, rms_norm, _dtype)

Params = Dict[str, Any]


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def sinusoidal_pos(seq: int, d: int, offset=0) -> jax.Array:
    pos = offset + jnp.arange(seq)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dtype(cfg.dtype)
        self.v_pad = pad_vocab(cfg.vocab_size, 256)

    # =================================================================== init
    def init(self, key) -> Params:
        cfg = self.cfg
        d = cfg.d_model
        keys = iter(jax.random.split(key, 64))
        p: Params = {
            "embed": {"w": embed_init(next(keys), (self.v_pad, d), self.dtype)},
            "final_norm": jnp.ones((d,), jnp.float32),
            "lm_head": {"w": dense_init(next(keys), d, (d, self.v_pad), self.dtype)},
        }
        if cfg.family == "ssm":
            p["ln0_s"] = jnp.ones((d,), jnp.float32)
            p["ln0_b"] = jnp.zeros((d,), jnp.float32)
            p["final_norm_b"] = jnp.zeros((d,), jnp.float32)
            p["layers"] = self._init_stack(next(keys), cfg.n_layers, self._init_rwkv_block)
        elif cfg.family == "encdec":
            p["encoder"] = {
                "layers": self._init_stack(next(keys), cfg.encdec.n_enc_layers,
                                           self._init_dense_block),
                "final_norm": jnp.ones((d,), jnp.float32),
            }
            p["layers"] = self._init_stack(next(keys), cfg.n_layers,
                                           self._init_encdec_block)
        elif cfg.family == "vlm":
            v = cfg.vision
            n_groups = v.n_cross_layers
            per = cfg.n_layers // n_groups
            p["vis_proj"] = dense_init(next(keys), v.d_vision, (v.d_vision, d), self.dtype)
            p["groups"] = {
                "self": self._init_stack(next(keys), n_groups * per,
                                         self._init_dense_block,
                                         reshape=(n_groups, per)),
                "cross": self._init_stack(next(keys), n_groups, self._init_cross_block),
            }
        elif cfg.family == "moe":
            nd = cfg.moe.first_dense_layers
            if nd:
                p["dense_layers"] = self._init_stack(next(keys), nd, self._init_dense_block)
            p["moe_layers"] = self._init_stack(next(keys), cfg.n_layers - nd,
                                               self._init_moe_block)
            if cfg.mtp_depth:
                p["mtp"] = {
                    "proj": dense_init(next(keys), 2 * d, (2 * d, d), self.dtype),
                    "norm_h": jnp.ones((d,), jnp.float32),
                    "norm_e": jnp.ones((d,), jnp.float32),
                    "block": self._init_dense_block(next(keys)),
                }
        else:  # dense / hybrid
            p["layers"] = self._init_stack(next(keys), cfg.n_layers,
                                           self._init_block)
        return p

    def _init_stack(self, key, n, init_one, reshape=None):
        ks = jax.random.split(key, n)
        stacked = jax.vmap(init_one)(ks)
        if reshape is not None:
            stacked = jax.tree.map(
                lambda x: x.reshape(reshape + x.shape[1:]), stacked)
        return stacked

    def _init_attn(self, key):
        cfg = self.cfg
        if cfg.mla is not None:
            return mla.init_mla(key, cfg.d_model, cfg.n_heads, cfg.mla, self.dtype)
        return attn.init_attention(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.resolved_head_dim, self.dtype, cfg.qkv_bias)

    def _init_dense_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"attn": self._init_attn(k1),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, self.dtype),
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32)}

    def _init_block(self, key):
        cfg = self.cfg
        p = self._init_dense_block(key)
        if cfg.ssm is not None:  # hymba hybrid: parallel SSM branch
            k = jax.random.fold_in(key, 7)
            p["ssm"] = mamba.init_ssm(k, cfg.d_model, cfg.ssm, self.dtype)
            p["attn_out_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["ssm_out_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        return p

    def _init_moe_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"attn": self._init_attn(k1),
                "moe": moe.init_moe(k2, cfg.d_model, cfg.moe, self.dtype),
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32)}

    def _init_rwkv_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"tm": rwkv6.init_time_mix(k1, cfg.d_model, cfg.rwkv, self.dtype),
                "cm": rwkv6.init_channel_mix(k2, cfg.d_model, cfg.d_ff, self.dtype),
                "ln1_s": jnp.ones((cfg.d_model,), jnp.float32),
                "ln1_b": jnp.zeros((cfg.d_model,), jnp.float32),
                "ln2_s": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2_b": jnp.zeros((cfg.d_model,), jnp.float32)}

    def _init_cross_block(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {"xattn": attn.init_attention(k1, cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.resolved_head_dim,
                                             self.dtype),
                "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.act, self.dtype),
                "gate_attn": jnp.zeros((), jnp.float32),
                "gate_mlp": jnp.zeros((), jnp.float32),
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32)}

    def _init_encdec_block(self, key):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        return {"attn": self._init_attn(k1),
                "xattn": attn.init_attention(k2, cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.resolved_head_dim,
                                             self.dtype),
                "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.act, self.dtype),
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "lnx": jnp.ones((cfg.d_model,), jnp.float32),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32)}

    # ============================================================ train blocks
    def _window_flags(self):
        """Per-layer effective window (int32; S+1 => effectively global)."""
        cfg = self.cfg
        if cfg.window is None:
            return None
        w = jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
        for g in cfg.global_layers:
            w = w.at[g].set(jnp.iinfo(jnp.int32).max // 2)
        return w

    def _block_fwd(self, p, x, positions, window, chunk=512):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            a = mla.apply_mla(p["attn"], h, n_heads=cfg.n_heads, m=cfg.mla,
                              theta=cfg.rope_theta, positions=positions, chunk=chunk)
        else:
            a = attn.self_attention(p["attn"], h, cfg=cfg, positions=positions,
                                    causal=True, window=window, chunk=chunk)
        if cfg.ssm is not None:
            s = mamba.apply_ssm(p["ssm"], h, d_model=cfg.d_model, ssm_cfg=cfg.ssm)
            mix = 0.5 * (rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                         + rms_norm(s, p["ssm_out_norm"], cfg.norm_eps))
            x = x + mix
        else:
            x = x + a
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            mo_out, aux = moe.apply_moe(p["moe"], h2, mo=cfg.moe, act=cfg.act)
            # carry constraint: the layer-scan's saved activation stack is
            # d_model-sharded for FSDP archs (sequence-parallel style)
            return constrain(x + mo_out, ("batch", None, "act_d")), aux
        out = constrain(x + apply_mlp(p["mlp"], h2, cfg.act),
                        ("batch", None, "act_d"))
        return out, jnp.float32(0.0)

    def _rwkv_block_fwd(self, p, x):
        cfg = self.cfg
        h = layer_norm(x, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
        x = x + rwkv6.apply_time_mix(p["tm"], h, n_heads=cfg.n_heads,
                                     rwkv_cfg=cfg.rwkv)
        h = layer_norm(x, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
        return x + rwkv6.apply_channel_mix(p["cm"], h)

    def _cross_block_fwd(self, p, x, memory):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        g_a = jnp.tanh(p["gate_attn"]).astype(x.dtype)
        x = x + g_a * attn.cross_attention(p["xattn"], h, memory, cfg=cfg)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        g_m = jnp.tanh(p["gate_mlp"]).astype(x.dtype)
        return x + g_m * apply_mlp(p["mlp"], h, cfg.act)

    def _encdec_block_fwd(self, p, x, memory, positions):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.self_attention(p["attn"], h, cfg=cfg, positions=positions,
                                    causal=True, rope=False)
        h = rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], h, memory, cfg=cfg)
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + apply_mlp(p["mlp"], h, cfg.act)

    # ============================================================== forward
    def _backbone(self, params, x, positions) -> Tuple[jax.Array, jax.Array]:
        """Token embeddings -> final hidden states. Returns (h, aux_loss)."""
        cfg = self.cfg
        remat = cfg.remat
        aux0 = jnp.float32(0.0)
        if cfg.family == "ssm":
            x = layer_norm(x, params["ln0_s"], params["ln0_b"], cfg.norm_eps)
            body = _remat(lambda h, p: self._rwkv_block_fwd(p, h), remat)
            x, _ = jax.lax.scan(lambda h, p: (body(h, p), None), x,
                                params["layers"],
                                unroll=flags.scan_unroll(cfg.n_layers))
            return x, aux0
        if cfg.family == "moe":
            if "dense_layers" in params:
                # leading dense layers (<=3): unrolled python loop so HLO
                # cost analysis counts them exactly (scan bodies count once)
                body = _remat(lambda h, p: self._block_fwd(
                    p, h, positions, None)[0], remat)
                nd = cfg.moe.first_dense_layers
                for i in range(nd):
                    x = body(x, jax.tree.map(lambda a: a[i],
                                             params["dense_layers"]))
            body2 = _remat(lambda h, p: self._block_fwd(p, h, positions, None), remat)

            def moe_step(carry, p):
                h, aux = carry
                h, a = body2(h, p)
                return (h, aux + a), None

            n_moe = cfg.n_layers - cfg.moe.first_dense_layers
            (x, aux), _ = jax.lax.scan(moe_step, (x, aux0),
                                       params["moe_layers"],
                                       unroll=flags.scan_unroll(n_moe))
            return x, aux
        if cfg.family == "vlm":
            raise RuntimeError("vlm uses _backbone_vlm")
        # dense / hybrid
        wins = self._window_flags()

        def step(h, xs):
            if wins is None:
                p = xs
                return _remat(lambda hh, pp: self._block_fwd(
                    pp, hh, positions, None)[0], remat)(h, p), None
            p, w = xs
            return _remat(lambda hh, pw: self._block_fwd(
                pw[0], hh, positions, pw[1])[0], remat)(h, (p, w)), None

        xs = params["layers"] if wins is None else (params["layers"], wins)
        x, _ = jax.lax.scan(step, x, xs,
                            unroll=flags.scan_unroll(cfg.n_layers))
        return x, aux0

    def _backbone_vlm(self, params, x, vis, positions):
        cfg = self.cfg
        remat = cfg.remat
        self_body = _remat(lambda h, p: self._block_fwd(
            p, h, positions, None)[0], remat)
        cross_body = _remat(lambda h, p: self._cross_block_fwd(p, h, vis), remat)

        per = cfg.n_layers // cfg.vision.n_cross_layers

        def group(h, gp):
            h, _ = jax.lax.scan(lambda hh, p: (self_body(hh, p), None),
                                h, gp["self"], unroll=flags.scan_unroll(per))
            h = cross_body(h, gp["cross"])
            return h, None

        x, _ = jax.lax.scan(group, x, params["groups"],
                            unroll=flags.scan_unroll(cfg.vision.n_cross_layers))
        return x, jnp.float32(0.0)

    def _encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings [B,S,d]."""
        cfg = self.cfg
        b, s, d = frames.shape
        x = frames.astype(self.dtype) + sinusoidal_pos(s, d).astype(self.dtype)
        positions = jnp.arange(s)

        def enc_step(h, p):  # bidirectional: causal=False via direct call
            hh = rms_norm(h, p["ln1"], cfg.norm_eps)
            a = attn.self_attention(p["attn"], hh, cfg=cfg, positions=positions,
                                    causal=False, rope=False)
            h = h + a
            hh = rms_norm(h, p["ln2"], cfg.norm_eps)
            return h + apply_mlp(p["mlp"], hh, cfg.act)

        enc_body = _remat(enc_step, cfg.remat)
        x, _ = jax.lax.scan(lambda h, p: (enc_body(h, p), None),
                            x, params["encoder"]["layers"],
                            unroll=flags.scan_unroll(cfg.encdec.n_enc_layers))
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # ================================================================= losses
    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        x = embed_lookup(params["embed"]["w"], tokens)
        x = constrain(x, ("batch", "seq", "embed"))
        positions = jnp.arange(s)
        aux = jnp.float32(0.0)
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            dec_pos = sinusoidal_pos(s, cfg.d_model).astype(self.dtype)
            x = x + dec_pos
            body = _remat(lambda h, p: self._encdec_block_fwd(
                p, h, memory, positions), cfg.remat)
            x, _ = jax.lax.scan(lambda h, p: (body(h, p), None), x,
                                params["layers"],
                                unroll=flags.scan_unroll(cfg.n_layers))
        elif cfg.family == "vlm":
            vis = batch["patches"].astype(self.dtype) @ params["vis_proj"]
            x, aux = self._backbone_vlm(params, x, vis, positions)
        else:
            x, aux = self._backbone(params, x, positions)
        x = constrain(x, ("batch", "seq", "embed"))
        h_final = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = h_final @ params["lm_head"]["w"]
        logits = constrain(logits, ("batch", "seq", "vocab"))
        ce = cross_entropy(logits, labels, cfg.vocab_size)
        metrics = {"ce": ce}
        loss = ce
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_weight * aux
            metrics["aux"] = aux
        if cfg.mtp_depth and "mtp" in params:
            mtp_loss = self._mtp_loss(params, h_final, tokens, labels, positions)
            loss = loss + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        return loss, metrics

    def _mtp_loss(self, params, h, tokens, labels, positions):
        """DeepSeek MTP: predict t+2 from [h_t ; emb(token_{t+1})]."""
        cfg = self.cfg
        mp = params["mtp"]
        emb_next = embed_lookup(params["embed"]["w"], tokens[:, 1:])
        h_in = jnp.concatenate(
            [rms_norm(h[:, :-1], mp["norm_h"], cfg.norm_eps),
             rms_norm(emb_next, mp["norm_e"], cfg.norm_eps)], axis=-1)
        x = h_in @ mp["proj"]
        x, _ = self._block_fwd(mp["block"], x, positions[:-1], None)
        logits = rms_norm(x, params["final_norm"], cfg.norm_eps) @ params["lm_head"]["w"]
        # labels shifted by one more step: logits[t] predicts labels[t+1]
        return cross_entropy(logits[:, :-1], labels[:, 2:], cfg.vocab_size)

    # ================================================================ caches
    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16) -> Params:
        cfg = self.cfg
        hkv, hd, L = cfg.n_kv_heads, cfg.resolved_head_dim, cfg.n_layers
        if cfg.family == "ssm":
            one = rwkv6.init_rwkv_cache(batch, cfg.d_model, cfg.n_heads, cfg.rwkv)
            return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
        if cfg.mla is not None:
            nd = cfg.moe.first_dense_layers if cfg.moe else 0
            cache = {"mla": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (L,) + x.shape),
                mla.init_mla_cache(batch, seq, cfg.mla, dtype))}
            # dense leading layers still use MLA attention in our impl, so the
            # cache is uniform across all layers.
            return cache
        kv = {"k": jnp.zeros((L, batch, seq, hkv, hd), dtype),
              "v": jnp.zeros((L, batch, seq, hkv, hd), dtype)}
        if cfg.family == "hybrid":
            one = mamba.init_ssm_cache(batch, cfg.d_model, cfg.ssm)
            kv["ssm"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (L,) + x.shape), one)
        if cfg.family == "encdec":
            kv["ck"] = jnp.zeros((L, batch, seq, hkv, hd), dtype)
            kv["cv"] = jnp.zeros((L, batch, seq, hkv, hd), dtype)
        if cfg.family == "vlm":
            v = cfg.vision
            g, per = v.n_cross_layers, cfg.n_layers // v.n_cross_layers
            kv = {"k": jnp.zeros((g, per, batch, seq, hkv, hd), dtype),
                  "v": jnp.zeros((g, per, batch, seq, hkv, hd), dtype),
                  "ck": jnp.zeros((g, batch, v.n_patches, hkv, hd), dtype),
                  "cv": jnp.zeros((g, batch, v.n_patches, hkv, hd), dtype)}
        return kv

    # ================================================================= decode
    def decode_step(self, params, cache, tokens, pos
                    ) -> Tuple[jax.Array, Params]:
        """One-token decode. tokens: [B] int32; pos: scalar int32."""
        cfg = self.cfg
        x = embed_lookup(params["embed"]["w"], tokens)       # [B, d]
        if cfg.family == "ssm":
            x = layer_norm(x, params["ln0_s"], params["ln0_b"], cfg.norm_eps)
            x, new_cache = self._decode_rwkv(params, cache, x)
        elif cfg.mla is not None:
            x, new_cache = self._decode_mla(params, cache, x, pos)
        elif cfg.family == "vlm":
            x, new_cache = self._decode_vlm(params, cache, x, pos)
        elif cfg.family == "encdec":
            x = x + sinusoidal_pos(1, cfg.d_model, offset=pos)[0].astype(x.dtype)
            x, new_cache = self._decode_encdec(params, cache, x, pos)
        else:
            x, new_cache = self._decode_dense(params, cache, x, pos)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = h @ params["lm_head"]["w"]
        logits = constrain(logits, ("batch", "vocab"))
        return logits, new_cache

    def _decode_block(self, p, x, kc, vc, pos, window, ssm_cache=None):
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        a, kc, vc = attn.decode_self_attention(p["attn"], h, kc, vc, pos,
                                               cfg=cfg, window=window)
        new_ssm = None
        if ssm_cache is not None:
            s, new_ssm = mamba.decode_ssm(p["ssm"], h, ssm_cache,
                                          d_model=cfg.d_model, ssm_cfg=cfg.ssm)
            x = x + 0.5 * (rms_norm(a, p["attn_out_norm"], cfg.norm_eps)
                           + rms_norm(s, p["ssm_out_norm"], cfg.norm_eps))
        else:
            x = x + a
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            mo_out, _ = moe.apply_moe(p["moe"], h2[:, None, :], mo=cfg.moe,
                                      act=cfg.act)
            x = x + mo_out[:, 0]
        else:
            x = x + apply_mlp(p["mlp"], h2, cfg.act)
        return x, kc, vc, new_ssm

    def _decode_dense(self, params, cache, x, pos):
        cfg = self.cfg
        if cfg.family == "moe":  # GQA MoE (kimi): split dense/moe layer groups
            return self._decode_moe_gqa(params, cache, x, pos)
        wins = self._window_flags()
        hybrid = cfg.family == "hybrid"

        def body(h, xs):
            if hybrid:
                p, kc, vc, sc, w = xs
                h, kc, vc, sc = self._decode_block(p, h, kc, vc, pos, w, sc)
                return h, (kc, vc, sc)
            if wins is not None:
                p, kc, vc, w = xs
                h, kc, vc, _ = self._decode_block(p, h, kc, vc, pos, w)
                return h, (kc, vc)
            p, kc, vc = xs
            h, kc, vc, _ = self._decode_block(p, h, kc, vc, pos, None)
            return h, (kc, vc)

        unr = flags.scan_unroll(cfg.n_layers)
        if hybrid:
            xs = (params["layers"], cache["k"], cache["v"], cache["ssm"], wins)
            x, (k, v, sc) = jax.lax.scan(body, x, xs, unroll=unr)
            return x, {"k": k, "v": v, "ssm": sc}
        if wins is not None:
            xs = (params["layers"], cache["k"], cache["v"], wins)
            x, (k, v) = jax.lax.scan(body, x, xs, unroll=unr)
            return x, {"k": k, "v": v}
        xs = (params["layers"], cache["k"], cache["v"])
        x, (k, v) = jax.lax.scan(body, x, xs, unroll=unr)
        return x, {"k": k, "v": v}

    def _decode_moe_gqa(self, params, cache, x, pos):
        cfg = self.cfg
        nd = cfg.moe.first_dense_layers

        def body_dense(h, xs):
            p, kc, vc = xs
            h, kc, vc, _ = self._decode_block(p, h, kc, vc, pos, None)
            return h, (kc, vc)

        def body_moe(h, xs):
            p, kc, vc = xs
            h, kc, vc, _ = self._decode_block(p, h, kc, vc, pos, None)
            return h, (kc, vc)

        ks, vs = cache["k"], cache["v"]
        if nd and "dense_layers" in params:
            kds, vds = [], []
            for i in range(nd):  # unrolled (see _backbone)
                p_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
                x, (kd, vd) = body_dense(x, (p_i, ks[i], vs[i]))
                kds.append(kd)
                vds.append(vd)
        x, (km, vm) = jax.lax.scan(
            body_moe, x, (params["moe_layers"], ks[nd:], vs[nd:]),
            unroll=flags.scan_unroll(cfg.n_layers - nd))
        if nd and "dense_layers" in params:
            k = jnp.concatenate([jnp.stack(kds), km], axis=0)
            v = jnp.concatenate([jnp.stack(vds), vm], axis=0)
        else:
            k, v = km, vm
        return x, {"k": k, "v": v}

    def _decode_mla(self, params, cache, x, pos):
        cfg = self.cfg
        nd = cfg.moe.first_dense_layers if cfg.moe else 0

        def make_body(use_moe):
            def body(h, xs):
                p, c = xs
                hh = rms_norm(h, p["ln1"], cfg.norm_eps)
                a, c = mla.decode_mla(p["attn"], hh, c, pos, n_heads=cfg.n_heads,
                                      m=cfg.mla, theta=cfg.rope_theta)
                h = h + a
                h2 = rms_norm(h, p["ln2"], cfg.norm_eps)
                if use_moe:
                    mo_out, _ = moe.apply_moe(p["moe"], h2[:, None, :],
                                              mo=cfg.moe, act=cfg.act)
                    h = h + mo_out[:, 0]
                else:
                    h = h + apply_mlp(p["mlp"], h2, cfg.act)
                return h, c
            return body

        mc = cache["mla"]
        sub = lambda lo, hi: jax.tree.map(lambda a: a[lo:hi], mc)
        outs = []
        if nd and "dense_layers" in params:
            body_d = make_body(False)
            cs = []
            for i in range(nd):  # unrolled (see _backbone)
                p_i = jax.tree.map(lambda a: a[i], params["dense_layers"])
                c_i = jax.tree.map(lambda a: a[i], mc)
                x, c_i = body_d(x, (p_i, c_i))
                cs.append(c_i)
            outs.append(jax.tree.map(lambda *xs: jnp.stack(xs), *cs))
        x, c2 = jax.lax.scan(make_body(True), x,
                             (params["moe_layers"], sub(nd, cfg.n_layers)),
                             unroll=flags.scan_unroll(cfg.n_layers - nd))
        outs.append(c2)
        new = (jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *outs)
               if len(outs) > 1 else outs[0])
        return x, {"mla": new}

    def _decode_rwkv(self, params, cache, x):
        cfg = self.cfg

        def body(h, xs):
            p, c = xs
            hh = layer_norm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
            tm_out, c_tm = rwkv6.decode_time_mix(p["tm"], hh, c,
                                                 n_heads=cfg.n_heads,
                                                 rwkv_cfg=cfg.rwkv)
            h = h + tm_out
            hh = layer_norm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
            cm_out, cm_x = rwkv6.decode_channel_mix(p["cm"], hh, c)
            h = h + cm_out
            new_c = {"tm_x": c_tm["tm_x"], "cm_x": cm_x, "wkv": c_tm["wkv"]}
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                    unroll=flags.scan_unroll(cfg.n_layers))
        return x, new_cache

    def _decode_vlm(self, params, cache, x, pos):
        cfg = self.cfg

        def group(h, xs):
            gp, kc, vc, ck, cv = xs

            def self_body(hh, ys):
                p, k1, v1 = ys
                hh, k1, v1, _ = self._decode_block(p, hh, k1, v1, pos, None)
                return hh, (k1, v1)

            per = cfg.n_layers // cfg.vision.n_cross_layers
            h, (kc, vc) = jax.lax.scan(self_body, h, (gp["self"], kc, vc),
                                       unroll=flags.scan_unroll(per))
            p = gp["cross"]
            hh = rms_norm(h, p["ln1"], cfg.norm_eps)
            a = attn.decode_cross_attention(p["xattn"], hh, ck, cv, cfg=cfg)
            h = h + jnp.tanh(p["gate_attn"]).astype(h.dtype) * a
            hh = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + jnp.tanh(p["gate_mlp"]).astype(h.dtype) * apply_mlp(
                p["mlp"], hh, cfg.act)
            return h, (kc, vc)

        xs = (params["groups"], cache["k"], cache["v"], cache["ck"], cache["cv"])
        x, (k, v) = jax.lax.scan(
            group, x, xs,
            unroll=flags.scan_unroll(cfg.vision.n_cross_layers))
        return x, {"k": k, "v": v, "ck": cache["ck"], "cv": cache["cv"]}

    def _decode_encdec(self, params, cache, x, pos):
        cfg = self.cfg

        def body(h, xs):
            p, kc, vc, ck, cv = xs
            hh = rms_norm(h, p["ln1"], cfg.norm_eps)
            a, kc, vc = attn.decode_self_attention(p["attn"], hh, kc, vc, pos,
                                                   cfg=cfg, rope=False)
            h = h + a
            hh = rms_norm(h, p["lnx"], cfg.norm_eps)
            h = h + attn.decode_cross_attention(p["xattn"], hh, ck, cv, cfg=cfg)
            hh = rms_norm(h, p["ln2"], cfg.norm_eps)
            h = h + apply_mlp(p["mlp"], hh, cfg.act)
            return h, (kc, vc)

        xs = (params["layers"], cache["k"], cache["v"], cache["ck"], cache["cv"])
        x, (k, v) = jax.lax.scan(body, x, xs,
                                 unroll=flags.scan_unroll(cfg.n_layers))
        return x, {"k": k, "v": v, "ck": cache["ck"], "cv": cache["cv"]}

    # ================================================================ prefill
    def prefill(self, params, batch) -> Tuple[jax.Array, Params]:
        """Forward over the prompt, returning (last-token logits, filled cache).

        For the dry-run roofline the cost is dominated by the forward pass;
        cache fill is included for attention families.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed_lookup(params["embed"]["w"], tokens)
        x = constrain(x, ("batch", "seq", "embed"))
        positions = jnp.arange(s)
        if cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
            x = x + sinusoidal_pos(s, cfg.d_model).astype(self.dtype)
            body = _remat(lambda h, p: self._encdec_block_fwd(
                p, h, memory, positions), "none")
            x, _ = jax.lax.scan(lambda h, p: (body(h, p), None), x,
                                params["layers"],
                                unroll=flags.scan_unroll(cfg.n_layers))
        elif cfg.family == "vlm":
            vis = batch["patches"].astype(self.dtype) @ params["vis_proj"]
            x, _ = self._backbone_vlm(params, x, vis, positions)
        else:
            x, _ = self._backbone(params, x, positions)
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits_last = h[:, -1, :] @ params["lm_head"]["w"]
        return logits_last, None
