"""Core layers: norms, MLPs, embeddings, RoPE, losses.

Params are plain nested dicts of jnp arrays (functional style). Layer-stacked
groups carry a leading ``[L, ...]`` axis consumed by ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ---------------------------------------------------------------- init helpers
def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def group_norm_heads(x: jax.Array, scale: jax.Array, bias: jax.Array,
                     n_heads: int, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over each head's channels (RWKV wkv output norm). x: [..., C]."""
    shp = x.shape
    xh = x.reshape(shp[:-1] + (n_heads, shp[-1] // n_heads)).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.mean((xh - mu) ** 2, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(shp) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- matmul
def mm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Matmul whose HLO dot emits the input dtype directly.

    For bf16 operands JAX's default keeps an f32 accumulation type on the
    dot, so the SPMD partitioner's partial-sum all-reduce moves f32 — 2× the
    necessary wire bytes on every TP-contracted matmul (w_down, wo, ...).
    preferred_element_type=bf16 makes the all-reduce bf16 (TPU MXU still
    accumulates f32 internally). §Perf iteration 1.
    """
    if a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16:
        return jnp.matmul(a, b, preferred_element_type=jnp.bfloat16)
    return a @ b


# ----------------------------------------------------------------------- mlp
def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], d_ff, (d_ff, d_model), dtype)}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], d_model, (d_model, d_ff), dtype)
    return p


def apply_mlp(p, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return mm(h, p["w_down"])


# ----------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Half-rotation RoPE. x: [..., S, H, D] or [..., H, D]; positions
    broadcastable to the S axis (or scalar for single-token decode)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    if x.ndim == angles.ndim + 2:                      # add head axis
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ embedding
def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token embedding lookup.

    Under a mesh rules env this is a one-hot einsum (bf16) rather than a
    gather: XLA partitions the contraction over the sharded vocab/d dims
    cleanly (FSDP-style weight all-gather), whereas gather-from-sharded-table
    lowers to partial-gather + a full [tokens, d] f32 all-reduce — and its
    *backward* to an even costlier scatter (§Perf iteration 2/3). Single
    device keeps the plain take.
    """
    from repro.dist.sharding import constrain, get_rules
    if get_rules() is None:
        return jnp.take(table, tokens, axis=0)
    onehot = jax.nn.one_hot(tokens, table.shape[0], dtype=table.dtype)
    # align the one-hot's V dim with the table's vocab sharding: the
    # contraction stays shard-local and only [tokens, d] partials reduce
    onehot = constrain(onehot, ("batch",) + (None,) * (onehot.ndim - 2)
                       + ("vocab",))
    return jnp.einsum("...v,vd->...d", onehot, table)


# ----------------------------------------------------------------------- loss
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab_logical: int) -> jax.Array:
    """Mean next-token CE, safe for vocab-padded + vocab-sharded logits.

    The one-hot is built from an iota compare (elementwise, fuses shard-local;
    no gather across the sharded vocab axis).
    """
    v_pad = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if v_pad != vocab_logical:
        valid = jnp.arange(v_pad) < vocab_logical
        lf = jnp.where(valid, lf, -1e9)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = labels[..., None] == jnp.arange(v_pad, dtype=labels.dtype)
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    return jnp.mean(lse - label_logit)


def pad_vocab(vocab: int, multiple: int = 512) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple
