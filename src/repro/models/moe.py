"""Routed MoE with capacity-based, *data-shard-local* dispatch.

TPU adaptation: tokens are reshaped to [n_shards, T_loc, d] with the leading
axis sharded over the batch mesh axes, and ALL routing (top-k, position
cumsum, scatter into the [E, C_loc, d] dispatch buffer) happens per shard
under vmap — no cross-shard sequentialization, no giant global scatter (the
naive global formulation replicates [T·k, d] f32 buffers per device; see
EXPERIMENTS.md §Perf). Expert FFNs run as one batched einsum with experts
sharded over ``model`` (EP); capacity is enforced per shard (GShard-style
local capacity). Shared experts are a plain dense branch. Aux load-balance
loss follows Switch/GShard.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import flags
from repro.dist.sharding import constrain
from repro.models.layers import dense_init


def init_moe(key, d_model: int, mo, dtype):
    ks = jax.random.split(key, 8)
    p = {
        "router": dense_init(ks[0], d_model, (d_model, mo.n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], d_model, (mo.n_experts, d_model, mo.d_expert), dtype),
        "w_up": dense_init(ks[2], d_model, (mo.n_experts, d_model, mo.d_expert), dtype),
        "w_down": dense_init(ks[3], mo.d_expert, (mo.n_experts, mo.d_expert, d_model), dtype),
    }
    if mo.n_shared:
        ds = (mo.d_shared or mo.d_expert) * mo.n_shared
        p["shared"] = {
            "w_gate": dense_init(ks[4], d_model, (d_model, ds), dtype),
            "w_up": dense_init(ks[5], d_model, (d_model, ds), dtype),
            "w_down": dense_init(ks[6], ds, (ds, d_model), dtype),
        }
    return p


def _dispatch_positions(expert_idx: jax.Array, n_experts: int, capacity: int):
    """expert_idx: [T, k] -> (positions [T, k], keep [T, k]).

    Slot-sequential running count: for each of the k routing slots, a [T, E]
    one-hot cumsum assigns intra-expert positions; a carried per-expert base
    count links the slots. Peak temp is [T, E] i32 (not [T*k, E])."""
    t, k = expert_idx.shape

    def body(counts, idx_col):
        onehot = jax.nn.one_hot(idx_col, n_experts, dtype=jnp.int32)  # [T, E]
        ranks = jnp.cumsum(onehot, axis=0) - 1                        # 0-based
        pos = jnp.take_along_axis(ranks, idx_col[:, None], axis=1)[:, 0] + \
            counts[idx_col]
        new_counts = counts + jnp.sum(onehot, axis=0)
        return new_counts, pos

    counts0 = jnp.zeros((n_experts,), jnp.int32)
    _, pos = jax.lax.scan(body, counts0, expert_idx.T,
                          unroll=flags.scan_unroll(k))
    pos = pos.T                                                       # [T, k]
    keep = pos < capacity
    return pos, keep


def _local_moe(p, xt: jax.Array, mo, act: str, capacity: int):
    """One shard's routing + dispatch. xt: [T_loc, d] ->
    (disp [E, C, d], combine [T_loc, k], ei [T_loc, k], pi [T_loc, k], aux)."""
    tl, d = xt.shape
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                           # [T, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, mo.top_k)            # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    pos, keep = _dispatch_positions(expert_idx, mo.n_experts, capacity)

    disp = jnp.zeros((mo.n_experts, capacity, d), xt.dtype)
    ei = expert_idx.reshape(-1)
    pi = jnp.where(keep, pos, capacity - 1).reshape(-1)
    xr = jnp.repeat(xt[:, None, :], mo.top_k, axis=1).reshape(-1, d)
    xr = xr * keep.reshape(-1, 1).astype(xt.dtype)
    disp = disp.at[ei, pi].add(xr)

    combine = (gate_vals * keep).astype(xt.dtype)                     # [T, k]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], mo.n_experts,
                                 dtype=jnp.float32), axis=0)
    aux = mo.n_experts * jnp.sum(me * ce)
    return disp, combine, expert_idx, jnp.where(keep, pos, capacity - 1), aux


def apply_moe(p, x: jax.Array, *, mo, act: str = "swiglu"
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    rules = shd.get_rules()
    n_shards = 1
    if rules is not None and rules.shard_batch:
        n_shards = rules.batch_size()
        if t % n_shards or t // n_shards < mo.top_k:
            n_shards = 1
    tl = t // n_shards
    capacity = max(int(mo.capacity_factor * tl * mo.top_k / mo.n_experts),
                   mo.top_k)

    xt = x.reshape(n_shards, tl, d)
    xt = constrain(xt, ("batch", None, None))
    disp, combine, ei, pi, aux = jax.vmap(
        lambda xs: _local_moe(p, xs, mo, act, capacity))(xt)
    # disp: [n_shards, E, C, d] — data-sharded on dim0, EP on dim1
    disp = constrain(disp, ("batch", "experts", None, None))

    pet = dict(preferred_element_type=x.dtype) if x.dtype == jnp.bfloat16 \
        else {}
    up = jnp.einsum("secd,edf->secf", disp, p["w_up"], **pet)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("secd,edf->secf", disp, p["w_gate"],
                                   **pet)) * up
    else:
        h = jax.nn.gelu(up)
    y = jnp.einsum("secf,efd->secd", h, p["w_down"], **pet)  # [S, E, C, d]
    y = constrain(y, ("batch", "experts", None, None))

    # gather back per shard and combine with gates
    def gather_shard(ys, eis, pis, cs):
        yk = ys[eis.reshape(-1), pis.reshape(-1)].reshape(tl, mo.top_k, d)
        return jnp.einsum("tkd,tk->td", yk, cs)

    out = jax.vmap(gather_shard)(y, ei, pi, combine)     # [S, T_loc, d]
    out = constrain(out, ("batch", None, None)).reshape(b, s, d)

    if "shared" in p:
        sh = p["shared"]
        xt2 = x.reshape(t, d)
        su = xt2 @ sh["w_up"]
        if act == "swiglu":
            hh = jax.nn.silu(xt2 @ sh["w_gate"]) * su
        else:
            hh = jax.nn.gelu(su)
        out = out + (hh @ sh["w_down"]).reshape(b, s, d)

    return out, jnp.mean(aux)
