"""SSM branch for Hymba blocks — Mamba-2/SSD-style selective state space,
chunked for the MXU (docs/DESIGN.md §2: GPU sequential selective-scan adapted to a
chunked matmul recurrence; state size stays at the assigned 16).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.gla import chunked_gla, gla_decode
from repro.models.layers import dense_init, rms_norm

CONV_WIDTH = 4


def init_ssm(key, d_model: int, ssm_cfg, dtype):
    di = ssm_cfg.expand * d_model
    nh = di // ssm_cfg.head_dim
    n = ssm_cfg.state_size
    ks = jax.random.split(key, 4)
    return {
        # z (gate, di) | x (di) | B (n) | C (n) | dt (nh)
        "in_proj": dense_init(ks[0], d_model, (d_model, 2 * di + 2 * n + nh), dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_WIDTH, di + 2 * n), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),       # A = -exp(a_log)
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, (di, d_model), dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: [B,S,C]; w: [W,C]. y[t] = sum_k w[k] * x[t - (W-1) + k] + b."""
    out = jnp.zeros_like(x)
    for k in range(CONV_WIDTH):
        shift = CONV_WIDTH - 1 - k
        xk = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + xk * w[k]
    return jax.nn.silu(out + b)


def _split_proj(p, proj, d_model, ssm_cfg):
    di = ssm_cfg.expand * d_model
    n = ssm_cfg.state_size
    nh = di // ssm_cfg.head_dim
    z = proj[..., :di]
    xbc = proj[..., di: 2 * di + 2 * n]
    dt_raw = proj[..., 2 * di + 2 * n:]
    return z, xbc, dt_raw, di, n, nh


def apply_ssm(p, x: jax.Array, *, d_model: int, ssm_cfg) -> jax.Array:
    """Training/prefill SSM branch. x: [B,S,d] -> [B,S,d]."""
    bsz, s, _ = x.shape
    hd = ssm_cfg.head_dim
    z, xbc, dt_raw, di, n, nh = _split_proj(p, x @ p["in_proj"], d_model, ssm_cfg)
    xbc = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di]
    bmat = xbc[..., di: di + n]
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,S,nh]
    a = -jnp.exp(p["a_log"])                                          # [nh]
    g = (dt * a).transpose(0, 2, 1)                                   # [B,nh,S]
    # SSD: k=B (shared across heads), v = dt * x, q=C
    k = jnp.broadcast_to(bmat[:, None, :, :], (bsz, nh, s, n))
    q = jnp.broadcast_to(cmat[:, None, :, :], (bsz, nh, s, n))
    v = (xs.reshape(bsz, s, nh, hd) * dt[..., None]).transpose(0, 2, 1, 3)
    o, _ = chunked_gla(q, k, v, g, chunk=ssm_cfg.chunk, inclusive=True)
    o = o + p["d_skip"][None, :, None, None] * xs.reshape(bsz, s, nh, hd).transpose(0, 2, 1, 3)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, s, di).astype(x.dtype)
    o = rms_norm(o * jax.nn.silu(z), p["norm_scale"])
    return o @ p["out_proj"]


def init_ssm_cache(batch: int, d_model: int, ssm_cfg, dtype=jnp.float32):
    di = ssm_cfg.expand * d_model
    nh = di // ssm_cfg.head_dim
    n = ssm_cfg.state_size
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, di + 2 * n), dtype),
        "state": jnp.zeros((batch, nh, n, ssm_cfg.head_dim), jnp.float32),
    }


def decode_ssm(p, x: jax.Array, cache, *, d_model: int, ssm_cfg) -> Tuple[jax.Array, dict]:
    """One-token SSM step. x: [B,d]. Returns (out [B,d], new cache)."""
    bsz = x.shape[0]
    hd = ssm_cfg.head_dim
    z, xbc, dt_raw, di, n, nh = _split_proj(p, x @ p["in_proj"], d_model, ssm_cfg)
    # conv over [cache, current]
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B,W,C]
    y = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc_c = jax.nn.silu(y).astype(x.dtype)
    new_conv = hist[:, 1:, :]
    xs = xbc_c[..., :di]
    bmat = xbc_c[..., di: di + n]
    cmat = xbc_c[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # [B,nh]
    a = -jnp.exp(p["a_log"])
    g = dt * a                                                        # [B,nh]
    k = jnp.broadcast_to(bmat[:, None, :], (bsz, nh, n))
    q = jnp.broadcast_to(cmat[:, None, :], (bsz, nh, n))
    v = xs.reshape(bsz, nh, hd) * dt[..., None]
    o, state = gla_decode(q, k, v, g, cache["state"], inclusive=True)
    o = o + p["d_skip"][None, :, None] * xs.reshape(bsz, nh, hd)
    o = o.reshape(bsz, di).astype(x.dtype)
    o = rms_norm(o * jax.nn.silu(z), p["norm_scale"])
    return o @ p["out_proj"], {"conv": new_conv, "state": state}
