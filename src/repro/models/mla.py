"""Multi-head Latent Attention (DeepSeek-V2/V3). Training uses the expanded
form; decode uses the absorbed form with the compressed latent KV cache —
the whole point of MLA for serving (cache = kv_lora_rank + rope_dim per token).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attend
from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e9


def init_mla(key, d_model: int, n_heads: int, m, dtype):
    ks = jax.random.split(key, 7)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], d_model, (d_model, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": dense_init(ks[1], m.q_lora_rank, (m.q_lora_rank, n_heads * qk), dtype),
        "wkv_a": dense_init(ks[2], d_model,
                            (d_model, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wk_b": dense_init(ks[3], m.kv_lora_rank,
                           (m.kv_lora_rank, n_heads * m.qk_nope_head_dim), dtype),
        "wv_b": dense_init(ks[4], m.kv_lora_rank,
                           (m.kv_lora_rank, n_heads * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], n_heads * m.v_head_dim,
                         (n_heads * m.v_head_dim, d_model), dtype),
    }


def _project_q(p, x, n_heads, m, theta, positions):
    b = x.shape[0]
    s = x.shape[1] if x.ndim == 3 else 1
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = rms_norm(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(b, s, n_heads, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta)
    return q_nope, q_rope


def _project_kv_latent(p, x, m, theta, positions):
    ckv = x @ p["wkv_a"]
    c, k_rope = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c = rms_norm(c, p["kv_norm"])
    # shared single-head rope key
    k_rope = apply_rope(k_rope[..., None, :], positions, theta)[..., 0, :]
    return c, k_rope


def apply_mla(p, x: jax.Array, *, n_heads: int, m, theta: float,
              positions, chunk: int = 512) -> jax.Array:
    """Training/prefill expanded MLA. x: [B,S,d]."""
    b, s, _ = x.shape
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope = _project_q(p, x, n_heads, m, theta, positions)
    c, k_rope = _project_kv_latent(p, x, m, theta, positions)
    k_nope = (c @ p["wk_b"]).reshape(b, s, n_heads, dn)
    v = (c @ p["wv_b"]).reshape(b, s, n_heads, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :], (b, s, n_heads, dr))],
                        axis=-1)
    # pad v up to qk dim for the shared attend() then slice back
    out = attend(q, k, v, causal=True, chunk=chunk)
    return out.reshape(b, s, n_heads * dv) @ p["wo"]


def init_mla_cache(batch: int, seq: int, m, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq, m.qk_rope_head_dim), dtype),
    }


def decode_mla(p, x: jax.Array, cache, pos, *, n_heads: int, m,
               theta: float) -> Tuple[jax.Array, dict]:
    """Absorbed-form one-token decode against the latent cache. x: [B,d]."""
    b, d = x.shape
    dn, dr, dv, dc = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                      m.v_head_dim, m.kv_lora_rank)
    posa = jnp.full((1,), pos)
    q_nope, q_rope = _project_q(p, x[:, None, :], n_heads, m, theta, posa)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]          # [B,H,dn], [B,H,dr]
    c_new, k_rope_new = _project_kv_latent(p, x[:, None, :], m, theta, posa)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), pos, axis=1)
    # absorb W_UK into q: q_c [B,H,dc]
    wk_b = p["wk_b"].reshape(dc, n_heads, dn)
    q_c = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32),
                     wk_b.astype(jnp.float32))
    scores = (jnp.einsum("bhc,bsc->bhs", q_c, c_kv.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32)))
    scores *= 1.0 / jnp.sqrt(jnp.asarray(dn + dr, jnp.float32))
    s = c_kv.shape[1]
    valid = jnp.arange(s) <= pos
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx_c = jnp.einsum("bhs,bsc->bhc", w, c_kv.astype(jnp.float32))  # [B,H,dc]
    wv_b = p["wv_b"].reshape(dc, n_heads, dv)
    ctx = jnp.einsum("bhc,chv->bhv", ctx_c, wv_b.astype(jnp.float32))
    out = ctx.reshape(b, n_heads * dv).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
