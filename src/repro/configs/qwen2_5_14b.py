"""qwen2.5-14b — GQA, QKV bias [hf:Qwen/Qwen2.5-14B].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2.5-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1000000.0,
        source="hf:Qwen/Qwen2.5-14B",
    )
