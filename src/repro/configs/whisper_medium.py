"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L encoder + 24L decoder, d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865,
GELU MLPs, learned absolute positions. The conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings [B, S, d_model].
"""
from repro.configs.base import EncDecConfig, ModelConfig

ARCH_ID = "whisper-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="encdec",
        n_layers=24,                     # decoder layers
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        act="gelu",
        encdec=EncDecConfig(n_enc_layers=24, max_target_len=448),
        source="arXiv:2212.04356",
    )
