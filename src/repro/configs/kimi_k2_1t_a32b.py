"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8, per the assignment table) expert_d_ff=2048
vocab=163840, MoE 384 routed top-8 + 1 shared, first layer dense.
NOTE: the public K2 uses MLA; the assignment table specifies GQA kv=8 and we
follow the assignment exactly (see docs/DESIGN.md §5).
"""
from repro.configs.base import MoEConfig, ModelConfig

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=18432,                      # dense-FFN first layer
        vocab_size=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048,
                      n_shared=1, d_shared=2048, first_dense_layers=1,
                      capacity_factor=1.25),
        rope_theta=50000.0,
        source="arXiv:2501.kimi2 (assignment table)",
    )
