"""stablelm-1.6b — dense [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "stablelm-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab_size=100352,
        rope_theta=10000.0,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
