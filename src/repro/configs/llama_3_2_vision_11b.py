"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

40L total = 32 self-attn + 8 gated cross-attn (every 5th), d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256. Vision tower is a STUB: ``input_specs``
provides precomputed patch embeddings (assignment rule).
"""
from repro.configs.base import ModelConfig, VisionConfig

ARCH_ID = "llama-3.2-vision-11b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=32,                     # self-attn blocks; +8 cross => 40L total
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        vision=VisionConfig(n_cross_layers=8, interval=5, n_patches=1024, d_vision=1280),
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
