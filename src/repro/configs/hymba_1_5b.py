"""hymba-1.5b — hybrid parallel attention + mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (1024) everywhere except first/middle/last layers
(full attention), per the Hymba paper; every block carries a parallel SSM
branch (chunked-SSD adaptation, see docs/DESIGN.md §2).
"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        window=1024,
        global_layers=(0, 15, 31),
        ssm=SSMConfig(state_size=16, expand=2, head_dim=64, chunk=128),
        rope_theta=10000.0,
        source="arXiv:2411.13676",
    )
