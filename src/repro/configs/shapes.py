"""Assigned input-shape set for the LM-family architectures.

``train_*`` lowers ``train_step``; ``prefill_*`` lowers a forward prefill;
``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV cache
of ``seq_len``). ``long_500k`` requires sub-quadratic attention and is skipped
(with a recorded reason) for pure full-attention archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig

SHAPES = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="long_decode"),
}

SHAPE_NAMES = tuple(SHAPES)


def applicability(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, Optional[str]]:
    """(runnable, skip_reason). Skips follow the assignment rules."""
    if shape.kind == "long_decode" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 524k-token decode needs "
                       "sub-quadratic attention (assignment rule; see docs/DESIGN.md)")
    return True, None
