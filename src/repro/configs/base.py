"""Config dataclasses for models, shapes, and runtime.

Every assigned architecture gets a module ``src/repro/configs/<id>.py`` exposing
``config() -> ModelConfig`` with the exact published numbers, plus
``ModelConfig.reduced()`` for CPU smoke tests (same family, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    d_shared: int = 0             # shared expert hidden dim (0 -> d_expert)
    first_dense_layers: int = 0   # leading layers that use a dense FFN instead
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Chunked-SSD style SSM branch (hymba) — per-head scalar decay, state=16."""
    state_size: int = 16
    expand: int = 2               # d_inner = expand * d_model
    head_dim: int = 64            # SSD head dim
    chunk: int = 128


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64          # rank of the data-dependent decay LoRA
    gate_lora: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 24
    max_target_len: int = 448     # informational; decode shapes override


@dataclass(frozen=True)
class VisionConfig:
    n_cross_layers: int = 8       # gated cross-attn layers, every `interval` blocks
    interval: int = 5             # one cross layer per `interval` self layers
    n_patches: int = 1024         # stub frontend: precomputed patch embeddings
    d_vision: int = 1280


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"           # swiglu | gelu
    # sliding-window hybrid attention (hymba): window size; layers in
    # `global_layers` use full attention.
    window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionConfig] = None
    mtp_depth: int = 0            # deepseek multi-token-prediction extra layers
    dtype: str = "bfloat16"       # params/activations dtype for full-scale runs
    # distribution hints
    fsdp_threshold: int = 8_000_000_000  # params >= threshold -> FSDP over data
    remat: str = "full"           # full | dots | none
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attn_free(self) -> bool:
        return self.rwkv is not None

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM / linear / SWA-hybrid)."""
        return self.rwkv is not None or (self.ssm is not None and self.window is not None)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d  # lm head
        for layer in range(L):
            # attention
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                n += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                n += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                n += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                n += self.n_heads * m.v_head_dim * d
            elif self.rwkv is None:
                n += d * self.n_heads * hd          # q
                n += 2 * d * self.n_kv_heads * hd   # k, v
                n += self.n_heads * hd * d          # o
            # ffn / moe (rwkv counts its channel-mix separately below)
            if self.moe is not None and layer >= self.moe.first_dense_layers:
                mo = self.moe
                n += d * mo.n_experts                       # router
                n += mo.n_experts * 3 * d * mo.d_expert     # routed experts
                ds = mo.d_shared or mo.d_expert
                n += mo.n_shared * 3 * d * ds               # shared experts
            elif self.rwkv is None:
                mult = 3 if self.act == "swiglu" else 2
                n += mult * d * self.d_ff
            # ssm branch
            if self.ssm is not None:
                di = self.ssm.expand * d
                n += d * 2 * di + di * d + di * 2 * self.ssm.state_size + 2 * di
            if self.rwkv is not None:
                # time-mix r,k,v,g,o + decay lora + channel-mix
                n += 5 * d * d + 2 * d * self.rwkv.decay_lora
                n += d * self.d_ff + self.d_ff * d + d * d
            n += 2 * d  # norms
        if self.encdec is not None:
            e = self.encdec
            for _ in range(e.n_enc_layers):
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
                n += (3 if self.act == "swiglu" else 2) * d * self.d_ff + 2 * d
            # decoder cross-attn
            n += L * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d + d)
        if self.vision is not None:
            v = self.vision
            n += v.d_vision * d  # projector
            n += v.n_cross_layers * (2 * (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                                          + self.n_heads * hd * d) // 2 + 3 * d * self.d_ff + 2 * d)
        return n

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        mo = self.moe
        dense_expert_params = mo.n_experts * 3 * self.d_model * mo.d_expert
        active_expert_params = mo.top_k * 3 * self.d_model * mo.d_expert
        n_moe_layers = self.n_layers - mo.first_dense_layers
        return self.n_params() - n_moe_layers * (dense_expert_params - active_expert_params)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            dtype="float32",
            remat="none",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                  n_shared=self.moe.n_shared, d_shared=32,
                                  first_dense_layers=min(1, self.moe.first_dense_layers),
                                  capacity_factor=2.0)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(state_size=4, expand=2, head_dim=16, chunk=16)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_size=16, decay_lora=8, gate_lora=8, chunk=16)
            kw["n_heads"] = 4
            kw["n_kv_heads"] = 4
        if self.encdec is not None:
            kw["encdec"] = EncDecConfig(n_enc_layers=2, max_target_len=32)
        if self.vision is not None:
            kw["vision"] = VisionConfig(n_cross_layers=1, interval=2, n_patches=8, d_vision=32)
        if self.window is not None:
            kw["window"] = 8
            kw["global_layers"] = (0,)
        if self.mtp_depth:
            kw["mtp_depth"] = 1
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


@dataclass(frozen=True)
class RunConfig:
    """Training/serving runtime knobs."""
    optimizer: str = "sgd"        # sgd | momentum | adamw
    learning_rate: float = 1e-2
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    # compression (paper technique, applied to DP/pod gradient sync or FL updates)
    compression: str = "none"     # none | topk | eftopk | randk
    compression_ratio: float = 0.1
    bcrs: bool = False
    opwa: bool = False
    opwa_gamma: float = 5.0
    opwa_overlap_threshold: int = 1
    server_lr: float = 1.0        # alpha
    block_size: int = 8192        # block top-k block size
    # checkpointing
    checkpoint_dir: str = ""
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
