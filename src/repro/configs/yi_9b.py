"""yi-9b — llama-arch GQA [arXiv:2403.04652; hf].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
"""
from repro.configs.base import ModelConfig

ARCH_ID = "yi-9b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=4096,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=11008,
        vocab_size=64000,
        rope_theta=10000.0,
        source="arXiv:2403.04652",
    )
