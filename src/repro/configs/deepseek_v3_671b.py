"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 MoE, MTP [arXiv:2412.19437; hf].

61L d_model=7168 128H (MLA) expert_d_ff=2048 vocab=129280; first 3 layers dense
(d_ff=18432 per the public config); MTP depth 1.
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,                      # dense-FFN layers (first 3)
        vocab_size=129280,
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048,
                      n_shared=1, d_shared=2048, first_dense_layers=3,
                      capacity_factor=1.25),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
        mtp_depth=1,
        rope_theta=10000.0,
        source="arXiv:2412.19437",
    )
