"""Architecture registry: ``--arch <id>`` lookup for all assigned configs."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

# arch-id -> module name under repro.configs
_MODULES: Dict[str, str] = {
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "whisper-medium": "whisper_medium",
    "stablelm-1.6b": "stablelm_1_6b",
    "yi-9b": "yi_9b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2.5-14b": "qwen2_5_14b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
