"""rwkv6-1.6b — Finch, data-dependent decay, attention-free [arXiv:2404.05892].

24L d_model=2048 d_ff=7168 vocab=65536; head_size=64 (32 heads). Implemented
with the chunked-GLA algorithm (log-space per-channel decay) — see docs/DESIGN.md §2.
"""
from repro.configs.base import ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-1.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=64, chunk=128),
        source="arXiv:2404.05892",
    )
