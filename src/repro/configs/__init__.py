from repro.configs.base import (EncDecConfig, MLAConfig, MoEConfig, ModelConfig,
                                RunConfig, RWKVConfig, ShapeConfig, SSMConfig,
                                VisionConfig)
from repro.configs.registry import ARCH_IDS, all_configs, get_config
from repro.configs.shapes import SHAPE_NAMES, SHAPES, applicability

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
    "EncDecConfig", "VisionConfig", "ShapeConfig", "RunConfig",
    "ARCH_IDS", "get_config", "all_configs", "SHAPES", "SHAPE_NAMES",
    "applicability",
]
