"""Pallas TPU kernel: fused EF-TopK step.

    corrected = residual + g
    mask      = block-top-k(|corrected|)
    send      = corrected ⊙ mask
    residual' = corrected − send

Unfused this is >= 3 HBM round-trips over the gradient; fused it is one read
of (g, residual) and one write of (send, residual'). Threshold selection
reuses the bisection from block_topk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_TILE = 8
N_ITERS = 40


def _ef_update_kernel(k: int, g_ref, e_ref, send_ref, newe_ref):
    corrected = (e_ref[...].astype(jnp.float32)
                 + g_ref[...].astype(jnp.float32))
    mag = jnp.abs(corrected)
    hi = jnp.max(mag, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        pred = cnt >= k
        return jnp.where(pred, mid, lo), jnp.where(pred, hi, mid)

    lo, _ = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    mask = mag >= lo
    send = jnp.where(mask, corrected, 0.0)
    send_ref[...] = send.astype(send_ref.dtype)
    newe_ref[...] = (corrected - send).astype(newe_ref.dtype)


def ef_update_pallas(g2d: jax.Array, e2d: jax.Array, k: int,
                     *, interpret: bool = True):
    """g2d, e2d: [nb, block]. Returns (send, new_residual), both f32."""
    nb, block = g2d.shape
    assert block % 128 == 0 and nb % ROWS_TILE == 0
    grid = (nb // ROWS_TILE,)
    bs = pl.BlockSpec((ROWS_TILE, block), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_ef_update_kernel, k),
        grid=grid,
        in_specs=[bs, bs],
        out_specs=[bs, bs],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.float32),
                   jax.ShapeDtypeStruct((nb, block), jnp.float32)],
        interpret=interpret,
    )(g2d, e2d)
