"""Pallas TPU kernel: causal flash attention (forward, online softmax).

The serving/prefill hot path: q tiles stay in VMEM while K/V stream through
in blk_k-sized blocks with running (max, denominator, accumulator) — one
HBM pass over K/V per q tile, no [Sq, Sk] score materialization. f32
accumulation regardless of input dtype (MXU-style).

Layout: heads are flattened into the grid's first axis; grid =
(B*H, Sq/blk_q). The pure-jnp oracle is ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(blk_k: int, scale: float, causal: bool, blk_q: int,
                  q_ref, k_ref, v_ref, o_ref):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [blk_q, D]
    sk = k_ref.shape[1]
    d = q.shape[-1]
    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)

    def body(i, carry):
        m, l, acc = carry
        kb = k_ref[0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        vb = v_ref[0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        s = q @ kb.T                                   # [blk_q, blk_k]
        if causal:
            k_pos = i * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ vb
        return m_new, l_new, acc_new

    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    acc0 = jnp.zeros((blk_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, sk // blk_k, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           *, causal: bool = True, blk_q: int = 128,
                           blk_k: int = 128, interpret: bool = True
                           ) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BH, Sk, D] (heads pre-flattened).

    Sq % blk_q == 0 and Sk % blk_k == 0 (pad in ops.py)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % blk_q == 0 and sk % blk_k == 0
    scale = 1.0 / (d ** 0.5)
    grid = (bh, sq // blk_q)
    return pl.pallas_call(
        functools.partial(_flash_kernel, blk_k, scale, causal, blk_q),
        grid=grid,
        in_specs=[pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
                  pl.BlockSpec((1, sk, d), lambda b, i: (b, 0, 0)),
                  pl.BlockSpec((1, sk, d), lambda b, i: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
