"""Pallas TPU kernel: fused OPWA aggregation (paper Alg. 1 line 17-18 +
Alg. 3) in a single HBM pass.

Per output tile of n: read all K clients' masked values + masks, compute
overlap counts, the gamma mask, and the coefficient-weighted sum — fused.
The unfused jnp path reads the K×n data three times (counts, weighted sum,
final multiply); this kernel reads it once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 1024


def _overlap_combine_kernel(gamma: float, d: int, vals_ref, masks_ref,
                            coeffs_ref, out_ref):
    vals = vals_ref[...].astype(jnp.float32)        # [K, T]
    masks = masks_ref[...].astype(jnp.int32)        # [K, T]
    coeffs = coeffs_ref[...].astype(jnp.float32)    # [K, 1]
    counts = jnp.sum(masks, axis=0, keepdims=True)  # [1, T]
    weighted = jnp.sum(vals * coeffs, axis=0, keepdims=True)
    amplify = (counts > 0) & (counts <= d)
    m = jnp.where(amplify, jnp.float32(gamma), jnp.float32(1.0))
    out_ref[...] = (m * weighted).astype(out_ref.dtype)


def overlap_combine_pallas(vals: jax.Array, masks: jax.Array,
                           coeffs: jax.Array, gamma: float, d: int,
                           *, interpret: bool = True) -> jax.Array:
    """vals: [K, n] f32; masks: [K, n] int8/bool; coeffs: [K] f32.

    n must be a multiple of TILE_N (pad in ops.py). Returns [1, n] f32."""
    k, n = vals.shape
    assert n % TILE_N == 0
    grid = (n // TILE_N,)
    kv = pl.BlockSpec((k, TILE_N), lambda i: (0, i))
    kc = pl.BlockSpec((k, 1), lambda i: (0, 0))
    out = pl.BlockSpec((1, TILE_N), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_overlap_combine_kernel, gamma, d),
        grid=grid,
        in_specs=[kv, kv, kc],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(vals, masks.astype(jnp.int8), coeffs.reshape(k, 1))
