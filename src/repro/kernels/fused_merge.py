"""Pallas TPU megakernel: fused traced-k apply + merge for the client-update
hot path (paper Alg. 1 lines 14-18 in ONE HBM pass).

Given the per-client k-th-magnitude thresholds from ``threshold_find``, the
unfused XLA path still makes 4-6 more full passes over the [C, n] update
matrix: EF correction, mask materialization, masked values, overlap counts,
the coefficient-weighted sum, and the OPWA multiply each round-trip HBM.
This kernel reads each (updates, residuals) tile once and produces, per
n-tile and entirely in VMEM:

    corrected = residuals + updates          (EF configs)
    mask      = bitcast(|corrected|) >= threshold   (ties kept)
    send      = corrected . mask             (x active-row gating)
    send      = dequant(quant(send, scale))  (codec configs: int8/int4 grid)
    counts    = sum_c mask                   (degree of overlap)
    M         = gamma where 0 < counts <= D else 1   (OPWA, Alg. 3)
    agg       = M . sum_c w_c * send         (coefficient-weighted merge)
    residual' = corrected - send             (inactive rows pass through)

writing only the aggregate tile [1, T] (plus the residual tile for EF
configs) back to HBM. It generalizes and subsumes the three static-k kernels
(``block_topk``'s selection, ``ef_update``'s EF arithmetic,
``overlap_combine``'s merge) at traced per-client k.

The codec stage (``codec="int8"|"int4"``) quantizes the send tile onto the
symmetric integer grid with the per-client ``scales`` column (derived from
``threshold_find``'s row absmax — for Top-K the survivors' absmax equals
the row absmax, so it costs no extra pass) and merges the DEQUANTIZED
values; ``residual' = corrected - dequant(send)`` makes EF absorb the
quantization error. The quantize->dequantize op sequence is
``core.strategies.symmetric_dequantize`` — literally the same function the
jnp ``value_codec`` path runs — so the two routes are bit-exact per tile
(docs/DESIGN.md §10).

Bit-exactness contract (asserted in tests/test_megakernel.py): every
intermediate uses the same op sequence as the jnp reference in
``fed.engine.aggregate_updates`` — in particular the weighted sum is a
dot_general ([1,C] @ [C,T]), which XLA lowers identically to the reference's
``einsum("k,kn->n")`` — so agg and residuals match the traced jnp path bit
for bit, per-tile, including the all-True tie masks of all-zero rows.

``active`` gating mirrors the engine's padded-cohort semantics: inactive
rows contribute nothing to the merge or the overlap counts and their
residuals pass through unchanged; it is a multiply by exactly 1.0/0.0, so
fully-active cohorts are bit-identical to the ungated arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the quantize->dequantize op sequence shared with the jnp value_codec path
# (strategies imports only jnp — no cycle)
from repro.core.strategies import CODEC_LEVELS, symmetric_dequantize

TILE_N = 1024


def _fused_merge_kernel(ef: bool, opwa: bool, gamma: float, d: int,
                        has_active: bool, codec: str, *refs):
    refs = list(refs)
    x_ref = refs.pop(0)
    e_ref = refs.pop(0) if ef else None
    th_ref = refs.pop(0)
    w_ref = refs.pop(0)
    sc_ref = refs.pop(0) if codec != "none" else None
    act_ref = refs.pop(0) if has_active else None
    agg_ref = refs.pop(0)
    newres_ref = refs.pop(0) if ef else None

    x = x_ref[...].astype(jnp.float32)                      # [C, T]
    corrected = e_ref[...].astype(jnp.float32) + x if ef else x
    bits = jax.lax.bitcast_convert_type(jnp.abs(corrected), jnp.uint32)
    mask = bits >= th_ref[...]                              # [C, T]
    vals = jnp.where(mask, corrected, jnp.float32(0.0))
    if codec != "none":
        # the jnp codec's exact op sequence on the jnp codec's exact scale
        # (absmax/levels, prefetched as a [C, 1] column) — survivors land on
        # the integer grid, non-survivors stay exactly zero, all-zero rows
        # keep scale 0 and dequantize to exact zeros
        vals = symmetric_dequantize(vals, sc_ref[...], CODEC_LEVELS[codec])

    if ef:
        new_res = corrected - vals
        if has_active:
            act_b = act_ref[...] > jnp.float32(0.5)         # [C, 1]
            new_res = jnp.where(act_b, new_res, e_ref[...])
        newres_ref[...] = new_res
    if has_active:
        act_b = act_ref[...] > jnp.float32(0.5)
        # padded rows are all-zero updates whose tie-at-zero Top-K mask is
        # all-True — gate them out of the merge and the overlap counts
        vals = vals * act_ref[...]
        mask = mask & act_b

    # [1, C] @ [C, T]: the same dot_general the reference einsum lowers to
    weighted = jax.lax.dot_general(
        w_ref[...], vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # [1, T]
    if opwa:
        counts = jnp.sum(mask.astype(jnp.int32), axis=0, keepdims=True)
        amplify = (counts > 0) & (counts <= d)
        m = jnp.where(amplify, jnp.float32(gamma), jnp.float32(1.0))
        agg_ref[...] = m * weighted
    else:
        agg_ref[...] = weighted


def fused_merge_pallas(x2d: jax.Array, thresholds: jax.Array,
                       weights: jax.Array,
                       e2d: jax.Array | None = None,
                       active: jax.Array | None = None,
                       *, opwa: bool = False, gamma: float = 1.0, d: int = 1,
                       codec: str = "none",
                       scales: jax.Array | None = None,
                       interpret: bool = True):
    """x2d: [C, n] f32 (any n — a ragged tail is zero-padded internally and
    the outputs sliced back); thresholds: [C, 1] uint32 bit-pattern
    thresholds (from ``threshold_find_pallas``); weights: [C, 1] f32 merge
    coefficients; e2d: optional EF residuals [C, n]; active: optional
    [C, 1] f32 row gate (exactly 1.0 / 0.0); codec + scales: optional
    quantization stage — scales [C, 1] f32 per-client symmetric grid scales
    (``strategies.quantization_scale`` of ``threshold_find``'s absmax; its
    mantissa rounding makes every dequantization product exact, so the EF
    subtraction below is immune to fma contraction).

    Zero padding is safe under every config: padded lanes have
    corrected == 0, so whatever the mask decides there (an all-True tie at
    a zero threshold included) contributes exactly-zero values, the codec
    maps them back to zero, overlap counts are per-lane, and the padded agg
    and residual lanes are sliced off before returning.

    Returns agg [1, n] f32, or (agg, new_residuals [C, n]) when ``e2d`` is
    given.
    """
    c, n = x2d.shape
    if codec != "none":
        assert codec in CODEC_LEVELS, f"unknown codec {codec!r}"
        assert scales is not None, "codec needs per-client scales"
        assert e2d is not None, (
            "codec without EF residuals silently drops the quantization "
            "error (same contract the strategy registry enforces)")
    n_pad = (-n) % TILE_N
    if n_pad:
        x2d = jnp.pad(x2d, ((0, 0), (0, n_pad)))
        if e2d is not None:
            e2d = jnp.pad(e2d, ((0, 0), (0, n_pad)))
    np_ = n + n_pad
    ef = e2d is not None
    has_active = active is not None
    grid = (np_ // TILE_N,)
    tile = pl.BlockSpec((c, TILE_N), lambda t: (0, t))
    col = pl.BlockSpec((c, 1), lambda t: (0, 0))

    in_specs, args = [tile], [x2d]
    if ef:
        in_specs.append(tile)
        args.append(e2d)
    in_specs += [col, col]
    args += [thresholds, weights.astype(jnp.float32)]
    if codec != "none":
        in_specs.append(col)
        args.append(scales.astype(jnp.float32))
    if has_active:
        in_specs.append(col)
        args.append(active.astype(jnp.float32))

    out_specs = [pl.BlockSpec((1, TILE_N), lambda t: (0, t))]
    out_shape = [jax.ShapeDtypeStruct((1, np_), jnp.float32)]
    if ef:
        out_specs.append(tile)
        out_shape.append(jax.ShapeDtypeStruct((c, np_), jnp.float32))

    out = pl.pallas_call(
        functools.partial(_fused_merge_kernel, ef, opwa, float(gamma),
                          int(d), has_active, codec),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if n_pad:
        if ef:
            return out[0][:, :n], out[1][:, :n]
        return out[0][:, :n]
    return (out[0], out[1]) if ef else out[0]
