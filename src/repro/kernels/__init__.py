"""Pallas TPU kernels for the compression hot path:

  block_topk       per-VMEM-block magnitude Top-K via threshold bisection
  overlap_combine  fused OPWA aggregation (counts + mask + weighted sum)
  ef_update        fused error-feedback Top-K step

Each has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py; validated
in interpret mode on CPU, targeted at TPU VMEM tiling (8 x 128 lanes).
"""
