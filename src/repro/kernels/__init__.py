"""Pallas TPU kernels for the compression hot path:

  threshold_find   exact per-client k-th-magnitude thresholds at TRACED k
                   (16-ary bit-pattern bisection, 8 streamed sweeps)
  fused_merge      traced-k apply/merge megakernel: EF correction, Top-K
                   masking, overlap counts, OPWA mask, and the weighted
                   aggregate in ONE pass over each (updates, residuals) tile
  block_topk       per-VMEM-block magnitude Top-K at static k
  overlap_combine  fused OPWA aggregation (counts + mask + weighted sum)
  ef_update        fused error-feedback Top-K step at static k

``threshold_find`` + ``fused_merge`` form the traced-k megakernel pipeline
behind ``fed.engine.aggregate_updates`` — the route that serves the paper's
bandwidth-adaptive per-client CRs; the three static-k kernels are the
special cases it subsumes. Each kernel has a pure-jnp oracle in ref.py and a
jit'd wrapper in ops.py; validated in interpret mode on CPU, targeted at TPU
VMEM tiling (8 x 128 lanes).
"""
