"""Pallas TPU kernel: per-block magnitude Top-K selection.

TPU adaptation of the GPU radix-select: each VMEM-resident block finds its
k-th-largest magnitude by threshold *bisection* (40 fixed iterations — the
interval shrinks below one f32 ULP, so the mask equals the exact
``mag >= kth_largest`` selection, ties kept). No sort, no gather; pure
vector compares + reductions, one HBM read + one write per element.

Layout: x is reshaped to [nb, block] rows; grid tiles rows at ROWS_TILE=8
(f32 sublane) × block lanes (multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_TILE = 8
N_ITERS = 40


def _block_topk_kernel(k: int, x_ref, vals_ref, mask_ref):
    x = x_ref[...]
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag, axis=1, keepdims=True)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.int32), axis=1, keepdims=True)
        pred = cnt >= k
        lo = jnp.where(pred, mid, lo)
        hi = jnp.where(pred, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, N_ITERS, body, (lo, hi))
    mask = mag >= lo
    vals_ref[...] = jnp.where(mask, x, 0).astype(vals_ref.dtype)
    mask_ref[...] = mask.astype(jnp.int8)


def block_topk_pallas(x2d: jax.Array, k: int, *, interpret: bool = True):
    """x2d: [nb, block] (block % 128 == 0, nb % ROWS_TILE == 0).

    Returns (values [nb, block], mask int8 [nb, block])."""
    nb, block = x2d.shape
    assert block % 128 == 0, f"block={block} must be lane-aligned (128)"
    assert nb % ROWS_TILE == 0, f"nb={nb} must be a multiple of {ROWS_TILE}"
    grid = (nb // ROWS_TILE,)
    bs = pl.BlockSpec((ROWS_TILE, block), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_block_topk_kernel, k),
        grid=grid,
        in_specs=[bs],
        out_specs=[bs, bs],
        out_shape=[jax.ShapeDtypeStruct((nb, block), x2d.dtype),
                   jax.ShapeDtypeStruct((nb, block), jnp.int8)],
        interpret=interpret,
    )(x2d)
