"""jit'd public wrappers for the Pallas kernels: padding/reshaping to tile
boundaries, CPU interpret-mode autodetection, flat-vector interfaces used by
repro.core."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.compression import Compressed, k_for_ratio
from repro.core.strategies import CODEC_LEVELS, quantization_scale
from repro.kernels.block_topk import ROWS_TILE, block_topk_pallas
from repro.kernels.ef_update import ef_update_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_merge import fused_merge_pallas
from repro.kernels.fused_merge import TILE_N as MERGE_TILE
from repro.kernels.overlap_combine import TILE_N, overlap_combine_pallas
from repro.kernels.threshold_find import threshold_find_pallas
from repro.kernels.threshold_find import TILE_N as THRESH_TILE


def _interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


def _pad_rows(n_rows: int) -> int:
    return (-n_rows) % ROWS_TILE


@functools.partial(jax.jit, static_argnames=("cr", "block"))
def block_topk(u: jax.Array, cr: float, block: int = 8192) -> Compressed:
    """Flat vector -> block-top-k Compressed (kernel-backed)."""
    n = u.shape[0]
    n_pad = (-n) % block
    up = jnp.pad(u.astype(jnp.float32), (0, n_pad))
    nb = up.shape[0] // block
    x2d = up.reshape(nb, block)
    rpad = _pad_rows(nb)
    if rpad:
        x2d = jnp.pad(x2d, ((0, rpad), (0, 0)))
    k = k_for_ratio(block, cr)
    vals, mask = block_topk_pallas(x2d, k, interpret=_interpret())
    vals = vals[:nb].reshape(-1)[:n].astype(u.dtype)
    mask = mask[:nb].reshape(-1)[:n] > 0
    return Compressed(vals, mask)


@functools.partial(jax.jit, static_argnames=("gamma", "d"))
def overlap_combine(vals: jax.Array, masks: jax.Array, coeffs: jax.Array,
                    gamma: float, d: int) -> jax.Array:
    """[K,n] masked updates + [K,n] masks + [K] coeffs -> OPWA-aggregated [n]."""
    k, n = vals.shape
    n_pad = (-n) % TILE_N
    v = jnp.pad(vals.astype(jnp.float32), ((0, 0), (0, n_pad)))
    m = jnp.pad(masks.astype(jnp.int8), ((0, 0), (0, n_pad)))
    out = overlap_combine_pallas(v, m, coeffs.astype(jnp.float32),
                                 float(gamma), int(d),
                                 interpret=_interpret())
    return out[0, :n]


# ------------------------------------------------- traced-k megakernel pipeline
@jax.jit
def topk_thresholds(updates: jax.Array, ks: jax.Array,
                    residuals: jax.Array | None = None) -> jax.Array:
    """[C, n] updates + traced [C] retained counts -> exact per-client
    k-th-|.| bit-pattern thresholds u32 [C] (of ``residuals + updates`` when
    residuals are given). The Top-K mask is
    ``bitcast(|x|, u32) >= thresholds[:, None]`` — bit-identical to
    ``topk_compress_dynamic`` in 8 streamed HBM sweeps instead of 32."""
    c, n = updates.shape
    n_pad = (-n) % THRESH_TILE
    up = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, n_pad)))
    ep = (jnp.pad(residuals.astype(jnp.float32), ((0, 0), (0, n_pad)))
          if residuals is not None else None)
    th = threshold_find_pallas(up, ks.reshape(c, 1), ep,
                               interpret=_interpret())
    return th[:, 0]


@functools.partial(jax.jit, static_argnames=("opwa", "gamma", "d", "codec"))
def megakernel_aggregate(updates: jax.Array, ks: jax.Array,
                         weights: jax.Array,
                         residuals: jax.Array | None = None,
                         active: jax.Array | None = None,
                         *, opwa: bool = False, gamma: float = 1.0,
                         d: int = 1, codec: str = "none"):
    """Whole flat-space client merge through the two-kernel pipeline:
    threshold-find (8 HBM sweeps) + fused apply/merge (1 pass) — vs the
    ~35 passes of the unfused XLA lowering (see repro.roofline.kernel_bytes).

    updates [C, n] f32; ks [C] i32 traced; weights [C] f32; residuals
    optional [C, n] (switches on EF arithmetic and the new-residual output);
    active optional bool [C] (padded-cohort gating, engine semantics);
    codec: "none" | "int8" | "int4" — quantize/dequantize the survivors
    inside the merge tile pass (requires residuals: EF absorbs the
    quantization error). The per-client scale is the row absmax emitted by
    threshold-find on its already-streamed sweep, fed through the identical
    ``strategies.quantization_scale`` the jnp ``value_codec`` uses, so the
    scales (and everything downstream) match bit for bit.

    Returns (agg [n] f32, new_residuals [C, n] | None) — bit-exact with the
    jnp path of ``fed.engine.aggregate_updates``.
    """
    c, n = updates.shape
    n_pad = (-n) % MERGE_TILE
    up = jnp.pad(updates.astype(jnp.float32), ((0, 0), (0, n_pad)))
    ep = (jnp.pad(residuals.astype(jnp.float32), ((0, 0), (0, n_pad)))
          if residuals is not None else None)
    # MERGE_TILE is a multiple of THRESH_TILE: one padding serves both
    if codec == "none":
        th = threshold_find_pallas(up, ks.reshape(c, 1), ep,
                                   interpret=_interpret())
        scales = None
    else:
        th, absmax = threshold_find_pallas(up, ks.reshape(c, 1), ep,
                                           emit_scale=True,
                                           interpret=_interpret())
        scales = quantization_scale(absmax, CODEC_LEVELS[codec])
    act = (active.astype(jnp.float32).reshape(c, 1)
           if active is not None else None)
    out = fused_merge_pallas(up, th, weights.astype(jnp.float32)
                             .reshape(c, 1), ep, act, opwa=opwa,
                             gamma=gamma, d=d, codec=codec, scales=scales,
                             interpret=_interpret())
    if residuals is None:
        return out[0, :n], None
    agg, new_res = out
    return agg[0, :n], new_res[:, :n]


@functools.partial(jax.jit, static_argnames=("cr", "block"))
def ef_topk_update(g: jax.Array, residual: jax.Array, cr: float,
                   block: int = 8192):
    """Fused EF step on flat vectors -> (send [n], new_residual [n])."""
    n = g.shape[0]
    n_pad = (-n) % block
    gp = jnp.pad(g.astype(jnp.float32), (0, n_pad))
    ep = jnp.pad(residual.astype(jnp.float32), (0, n_pad))
    nb = gp.shape[0] // block
    g2d, e2d = gp.reshape(nb, block), ep.reshape(nb, block)
    rpad = _pad_rows(nb)
    if rpad:
        g2d = jnp.pad(g2d, ((0, rpad), (0, 0)))
        e2d = jnp.pad(e2d, ((0, rpad), (0, 0)))
    k = k_for_ratio(block, cr)
    send, new_e = ef_update_pallas(g2d, e2d, k, interpret=_interpret())
    return (send[:nb].reshape(-1)[:n], new_e[:nb].reshape(-1)[:n])


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128) -> jax.Array:
    """Model-layout wrapper: q [B,S,H,D], k/v [B,S,H,D] (equal heads; GQA
    callers broadcast kv first). Pads Sq/Sk to block multiples."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    pq, pk = (-sq) % blk_q, (-sk) % blk_k
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys sit at positions >= Sk: causal-masked away for every
        # real query position (non-causal callers must pad Sk themselves)
        assert causal, "non-causal flash with Sk % blk_k != 0 unsupported"
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(qt, kt, vt, causal=causal, blk_q=blk_q,
                                 blk_k=blk_k, interpret=_interpret())
    out = out[:, :sq].reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    return out
