"""Pallas TPU kernel: exact per-client k-th-magnitude thresholds at TRACED k.

The traced-k Top-K in ``core.compression.topk_compress_dynamic`` bisects the
uint32 bit pattern of |u| (non-negative IEEE floats order identically to
their bit patterns), but its XLA lowering re-reads the whole [C, n] magnitude
array on every one of its 32 halvings — ~32 HBM round-trips just to find the
thresholds. This kernel finds the SAME thresholds in ``SWEEPS`` = 8 logical
reads by widening the bisection to a 16-ary search:

  * the grid is (SWEEPS, n_tiles); TPU grids iterate the last axis innermost,
    so each sweep streams every n-tile through VMEM exactly once;
  * per-client interval state ``lo [C, 1]`` lives in VMEM scratch across the
    whole grid; the interval width is uniform across clients and depends only
    on the sweep index (width_s = 2^31 / 16^s), so it is recomputed from
    ``program_id(0)`` instead of being carried;
  * each tile accumulates per-client counts of ``bits >= lo + j*step`` for
    the W-1 = 15 candidate boundaries into a [C, W-1] VMEM accumulator
    (hierarchical count reduction: tile-local compare+sum, cross-tile add);
  * at the sweep's last tile the largest qualifying boundary (count >= k)
    becomes the new ``lo`` — after 8 sweeps the interval width is 1 and
    ``lo`` is exactly the k-th-largest bit pattern (ties kept), bit-identical
    to the 32-halving reference for every k in [1, n].

Per-client retained counts ``ks [C, 1]`` arrive as a scalar-prefetch operand
(SMEM), so they stay fully traced — one compiled kernel serves every BCRS
schedule. The optional ``e2d`` input switches the selection quantity to the
error-feedback ``corrected = residuals + updates`` without materializing it
in HBM.

``emit_scale`` additionally returns the per-client row absmax
``max_j |corrected_ij|`` — the quantity a symmetric quantizer's scale is
derived from. It rides on sweep 0's existing streamed tiles (a running
max-of-tile-maxes in the output's VMEM block), so it costs ZERO extra HBM
passes; fp max is exact and associative, so the tile-wise accumulation is
bit-identical to ``jnp.max(jnp.abs(corrected), axis=1)``. For Top-K
selection this absmax IS the survivors' absmax (k >= 1 keeps the largest
magnitude, ties or not), which is why the downstream codec kernel can use
it as the jnp codec's scale verbatim (docs/DESIGN.md §10).

Padding contract: tail lanes past the real ``n`` must be zero. Candidate
boundaries are always >= 1 (``step >= 1``, ``j >= 1``), so zero-padded lanes
can never be counted and the thresholds are those of the unpadded rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: 16-ary search: 15 candidate boundaries per sweep, 8 sweeps cover the full
#: 2^31 span of |f32| bit patterns (16^8 = 2^32), ending at interval width 1.
WAYS = 16
SWEEPS = 8
TILE_N = 512
#: initial boundary spacing: span 2^31 split into WAYS buckets
_STEP0 = np.uint32((1 << 31) // WAYS)


def _threshold_find_kernel(has_res: bool, emit_scale: bool, ks_ref, x_ref,
                           *rest):
    rest = list(rest)
    e_ref = rest.pop(0) if has_res else None
    th_ref = rest.pop(0)
    sc_ref = rest.pop(0) if emit_scale else None
    lo_ref, cnt_ref = rest
    if has_res:
        corrected = (e_ref[...].astype(jnp.float32)
                     + x_ref[...].astype(jnp.float32))
    else:
        corrected = x_ref[...].astype(jnp.float32)
    s = pl.program_id(0)
    t = pl.program_id(1)
    nt = pl.num_programs(1)
    bits = jax.lax.bitcast_convert_type(jnp.abs(corrected), jnp.uint32)

    if emit_scale:
        # per-client absmax accumulated over sweep 0's tiles only — the
        # operand stream is already paid for, and the output block maps to
        # (0, 0) for every grid step so the running max persists in VMEM
        @pl.when(s == 0)
        def _():
            tilemax = jnp.max(jnp.abs(corrected), axis=1, keepdims=True)
            prev = jnp.where(t == 0, jnp.float32(0.0), sc_ref[...])
            sc_ref[...] = jnp.maximum(prev, tilemax)

    @pl.when(jnp.logical_and(s == 0, t == 0))
    def _():
        lo_ref[...] = jnp.zeros_like(lo_ref)

    @pl.when(t == 0)
    def _():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    # interval width is client-independent: width_s = 2^31 / 16^s, so the
    # boundary spacing needs no cross-sweep state (floor(ceil) identities:
    # widths are powers of two until the final width-8 -> step-1 sweep)
    step = jnp.maximum(_STEP0 >> (4 * s.astype(jnp.uint32)), jnp.uint32(1))
    lo = lo_ref[...]                                        # [C, 1] u32

    # hierarchical count: tile-local compare+sum per candidate boundary,
    # accumulated across tiles in VMEM (W-1 static columns, unrolled)
    cols = []
    for j in range(1, WAYS):
        b_j = lo + jnp.uint32(j) * step                     # [C, 1]
        cols.append(jnp.sum((bits >= b_j).astype(jnp.int32),
                            axis=1, keepdims=True))
    cnt_ref[...] += jnp.concatenate(cols, axis=1)           # [C, W-1]

    @pl.when(t == nt - 1)
    def _():
        cnt = cnt_ref[...]
        k = ks_ref[...]                                     # [C, 1] i32
        qual = cnt >= k
        jvec = (jax.lax.broadcasted_iota(jnp.uint32, (1, WAYS - 1), 1)
                + jnp.uint32(1))
        jsel = jnp.max(jnp.where(qual, jvec, jnp.uint32(0)),
                       axis=1, keepdims=True)               # [C, 1]
        new_lo = lo + jsel * step
        lo_ref[...] = new_lo

        @pl.when(s == SWEEPS - 1)
        def _():
            th_ref[...] = new_lo


def threshold_find_pallas(x2d: jax.Array, ks: jax.Array,
                          e2d: jax.Array | None = None,
                          *, emit_scale: bool = False,
                          interpret: bool = True):
    """x2d: [C, n] f32 (n % TILE_N == 0, zero-padded tail); ks: [C, 1] i32
    traced retained counts (1 <= k <= real n); e2d: optional matching EF
    residuals — thresholds are then those of ``e2d + x2d``.

    Returns the k-th-largest |.| bit patterns as uint32 [C, 1]: the exact
    Top-K mask is ``bitcast(|x|) >= thresholds`` (ties kept), matching
    ``topk_compress_dynamic`` bit for bit. With ``emit_scale`` returns
    ``(thresholds, absmax [C, 1] f32)`` — the per-client
    ``max |corrected|``, bit-identical to the jnp row max (see module
    docstring), free-riding on sweep 0's operand stream.
    """
    c, n = x2d.shape
    assert n % TILE_N == 0, f"n={n} must be a multiple of {TILE_N}"
    nt = n // TILE_N
    bs = pl.BlockSpec((c, TILE_N), lambda s, t, *_: (0, t))
    in_specs, args = [bs], [x2d]
    if e2d is not None:
        in_specs.append(bs)
        args.append(e2d)
    col = pl.BlockSpec((c, 1), lambda s, t, *_: (0, 0))
    out_specs = [col, col] if emit_scale else col
    out_shape = jax.ShapeDtypeStruct((c, 1), jnp.uint32)
    if emit_scale:
        out_shape = [out_shape, jax.ShapeDtypeStruct((c, 1), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(SWEEPS, nt),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((c, 1), jnp.uint32),
                        pltpu.VMEM((c, WAYS - 1), jnp.int32)],
    )
    out = pl.pallas_call(
        functools.partial(_threshold_find_kernel, e2d is not None,
                          emit_scale),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(ks.astype(jnp.int32), *args)
    return (out[0], out[1]) if emit_scale else out
