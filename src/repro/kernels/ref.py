"""Pure-jnp oracles for every Pallas kernel (exact semantics incl. ties)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(x2d: jax.Array, k: int):
    """[nb, block] -> (values, mask int8). Keeps |x| >= k-th largest (ties kept)."""
    mag = jnp.abs(x2d.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][:, -1:]
    mask = mag >= thresh
    return jnp.where(mask, x2d, 0), mask.astype(jnp.int8)


def overlap_combine_ref(vals: jax.Array, masks: jax.Array, coeffs: jax.Array,
                        gamma: float, d: int) -> jax.Array:
    """[K,n] masked values, [K,n] masks, [K] coeffs -> [1,n] f32."""
    counts = jnp.sum(masks.astype(jnp.int32), axis=0, keepdims=True)
    weighted = jnp.einsum("k,kn->n", coeffs.astype(jnp.float32),
                          vals.astype(jnp.float32))[None, :]
    m = jnp.where((counts > 0) & (counts <= d), jnp.float32(gamma), 1.0)
    return m * weighted


def threshold_find_ref(x2d: jax.Array, ks: jax.Array,
                       e2d: jax.Array | None = None) -> jax.Array:
    """Traced-k thresholds [C, 1] u32: the k-th-largest |.| bit pattern per
    row (of ``e2d + x2d`` when residuals are given), via the 32-halving
    reference bisection."""
    from repro.core.compression import topk_compress_dynamic
    x = x2d.astype(jnp.float32)
    if e2d is not None:
        x = e2d.astype(jnp.float32) + x
    masks = jax.vmap(topk_compress_dynamic)(x, ks.reshape(-1)).mask
    bits = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.uint32)
    # the bisection's converged lo == the smallest kept bit pattern
    return jnp.min(jnp.where(masks, bits, jnp.uint32(0xFFFFFFFF)),
                   axis=1, keepdims=True)


def fused_merge_ref(x2d: jax.Array, thresholds: jax.Array, weights: jax.Array,
                    e2d: jax.Array | None = None,
                    active: jax.Array | None = None,
                    *, opwa: bool = False, gamma: float = 1.0, d: int = 1,
                    codec: str = "none", scales: jax.Array | None = None):
    """Oracle for the apply/merge megakernel: same op sequence as the jnp
    path in ``fed.engine.aggregate_updates``. ``codec`` + ``scales`` [C, 1]
    mirror the kernel's quantization stage (survivors dequantized before the
    merge; EF absorbs the quantization error). Returns agg [1, n] (plus
    new_residuals [C, n] when ``e2d`` is given)."""
    from repro.core.strategies import CODEC_LEVELS, symmetric_dequantize
    x = x2d.astype(jnp.float32)
    corrected = e2d.astype(jnp.float32) + x if e2d is not None else x
    bits = jax.lax.bitcast_convert_type(jnp.abs(corrected), jnp.uint32)
    mask = bits >= thresholds.reshape(-1, 1)
    vals = jnp.where(mask, corrected, 0.0)
    if codec != "none":
        vals = symmetric_dequantize(vals, scales, CODEC_LEVELS[codec])
    new_res = corrected - vals if e2d is not None else None
    if active is not None:
        act = active.reshape(-1, 1)
        if new_res is not None:
            new_res = jnp.where(act > 0.5, new_res, e2d)
        vals = vals * act.astype(jnp.float32)
        mask = mask & (act > 0.5)
    weighted = jnp.einsum("k,kn->n", weights.reshape(-1).astype(jnp.float32),
                          vals)[None, :]
    if opwa:
        counts = jnp.sum(mask.astype(jnp.int32), axis=0, keepdims=True)
        m = jnp.where((counts > 0) & (counts <= d), jnp.float32(gamma),
                      jnp.float32(1.0))
        weighted = m * weighted
    return weighted if e2d is None else (weighted, new_res)


def ef_update_ref(g2d: jax.Array, e2d: jax.Array, k: int):
    corrected = e2d.astype(jnp.float32) + g2d.astype(jnp.float32)
    send, _ = block_topk_ref(corrected, k)
    return send, corrected - send


def flash_attention_ref(q, k, v, causal: bool = True):
    """[BH, Sq, D] x [BH, Sk, D] -> [BH, Sq, D]; f32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
