"""Pure-jnp oracles for every Pallas kernel (exact semantics incl. ties)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(x2d: jax.Array, k: int):
    """[nb, block] -> (values, mask int8). Keeps |x| >= k-th largest (ties kept)."""
    mag = jnp.abs(x2d.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][:, -1:]
    mask = mag >= thresh
    return jnp.where(mask, x2d, 0), mask.astype(jnp.int8)


def overlap_combine_ref(vals: jax.Array, masks: jax.Array, coeffs: jax.Array,
                        gamma: float, d: int) -> jax.Array:
    """[K,n] masked values, [K,n] masks, [K] coeffs -> [1,n] f32."""
    counts = jnp.sum(masks.astype(jnp.int32), axis=0, keepdims=True)
    weighted = jnp.einsum("k,kn->n", coeffs.astype(jnp.float32),
                          vals.astype(jnp.float32))[None, :]
    m = jnp.where((counts > 0) & (counts <= d), jnp.float32(gamma), 1.0)
    return m * weighted


def ef_update_ref(g2d: jax.Array, e2d: jax.Array, k: int):
    corrected = e2d.astype(jnp.float32) + g2d.astype(jnp.float32)
    send, _ = block_topk_ref(corrected, k)
    return send, corrected - send


def flash_attention_ref(q, k, v, causal: bool = True):
    """[BH, Sq, D] x [BH, Sk, D] -> [BH, Sq, D]; f32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
