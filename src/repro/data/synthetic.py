"""Synthetic datasets for the offline container.

* ``synthetic_classification`` — a mixture-of-Gaussians classification task
  (stands in for CIFAR/SVHN in FL benchmarks; learnable, non-trivial, with
  real class structure so Dirichlet label skew is meaningful).
* ``synthetic_lm_tokens`` — Zipf-distributed token streams with a planted
  bigram structure (so LM training shows measurable CE decrease).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def synthetic_classification(n: int, n_classes: int, dim: int,
                             rng: np.random.Generator,
                             noise: float = 1.0) -> Tuple[np.ndarray, np.ndarray]:
    centers = rng.normal(0.0, 2.0, (n_classes, dim))
    labels = rng.integers(0, n_classes, n)
    x = centers[labels] + rng.normal(0.0, noise, (n, dim))
    return x.astype(np.float32), labels.astype(np.int32)


def synthetic_lm_tokens(n_seqs: int, seq_len: int, vocab: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Zipf unigram + deterministic planted bigraph: token t+1 depends on t
    with prob 0.5 via a fixed permutation (learnable structure)."""
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks
    probs /= probs.sum()
    perm = rng.permutation(vocab)
    toks = np.empty((n_seqs, seq_len), np.int32)
    toks[:, 0] = rng.choice(vocab, n_seqs, p=probs)
    for t in range(1, seq_len):
        follow = rng.random(n_seqs) < 0.5
        fresh = rng.choice(vocab, n_seqs, p=probs)
        toks[:, t] = np.where(follow, perm[toks[:, t - 1]], fresh)
    return toks


def lm_batch(tokens: np.ndarray):
    """Next-token prediction batch dict from a [B, S+1] token block."""
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32)}
