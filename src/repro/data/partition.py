"""Dirichlet label-skew partitioning (paper §5.1, following Li et al. 2021).

Each client i receives a proportion ``p_{k,i}`` of class k's samples with
``p_k ~ Dir(beta)``. beta=0.1 -> severe heterogeneity, beta=0.5 -> moderate.
"""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, beta: float,
                        rng: np.random.Generator,
                        min_size: int = 2) -> List[np.ndarray]:
    """Returns per-client index arrays covering all samples exactly once."""
    n_classes = int(labels.max()) + 1
    while True:
        idx_by_client: List[List[int]] = [[] for _ in range(n_clients)]
        for k in range(n_classes):
            idx_k = np.where(labels == k)[0]
            rng.shuffle(idx_k)
            props = rng.dirichlet(np.full(n_clients, beta))
            cuts = (np.cumsum(props)[:-1] * len(idx_k)).astype(int)
            for c, part in enumerate(np.split(idx_k, cuts)):
                idx_by_client[c].extend(part.tolist())
        sizes = [len(ix) for ix in idx_by_client]
        if min(sizes) >= min_size:
            break
    return [np.array(sorted(ix)) for ix in idx_by_client]


def client_label_histogram(labels: np.ndarray,
                           parts: List[np.ndarray]) -> np.ndarray:
    """[n_clients, n_classes] counts — the paper's Fig. 5 heat map data."""
    n_classes = int(labels.max()) + 1
    return np.stack([np.bincount(labels[ix], minlength=n_classes)
                     for ix in parts])


def data_fractions(parts: List[np.ndarray]) -> np.ndarray:
    sizes = np.array([len(ix) for ix in parts], np.float64)
    return sizes / sizes.sum()
