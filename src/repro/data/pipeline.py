"""Client data pipeline: per-client shard iterators with deterministic
shuffling, epoch semantics (paper's E local epochs), and drop-last batching.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np


@dataclass
class ClientDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return len(self.y)

    def epoch_batches(self, batch_size: int, rng: np.random.Generator
                      ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        order = rng.permutation(len(self.y))
        for i in range(0, len(order) - batch_size + 1, batch_size):
            sel = order[i: i + batch_size]
            yield self.x[sel], self.y[sel]

    def fixed_batch_indices(self, batch_size: int, n_batches: int,
                            rng: np.random.Generator) -> np.ndarray:
        """Local sample indices [n_batches * bs] for ``fixed_batches``
        (cycling if needed). Split out so the scanned simulation can feed the
        *indices* to an in-jit gather — it consumes the exact same rng draws
        as materializing the batches on host, so the two paths stay on one
        seeded stream."""
        need = n_batches * batch_size
        reps = int(np.ceil(need / max(len(self.y), 1)))
        order = np.concatenate([rng.permutation(len(self.y)) for _ in range(reps)])
        return order[:need]

    def fixed_batches(self, batch_size: int, n_batches: int,
                      rng: np.random.Generator) -> Tuple[np.ndarray, np.ndarray]:
        """[n_batches, bs, ...] stacked batches (cycling if needed) — the
        shape used by the vmapped mesh-parallel FL round."""
        sel = self.fixed_batch_indices(batch_size, n_batches, rng)
        xs = self.x[sel].reshape(n_batches, batch_size, *self.x.shape[1:])
        ys = self.y[sel].reshape(n_batches, batch_size, *self.y.shape[1:])
        return xs, ys


def build_client_datasets(x: np.ndarray, y: np.ndarray,
                          parts: List[np.ndarray]) -> List[ClientDataset]:
    return [ClientDataset(x[ix], y[ix]) for ix in parts]
