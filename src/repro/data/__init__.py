from repro.data.partition import (client_label_histogram, data_fractions,
                                  dirichlet_partition)
from repro.data.pipeline import ClientDataset, build_client_datasets
from repro.data.synthetic import (lm_batch, synthetic_classification,
                                  synthetic_lm_tokens)

__all__ = [
    "dirichlet_partition", "client_label_histogram", "data_fractions",
    "ClientDataset", "build_client_datasets", "synthetic_classification",
    "synthetic_lm_tokens", "lm_batch",
]
