"""The paper's primary contribution: BCRS + OPWA compressed aggregation."""
from repro.core.aggregation import AggregationConfig, aggregate
from repro.core.bcrs import (BCRSSchedule, ClientLink, client_coefficients,
                             comm_time, make_schedule, pod_link_schedule,
                             schedule_crs)
from repro.core.compression import (Compressed, block_topk_compress,
                                    ef_compress, flatten_tree, from_sparse,
                                    k_for_ratio, quantize_stochastic,
                                    randk_compress, to_sparse, topk_compress,
                                    topk_compress_dynamic)
from repro.core.cost_model import (RoundTime, TimeAccumulator, round_times,
                                   sample_links, uncompressed_round)
from repro.core.opwa import (bcrs_aggregate, opwa_aggregate, opwa_mask,
                             overlap_counts, overlap_histogram)

__all__ = [
    "AggregationConfig", "aggregate", "BCRSSchedule", "ClientLink",
    "client_coefficients", "comm_time", "make_schedule", "pod_link_schedule",
    "schedule_crs", "Compressed", "block_topk_compress", "ef_compress",
    "flatten_tree", "from_sparse", "k_for_ratio", "quantize_stochastic",
    "randk_compress", "to_sparse", "topk_compress", "topk_compress_dynamic",
    "RoundTime",
    "TimeAccumulator", "round_times", "sample_links", "uncompressed_round",
    "bcrs_aggregate", "opwa_aggregate", "opwa_mask", "overlap_counts",
    "overlap_histogram",
]
