"""Strategy plugin registry: ONE declarative compressor/aggregator interface.

Every FL round engine in the tree — the legacy eager loop
(``fed.server.FLServer.round``), the fused per-round program
(``fed.round_step``), the whole-simulation scan (``fed.engine.make_sim_scan``),
the mesh per-leaf scan (``fed.engine.make_mesh_sim_scan`` /
``fed.mesh_round``), and the compressed pod sync (``dist.grad_sync``) —
consumes strategies exclusively through this registry. A ``Strategy``
declares its *capabilities*; the engines dispatch on those capabilities and
never match strategy names. This module is therefore the ONLY place in
``src/`` allowed to mention strategy names structurally
(``tools/check_strategy_enum.py`` enforces that in CI), which is what makes
third-party strategies drop in without touching any engine file:

    from repro.core import strategies

    strategies.register(strategies.Strategy(
        name="my_ef_topk",
        description="Top-K with EF, my twist",
        carry="ef", selector="topk", weighting="data",
        wire=strategies.SPARSE32, megakernel=True))

and ``my_ef_topk`` runs through every engine, CLI, and cost model.

Capability fields (see docs/DESIGN.md §8 for the full table):

  carry        what state threads across rounds per cohort slot:
               "none" | "ef" (error-feedback residuals; engines allocate,
               donate, reset-on-cohort-resize, and checkpoint the buffers).
  selector     which survivor-selection family runs client-side: "none"
               (dense — every coordinate survives) | "topk" (the traced-k
               bit-pattern bisection; the block variant stays an engine-side
               config knob orthogonal to the strategy).
  value_codec  optional lossy wire codec applied to the surviving values:
               ``codec(values [C, ...], mask) -> values`` (rank-agnostic,
               leading client axis). The engines feed the DEQUANTIZED values
               to both the merge and the EF residual update, so EF absorbs
               the codec error automatically — which is why a codec REQUIRES
               ``carry="ef"`` (without EF the codec error is silently
               dropped bias; registration refuses it).
  weighting    where averaging coefficients come from: "data" (data
               fractions, uniform CR*) | "bcrs" (bandwidth schedule Alg. 2 +
               Eq. 6 coefficients).
  overlap_weighted  apply the OPWA overlap mask (Alg. 3) at the merge.
  wire         ``WireFormat`` — declarative bytes-on-the-wire model feeding
               ALL comm-time accounting (replaces the scattered
               ``cr_eff = 1.0 if strategy == "fedavg"`` special cases).
  residual_layout  how the population client-state store persists this
               strategy's EF residual between participations (only
               meaningful for ``carry="ef"``):
               "dense"            residual may be nonzero anywhere (e.g. a
                                  value codec leaves quantization error at
                                  the SURVIVOR coordinates too — qtopk);
                                  the store keeps full f32 rows, chunked
                                  and spilled but not sparsified.
               "topk_complement"  residual is nonzero only on the dropped
                                  coordinates of the client's last
                                  participation (pure Top-K selection under
                                  EF: survivors are sent exactly, so their
                                  residual is zero). nnz <= n - k, so the
                                  store persists (idx32, f32) pairs of
                                  static width n - k_min — O(P*(n-k_min))
                                  instead of O(P*n). Requires
                                  selector="topk" and no value_codec
                                  (registration refuses layouts the math
                                  can't honor).
  megakernel   eligible for the traced-k Pallas pipeline (threshold_find +
               fused_merge). Codec strategies may opt in by ALSO declaring
               ``kernel_codec`` — the kernel's per-tile quantize/dequantize
               stage (see docs/DESIGN.md §10); a codec without a declared
               kernel lowering must keep megakernel=False (registration
               refuses the combo).
  kernel_codec None, or the name of the fused_merge codec stage ("int8" /
               "int4") whose in-kernel quantize->dequantize sequence is
               bit-exact with this strategy's ``value_codec``. Declaring it
               is the per-codec megakernel capability: the engines pass it
               to ``kernels.ops.megakernel_aggregate`` so the whole
               compress->codec->EF->merge pipeline stays in one tile pass.

Shape follows the builder-registry pattern (SNIPPETS.md snippet 3): a
validating ``register`` over a name-keyed table, duplicate names refused.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

import jax.numpy as jnp
from jax import lax

__all__ = [
    "WireFormat", "Strategy", "StrategyRegistry", "REGISTRY",
    "register", "unregister", "get", "names",
    "DENSE32", "SPARSE32", "PACKED_INT8", "PACKED_INT4",
    "BITMASK_INT8", "BITMASK_INT4",
    "CODEC_LEVELS", "symmetric_dequantize", "quantization_scale",
    "scale_mantissa_bits",
    "int8_symmetric_codec", "int4_symmetric_codec",
]

#: bytes per survivor of the paper's reference sparse pair (int32 index +
#: f32 value) — the 2x factor inside ``core.bcrs.comm_time``'s
#: ``T = L + 2 * V_bits * cr / B``. Every wire format's effective CR is
#: normalized against this so the scheduler's time model needs no per-format
#: branches.
_REF_PAIR_BYTES = 8.0


# ------------------------------------------------------------- wire format
@dataclass(frozen=True)
class WireFormat:
    """Declarative bytes-on-the-wire model for one strategy.

    ``dense`` formats ship the full f32 vector (no index overhead); the
    authoritative dense round time is ``cost_model.uncompressed_round``
    (T = L + V_bits / B). Sparse formats ship ``index_bytes + value_bytes``
    per survivor plus ``overhead_bytes`` per client message (e.g. a
    quantization scale). ``mask_bits`` replaces (or supplements) the
    per-survivor index stream with a length-n bitmask: ``mask_bits`` bits
    per COORDINATE regardless of k — cheaper than idx32 whenever
    k/n > mask_bits/32 (1-bit mask beats 4-byte indices above ~3.1%
    density).
    """
    kind: str                      # human-readable, lands in docs/README
    dense: bool = False
    index_bytes: float = 4.0
    value_bytes: float = 4.0
    overhead_bytes: float = 0.0
    mask_bits: float = 0.0

    def bytes_on_wire(self, n_params: int, k) -> float:
        """Exact payload bytes one client uploads: ``k`` survivors out of
        ``n_params`` (``k`` ignored for dense formats)."""
        if self.dense:
            return 4.0 * n_params
        return (k * (self.index_bytes + self.value_bytes)
                + self.mask_bits * n_params / 8.0 + self.overhead_bytes)

    def cr_eff(self, cr, n_params: Optional[int] = None):
        """Effective ratio to plug into the paper's ``comm_time`` (Alg. 2),
        whose 2x factor prices the reference idx32+f32 pair: the cr that
        makes ``comm_time`` charge exactly this format's bytes-on-the-wire.
        Accepts scalars or numpy arrays (vectorized arithmetic).

        Dense formats return 1.0 — the legacy convention the straggler
        arrival ordering and the traced-sampling scan always used for
        fedavg (authoritative dense *round* accounting goes through
        ``uncompressed_round``, gated on ``wire.dense``). The reference
        sparse pair returns ``cr`` unchanged (bit-identical to the
        pre-registry accounting); packed formats scale it down honestly.
        """
        if self.dense:
            return cr * 0.0 + 1.0 if hasattr(cr, "shape") else 1.0
        pair = self.index_bytes + self.value_bytes
        eff = cr if pair == _REF_PAIR_BYTES else cr * (pair / _REF_PAIR_BYTES)
        if self.mask_bits:
            # n bits of mask == (mask_bits/8) bytes per coordinate: a
            # k-independent constant once normalized by the 8-byte ref pair
            eff = eff + self.mask_bits / (8.0 * _REF_PAIR_BYTES)
        if self.overhead_bytes:
            if not n_params:
                raise ValueError(
                    f"wire format {self.kind!r} has per-message overhead; "
                    "cr_eff needs n_params")
            eff = eff + self.overhead_bytes / (_REF_PAIR_BYTES * n_params)
        return eff


DENSE32 = WireFormat(kind="dense f32", dense=True)
SPARSE32 = WireFormat(kind="idx32 + f32", index_bytes=4.0, value_bytes=4.0)
PACKED_INT8 = WireFormat(kind="idx32 + int8 + scale32",
                         index_bytes=4.0, value_bytes=1.0,
                         overhead_bytes=4.0)
PACKED_INT4 = WireFormat(kind="idx32 + int4 + scale32",
                         index_bytes=4.0, value_bytes=0.5,
                         overhead_bytes=4.0)
BITMASK_INT8 = WireFormat(kind="bitmask + int8 + scale32",
                          index_bytes=0.0, value_bytes=1.0,
                          mask_bits=1.0, overhead_bytes=4.0)
BITMASK_INT4 = WireFormat(kind="bitmask + int4 + scale32",
                          index_bytes=0.0, value_bytes=0.5,
                          mask_bits=1.0, overhead_bytes=4.0)


# ------------------------------------------------------------- value codecs
#: symmetric grids: wire values live in [-levels, levels]
INT8_LEVELS = 127.0
INT4_LEVELS = 7.0
#: kernel-codec name -> quantization grid — the shared source of truth for
#: the jnp codecs below AND the fused_merge kernel codec stage, so the two
#: lowerings cannot drift (docs/DESIGN.md §10)
CODEC_LEVELS = {"int8": INT8_LEVELS, "int4": INT4_LEVELS}


def scale_mantissa_bits(levels: float) -> int:
    """Mantissa bits kept in a symmetric-grid quantizer scale: with the
    quantized magnitude needing ``ceil(log2(levels + 1))`` significand bits,
    keeping ``23 - that`` mantissa bits in the scale makes every
    ``q * scale`` product exactly representable in f32 (product significand
    <= 24 bits). int8 (levels 127) -> 16 bits, int4 (levels 7) -> 20."""
    return 23 - math.ceil(math.log2(levels + 1.0))


def quantization_scale(absmax, levels):
    """Per-row absmax -> the symmetric ``[-levels, levels]`` grid scale.

    Two deliberate deviations from the textbook ``absmax / levels``, both
    in service of bit-identical results across lowerings (the jnp codec
    path runs eagerly; the fused_merge kernel codec stage runs inside jit,
    and the two must agree bit for bit — docs/DESIGN.md §10):

      * multiply by the host-rounded reciprocal instead of dividing:
        XLA:CPU strength-reduces constant-divisor division to a reciprocal
        multiply under jit but not in eager dispatch, a data-dependent
        one-ULP drift between the two contexts. A plain multiply has no
        such transform and is correctly rounded everywhere.

      * round the result (to nearest, ties to even) to
        ``scale_mantissa_bits(levels)`` mantissa bits. That makes every
        ``q * scale`` dequantization product EXACT in f32, so the EF
        residual ``corrected - q*scale`` — an fma-contraction target that
        XLA:CPU demonstrably contracts inside fused loops (select/barrier
        blockers get folded by fast-math codegen) — computes the same value
        contracted or not.

    The combined scale perturbation is <= 2^-16 relative — three orders
    below the int8 grid's own quantization error, and EF absorbs both.
    """
    return lax.reduce_precision(absmax * jnp.float32(1.0 / levels), 8,
                                scale_mantissa_bits(levels))


def symmetric_dequantize(values, scale, levels):
    """quantize-then-dequantize on the symmetric ``[-levels, levels]`` grid
    with a precomputed per-row ``scale`` (broadcastable against ``values``,
    from ``quantization_scale`` — the mantissa rounding there is what makes
    this sequence bit-stable across lowerings).

    This exact op sequence is shared by the jnp codecs and the fused_merge
    kernel codec stage — bit-exactness between the two routes follows from
    running the SAME ops on the SAME scale. An all-zero row has scale 0;
    dividing by the ``where``-guarded 1.0 instead keeps the row exactly
    zero (a ``maximum(scale, eps)`` floor breaks on denormal-flush
    backends, where eps itself flushes to 0).
    """
    safe = jnp.where(scale > 0, scale, jnp.float32(1.0))
    q = jnp.clip(jnp.round(values / safe), -levels, levels)
    return q * scale


def _symmetric_codec(values, levels):
    v = values.astype(jnp.float32)
    axes = tuple(range(1, v.ndim))
    absmax = jnp.max(jnp.abs(v), axis=axes, keepdims=True)
    return symmetric_dequantize(v, quantization_scale(absmax, levels), levels)


def int8_symmetric_codec(values, mask):
    """Per-client symmetric int8 quantization of the surviving values.

    values: [C, ...] dense-masked survivors (rank-agnostic — the scale
    reduces over ALL non-client axes, so per-leaf mesh layouts work
    unreshaped); mask: matching bool (unused — zeros round to exactly zero
    under the symmetric grid, so non-survivors stay zero).

    Returns the DEQUANTIZED f32 values — what the server reconstructs from
    the int8 wire payload. Feeding these to the EF residual update
    (``corrected - sent``) makes error feedback absorb the quantization
    error with no extra engine code.
    """
    del mask
    return _symmetric_codec(values, INT8_LEVELS)


def int4_symmetric_codec(values, mask):
    """Per-client symmetric int4 quantization (15-point grid) of the
    surviving values — same contract as ``int8_symmetric_codec`` at a
    quarter of the value-stream bytes. EF absorbs the (much larger)
    quantization error, which is what keeps the biased low-bit compressor
    sound (CFedAvg, arXiv 2106.07155)."""
    del mask
    return _symmetric_codec(values, INT4_LEVELS)


# ---------------------------------------------------------------- strategy
_CARRIES = ("none", "ef")
_SELECTORS = ("none", "topk")
_WEIGHTINGS = ("data", "bcrs")
_RESIDUAL_LAYOUTS = ("dense", "topk_complement")


@dataclass(frozen=True)
class Strategy:
    """Declarative capability record — see the module docstring for field
    semantics. Frozen + hashable so it can ride as a static jit argument."""
    name: str
    description: str = ""
    carry: str = "none"
    selector: str = "topk"
    value_codec: Optional[Callable] = None
    weighting: str = "data"
    overlap_weighted: bool = False
    wire: WireFormat = field(default=SPARSE32)
    megakernel: bool = True
    residual_layout: str = "dense"
    kernel_codec: Optional[str] = None

    @property
    def compresses(self) -> bool:
        """Whether clients sparsify before upload (drives compression work,
        schedule CRs, and the sparse-vs-dense accounting split)."""
        return self.selector != "none"

    @property
    def needs_residuals(self) -> bool:
        """Whether engines must allocate/thread/donate EF residual buffers."""
        return self.carry == "ef"


# ---------------------------------------------------------------- registry
class StrategyRegistry:
    """Name-keyed table of validated ``Strategy`` records (the builder-
    registry shape of SNIPPETS.md snippet 3, with duplicates refused instead
    of warned — two strategies silently swapping under one name is exactly
    the drift this registry exists to prevent)."""

    def __init__(self):
        self._strategies: dict = {}

    # -- registration ----------------------------------------------------
    def register(self, strategy: Strategy, *,
                 override: bool = False) -> Strategy:
        """Validate and register. Returns the strategy (decorator-friendly).

        Raises ``ValueError`` on duplicate names (unless ``override=True``)
        and on capability combinations no engine can honor — a registration-
        time error beats five engines failing differently at trace time.
        """
        self._validate(strategy)
        if strategy.name in self._strategies and not override:
            raise ValueError(
                f"strategy {strategy.name!r} is already registered "
                f"(registered: {', '.join(self.names())}); pass "
                "override=True to replace it")
        self._strategies[strategy.name] = strategy
        return strategy

    @staticmethod
    def _validate(strategy: Strategy) -> None:
        if not isinstance(strategy, Strategy):
            raise TypeError(f"expected Strategy, got {type(strategy)!r}")
        if not strategy.name or not isinstance(strategy.name, str):
            raise ValueError("strategy needs a non-empty string name")
        if strategy.carry not in _CARRIES:
            raise ValueError(
                f"strategy {strategy.name!r}: unknown carry "
                f"{strategy.carry!r} (one of {_CARRIES})")
        if strategy.selector not in _SELECTORS:
            raise ValueError(
                f"strategy {strategy.name!r}: unknown selector "
                f"{strategy.selector!r} (one of {_SELECTORS})")
        if strategy.weighting not in _WEIGHTINGS:
            raise ValueError(
                f"strategy {strategy.name!r}: unknown weighting "
                f"{strategy.weighting!r} (one of {_WEIGHTINGS})")
        if not isinstance(strategy.wire, WireFormat):
            raise ValueError(
                f"strategy {strategy.name!r}: wire must be a WireFormat, "
                f"got {type(strategy.wire)!r}")
        if strategy.kernel_codec is not None:
            if strategy.kernel_codec not in CODEC_LEVELS:
                raise ValueError(
                    f"strategy {strategy.name!r}: unknown kernel_codec "
                    f"{strategy.kernel_codec!r} (one of "
                    f"{tuple(CODEC_LEVELS)})")
            if strategy.value_codec is None:
                raise ValueError(
                    f"strategy {strategy.name!r}: kernel_codec names the "
                    "kernel lowering of a value_codec — declare the "
                    "value_codec it must stay bit-exact with")
        if strategy.value_codec is not None:
            if not callable(strategy.value_codec):
                raise ValueError(
                    f"strategy {strategy.name!r}: value_codec must be "
                    "callable")
            if strategy.carry != "ef":
                raise ValueError(
                    f"strategy {strategy.name!r}: a lossy value_codec "
                    "requires carry='ef' — without error feedback the "
                    "codec error is silently dropped bias")
            if strategy.megakernel and strategy.kernel_codec is None:
                raise ValueError(
                    f"strategy {strategy.name!r}: a value_codec strategy "
                    "may declare megakernel=True only with a kernel_codec "
                    "(the fused_merge dequantization stage that matches "
                    "its codec — see docs/DESIGN.md §10)")
        if strategy.residual_layout not in _RESIDUAL_LAYOUTS:
            raise ValueError(
                f"strategy {strategy.name!r}: unknown residual_layout "
                f"{strategy.residual_layout!r} (one of {_RESIDUAL_LAYOUTS})")
        if strategy.residual_layout == "topk_complement":
            if strategy.carry != "ef":
                raise ValueError(
                    f"strategy {strategy.name!r}: residual_layout="
                    "'topk_complement' describes EF residuals — requires "
                    "carry='ef'")
            if strategy.selector != "topk":
                raise ValueError(
                    f"strategy {strategy.name!r}: residual_layout="
                    "'topk_complement' holds only the dropped coordinates "
                    "of a Top-K selection — requires selector='topk'")
            if strategy.value_codec is not None:
                raise ValueError(
                    f"strategy {strategy.name!r}: a value_codec leaves "
                    "quantization error at the survivor coordinates, so "
                    "the EF residual is dense — declare "
                    "residual_layout='dense'")
        if strategy.selector == "none":
            if not strategy.wire.dense:
                raise ValueError(
                    f"strategy {strategy.name!r}: selector='none' ships "
                    "every coordinate — declare a dense wire format")
            if strategy.overlap_weighted:
                raise ValueError(
                    f"strategy {strategy.name!r}: overlap weighting needs "
                    "survivor masks — selector='none' has none")
        elif strategy.wire.dense:
            raise ValueError(
                f"strategy {strategy.name!r}: a sparsifying selector with "
                "a dense wire format would misprice every upload")

    def unregister(self, name: str) -> None:
        """Remove a registration (test teardown; built-ins removable too —
        there is nothing special about them)."""
        self._strategies.pop(name, None)

    # -- lookup ----------------------------------------------------------
    def get(self, name: str) -> Strategy:
        try:
            return self._strategies[name]
        except KeyError:
            raise ValueError(
                f"unknown strategy {name!r} (registered: "
                f"{', '.join(self.names())})") from None

    def names(self) -> Tuple[str, ...]:
        return tuple(self._strategies)

    def __contains__(self, name: str) -> bool:
        return name in self._strategies

    def __iter__(self):
        return iter(self._strategies.values())


#: the process-wide registry every engine/CLI/cost model reads
REGISTRY = StrategyRegistry()
register = REGISTRY.register
unregister = REGISTRY.unregister
get = REGISTRY.get
names = REGISTRY.names


# ---------------------------------------------------------------- built-ins
# The paper's five strategies (Alg. 1), re-registered through the public
# API — they get no private hooks, so they double as registration examples.
register(Strategy(
    name="fedavg",
    description="uniform data-weighted average, no compression",
    carry="none", selector="none", weighting="data",
    wire=DENSE32, megakernel=False))

register(Strategy(
    name="topk",
    description="data-weighted average of Top-K-compressed updates",
    carry="none", selector="topk", weighting="data",
    wire=SPARSE32, megakernel=True))

register(Strategy(
    name="eftopk",
    description="Top-K with client-side error-feedback residuals",
    carry="ef", selector="topk", weighting="data",
    wire=SPARSE32, megakernel=True, residual_layout="topk_complement"))

register(Strategy(
    name="bcrs",
    description="per-client CRs from the bandwidth schedule (Alg. 2) "
                "+ Eq. 6 coefficients",
    carry="none", selector="topk", weighting="bcrs",
    wire=SPARSE32, megakernel=True))

register(Strategy(
    name="bcrs_opwa",
    description="BCRS + overlap-aware parameter weighting (Alg. 3)",
    carry="none", selector="topk", weighting="bcrs",
    overlap_weighted=True, wire=SPARSE32, megakernel=True))

# Registry-only plugins (no engine file mentions them): quantized Top-K
# survivors — the FedSparQ sparsity-x-quantization direction. EF absorbs the
# quantization error; the packed wire formats (4+1 / 4+0.5 bytes/survivor +
# one f32 scale) make their comm accounting honest, 8/5x / 16/9x cheaper
# than idx32+f32 at equal sparsity. kernel_codec opts them into the Pallas
# pipeline: fused_merge quantizes/dequantizes in the tile pass with the
# scale threshold_find emitted (docs/DESIGN.md §10).
register(Strategy(
    name="qtopk",
    description="int8-quantized Top-K survivors with EF absorbing the "
                "quantization error; packed-bytes wire accounting",
    carry="ef", selector="topk", value_codec=int8_symmetric_codec,
    weighting="data", wire=PACKED_INT8, megakernel=True,
    kernel_codec="int8"))

register(Strategy(
    name="bitmask_topk",
    description="int8-quantized Top-K survivors shipped under a 1-bit "
                "coordinate bitmask instead of idx32 — cheaper than packed "
                "indices above ~3.1% density, and the built-in that "
                "exercises the BITMASK_* mask-bits pricing end-to-end",
    carry="ef", selector="topk", value_codec=int8_symmetric_codec,
    weighting="data", wire=BITMASK_INT8, megakernel=True,
    kernel_codec="int8"))

register(Strategy(
    name="int4",
    description="int4-quantized Top-K survivors (EF absorbs the error); "
                "idx32+int4 packed wire at 9/16 of the reference pair",
    carry="ef", selector="topk", value_codec=int4_symmetric_codec,
    weighting="data", wire=PACKED_INT4, megakernel=True,
    kernel_codec="int4"))
