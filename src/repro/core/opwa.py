"""Overlap-aware Parameter Weighted Averaging (paper §4.3, Alg. 3).

Degree of overlap of parameter j = number of selected clients whose
sparsified update retained index j. Indices with overlap in (0, D] get their
aggregated update scaled by the enlarge rate gamma; everything else by 1.

The server update (Alg. 1 line 18):
    w_{t+1} = w_t - eta * sum_i p'_i * M ⊙ Δw_i^sparse
with M shared across clients, so aggregation fuses into a single masked
weighted sum — exactly what the ``overlap_combine`` Pallas kernel computes in
one HBM pass.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import resolve_use_kernel


def overlap_counts(masks: jax.Array) -> jax.Array:
    """masks: bool/int [K, n] (K clients) -> int32 counts [n]."""
    return jnp.sum(masks.astype(jnp.int32), axis=0)


def opwa_mask(counts: jax.Array, gamma: float, d: int = 1) -> jax.Array:
    """M[j] = gamma if 0 < counts[j] <= D else 1 (f32 [n])."""
    amplify = (counts > 0) & (counts <= d)
    return jnp.where(amplify, jnp.float32(gamma), jnp.float32(1.0))


def overlap_histogram(masks: jax.Array, k_max: Optional[int] = None
                      ) -> jax.Array:
    """Counts-of-counts for the paper's Fig. 4 (degree-of-overlap dist).

    One ``bincount`` reduction (single pass over counts) instead of K+1
    masked sums; degrees above ``k_max`` are dropped, as before."""
    counts = overlap_counts(masks)
    k_max = k_max or masks.shape[0]
    return jnp.bincount(counts.reshape(-1), length=k_max + 1)


def opwa_aggregate(updates: jax.Array, masks: jax.Array, coeffs: jax.Array,
                   gamma: float, d: int = 1,
                   use_kernel="auto") -> jax.Array:
    """Fused OPWA aggregation (rank-agnostic).

    updates: [K, *shape] dense-masked sparse updates (flat [K, n] from the
    round engines, natural possibly-sharded leaf layout from the mesh/pod
    adapters); masks: matching bool; coeffs: [K] client coefficients p'_i.
    Returns M ⊙ Σ_i p'_i u_i  [*shape]. The Pallas kernel route applies to
    the flat [K, n] layout only.
    """
    if resolve_use_kernel(use_kernel) and updates.ndim == 2:
        from repro.kernels import ops as kops
        return kops.overlap_combine(updates, masks, coeffs, gamma, d)
    counts = overlap_counts(masks)
    m = opwa_mask(counts, gamma, d)
    if updates.ndim == 2:
        weighted = jnp.einsum("k,kn->n", coeffs.astype(jnp.float32),
                              updates.astype(jnp.float32))
    else:
        weighted = jnp.tensordot(coeffs.astype(jnp.float32),
                                 updates.astype(jnp.float32), axes=(0, 0))
    return m * weighted


def opwa_aggregate_traced_k(updates: jax.Array, ks: jax.Array,
                            coeffs: jax.Array, gamma: float, d: int = 1,
                            active: Optional[jax.Array] = None,
                            use_kernel="auto") -> jax.Array:
    """OPWA aggregation fused with traced-k Top-K selection (the paper's
    BCRS+OPWA hot path): updates [K, n] RAW flat client updates, ks [K] i32
    traced retained counts — selection, overlap counts, the gamma mask, and
    the weighted merge happen in one pipeline instead of materializing
    values/masks first.

    Kernel route: the two-kernel Pallas pipeline (``threshold_find`` +
    ``fused_merge``) — 9 logical HBM passes over [K, n] vs ~35 unfused.
    Reference route: ``topk_compress_batch`` + ``opwa_aggregate``,
    bit-identical. ``active`` gates padded cohort rows out of the merge and
    the overlap counts (engine semantics).
    """
    if resolve_use_kernel(use_kernel):
        from repro.kernels import ops as kops
        agg, _ = kops.megakernel_aggregate(
            updates, ks, coeffs, active=active, opwa=True,
            gamma=float(gamma), d=int(d))
        return agg
    from repro.core.compression import topk_compress_batch
    c = topk_compress_batch(updates, ks)
    vals, mask = c.values, c.mask
    if active is not None:
        vals = vals * active[:, None]
        mask = mask & active[:, None]
    return opwa_aggregate(vals, mask, coeffs, gamma, d, use_kernel=False)


def bcrs_aggregate(updates: jax.Array, coeffs: jax.Array) -> jax.Array:
    """BCRS-only aggregation (uniform parameter weights)."""
    return jnp.einsum("k,kn->n", coeffs.astype(jnp.float32),
                      updates.astype(jnp.float32))
