"""Server aggregation strategies (paper Alg. 1).

Strategies consume per-client *flat* updates Δw_i = w_t − w_i (K × n), apply
the chosen compression client-side, and produce the aggregated update the
server subtracts:  w_{t+1} = w_t − η · agg.

  fedavg      uniform data-weighted average, no compression
  topk        data-weighted average of Top-K-compressed updates
  eftopk      topk + client-side error feedback residuals
  bcrs        per-client CRs from bandwidth schedule + Eq. 6 coefficients
  bcrs_opwa   bcrs + overlap-aware parameter mask (Alg. 3)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcrs as bcrs_mod
from repro.core import compression as comp
from repro.core import opwa as opwa_mod


@dataclass
class AggregationConfig:
    strategy: str = "fedavg"       # fedavg | topk | eftopk | bcrs | bcrs_opwa
    cr: float = 0.1                # default/uniform compression ratio CR*
    alpha: float = 1.0             # server lr inside coefficients (Eq. 6)
    gamma: float = 5.0             # OPWA enlarge rate
    overlap_d: int = 1             # OPWA required degree of overlap
    block_topk: bool = False       # use TPU block top-k instead of exact
    block_size: int = 8192
    use_kernel: bool = False       # route through the Pallas kernels


def _compress_fn(acfg: AggregationConfig):
    if acfg.block_topk:
        return lambda u, cr: comp.block_topk_compress(
            u, cr, block=acfg.block_size, use_kernel=acfg.use_kernel)
    return comp.topk_compress


def compress_clients(updates: jax.Array, crs: np.ndarray,
                     acfg: AggregationConfig,
                     residuals: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """updates [K, n] -> (values [K, n], masks [K, n], new_residuals)."""
    fn = _compress_fn(acfg)
    vals, masks, new_res = [], [], []
    for i in range(updates.shape[0]):
        u = updates[i]
        if residuals is not None:
            c, r = comp.ef_compress(residuals[i], u, float(crs[i]),
                                    compress=lambda x, cr: fn(x, cr))
            new_res.append(r)
        else:
            c = fn(u, float(crs[i]))
        vals.append(c.values)
        masks.append(c.mask)
    return (jnp.stack(vals), jnp.stack(masks),
            jnp.stack(new_res) if residuals is not None else None)


def aggregate(updates: jax.Array, data_fracs: np.ndarray,
              acfg: AggregationConfig,
              links=None, v_bytes: float = 0.0,
              residuals: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, dict, Optional[jax.Array]]:
    """Run one server aggregation. Returns (agg [n], info, new_residuals)."""
    k, n = updates.shape
    f = jnp.asarray(data_fracs, jnp.float32)
    info: dict = {"strategy": acfg.strategy}

    if acfg.strategy == "fedavg":
        agg = jnp.einsum("k,kn->n", f, updates.astype(jnp.float32))
        return agg, info, None

    if acfg.strategy in ("topk", "eftopk"):
        crs = np.full((k,), acfg.cr)
        res = residuals if acfg.strategy == "eftopk" else None
        vals, masks, new_res = compress_clients(updates, crs, acfg, res)
        agg = jnp.einsum("k,kn->n", f, vals.astype(jnp.float32))
        info["crs"] = crs
        return agg, info, new_res

    if acfg.strategy in ("bcrs", "bcrs_opwa"):
        assert links is not None and v_bytes > 0, "BCRS needs link models"
        sched = bcrs_mod.make_schedule(links, np.asarray(data_fracs),
                                       v_bytes, acfg.cr, acfg.alpha)
        vals, masks, new_res = compress_clients(updates, sched.crs, acfg,
                                                residuals)
        coeffs = jnp.asarray(sched.coefficients, jnp.float32)
        if acfg.strategy == "bcrs_opwa":
            agg = opwa_mod.opwa_aggregate(vals, masks, coeffs, acfg.gamma,
                                          acfg.overlap_d,
                                          use_kernel=acfg.use_kernel)
        else:
            agg = opwa_mod.bcrs_aggregate(vals, coeffs)
        info["crs"] = sched.crs
        info["coefficients"] = sched.coefficients
        info["t_bench"] = sched.t_bench
        return agg, info, new_res

    raise ValueError(f"unknown strategy {acfg.strategy!r}")
