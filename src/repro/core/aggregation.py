"""Server aggregation strategies (paper Alg. 1).

Strategies consume per-client *flat* updates Δw_i = w_t − w_i (K × n), apply
the chosen compression client-side, and produce the aggregated update the
server subtracts:  w_{t+1} = w_t − η · agg.

Strategies are registered capability records (``repro.core.strategies``) —
this module dispatches on ``compresses`` / ``needs_residuals`` /
``weighting`` / ``overlap_weighted`` / ``value_codec`` and never matches
strategy names, so registry-only strategies (e.g. ``qtopk``) run through the
eager path unchanged. ``strategies.names()`` lists what is available.

The host-side schedule (``round_schedule``) is shared by the eager path here
and the fused jitted round (repro.fed.round_step): per-round CRs/coefficients
stay host-scheduled numpy, everything per-parameter is traced.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcrs as bcrs_mod
from repro.core import compression as comp
from repro.core import opwa as opwa_mod
from repro.core import strategies as strat_mod


@dataclass
class AggregationConfig:
    strategy: str = "fedavg"       # any name in core.strategies.names()
    cr: float = 0.1                # default/uniform compression ratio CR*
    alpha: float = 1.0             # server lr inside coefficients (Eq. 6)
    gamma: float = 5.0             # OPWA enlarge rate
    overlap_d: int = 1             # OPWA required degree of overlap
    block_topk: bool = False       # use TPU block top-k instead of exact
    block_size: int = 8192
    use_kernel: object = "auto"    # Pallas kernels: True | False | "auto"

    def __post_init__(self):
        strat_mod.get(self.strategy)   # config-time error, names listed

    @property
    def strat(self) -> strat_mod.Strategy:
        """The registered capability record for ``strategy``."""
        return strat_mod.get(self.strategy)


# ------------------------------------------------------------- host schedule
def round_schedule(acfg: AggregationConfig, k: int, data_fracs: np.ndarray,
                   links=None, v_bytes: float = 0.0
                   ) -> Tuple[np.ndarray, np.ndarray, dict]:
    """Host-side per-round schedule: (crs [k], agg weights [k], info).

    Dispatches on registry capabilities: non-compressing strategies get
    all-ones CRs with data-fraction weights (and no "crs" info key, so the
    server's time accounting takes the dense route exactly as before);
    "data"-weighted compressors get the uniform CR*; "bcrs"-weighted ones
    get the bandwidth schedule's CRs and Eq. 6 coefficients.
    """
    strat = acfg.strat
    info: dict = {"strategy": acfg.strategy}
    f = np.asarray(data_fracs, np.float64)
    if not strat.compresses:
        return np.ones((k,)), f, info
    if strat.weighting == "data":
        crs = np.full((k,), acfg.cr)
        info["crs"] = crs
        return crs, f, info
    assert links is not None and v_bytes > 0, "BCRS needs link models"
    sched = bcrs_mod.make_schedule(links, f, v_bytes, acfg.cr, acfg.alpha)
    info["crs"] = sched.crs
    info["coefficients"] = sched.coefficients
    info["t_bench"] = sched.t_bench
    return sched.crs, sched.coefficients, info


def ks_for_schedule(n: int, crs: np.ndarray, acfg: AggregationConfig
                    ) -> np.ndarray:
    """Per-client retained counts for the traced compressors. Computed on
    host in f64 so they match the legacy per-client ``k_for_ratio`` exactly
    (block mode: k per block of ``block_size``)."""
    base = acfg.block_size if acfg.block_topk else n
    return np.asarray([comp.k_for_ratio(base, float(c)) for c in crs],
                      np.int32)


def overlap_ks(acfg: AggregationConfig, info: dict, k: int, n: int
               ) -> np.ndarray:
    """Per-client GLOBAL top-k counts for the Fig. 4 overlap instrumentation
    (mirrors the legacy host-side fallback): schedule CRs when the strategy
    has them, else the configured CR* — fedavg's schedule crs are all-ones
    and would make the histogram degenerate. Shared by the fused round
    server and the scan plan builder so the two engines' histograms agree
    structurally."""
    crs_overlap = info.get("crs", np.full(k, acfg.cr))
    return np.asarray([comp.k_for_ratio(n, float(c)) for c in crs_overlap],
                      np.int32)


# ------------------------------------------------------- client compression
def _compress_fn(acfg: AggregationConfig):
    if acfg.block_topk:
        base = lambda u, cr: comp.block_topk_compress(
            u, cr, block=acfg.block_size, use_kernel=acfg.use_kernel)
    else:
        base = comp.topk_compress
    codec = acfg.strat.value_codec
    if codec is None:
        return base

    def fn(u, cr):
        c = base(u, cr)
        # the codec contract is batched ([C, ...] leading client axis);
        # single-client callers add/strip it here
        return comp.Compressed(codec(c.values[None], c.mask[None])[0],
                               c.mask)

    return fn


@functools.partial(jax.jit, static_argnames=("block", "codec"))
def _compress_batch(updates, ks, residuals, block, codec=None):
    fn = (comp.topk_compress_batch if block is None else
          functools.partial(comp.block_topk_compress_batch, block=block))
    if codec is not None:
        base = fn
        fn = lambda u, k_: (lambda c: comp.Compressed(
            codec(c.values, c.mask), c.mask))(base(u, k_))
    if residuals is None:
        c = fn(updates, ks)
        return c.values, c.mask, None
    c, new_res = comp.ef_compress_batch(residuals, updates, ks,
                                        compress_batch=fn)
    return c.values, c.mask, new_res


def compress_clients(updates: jax.Array, crs: np.ndarray,
                     acfg: AggregationConfig,
                     residuals: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """updates [K, n] -> (values [K, n], masks [K, n], new_residuals).

    One compiled program with *traced* per-client k — any BCRS schedule
    reuses the same executable (the legacy loop re-lowered ``lax.top_k``
    per distinct static CR). Kernel-backed block top-k keeps the loop path
    (the Pallas kernel wants a static k); everything else is vectorized.
    A registered ``value_codec`` rides along as a static arg (module-level
    functions hash stably, so the jit cache stays warm).
    """
    if acfg.block_topk and comp.resolve_use_kernel(acfg.use_kernel):
        return compress_clients_loop(updates, crs, acfg, residuals)
    ks = jnp.asarray(ks_for_schedule(updates.shape[1], crs, acfg))
    block = acfg.block_size if acfg.block_topk else None
    return _compress_batch(updates, ks, residuals, block,
                           acfg.strat.value_codec)


def compress_clients_loop(updates: jax.Array, crs: np.ndarray,
                          acfg: AggregationConfig,
                          residuals: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Legacy per-client loop (static-CR compressors). Kept as the parity
    reference for the vectorized path and as the route to the static-k
    Pallas block-top-k kernel."""
    fn = _compress_fn(acfg)
    vals, masks, new_res = [], [], []
    for i in range(updates.shape[0]):
        u = updates[i]
        if residuals is not None:
            c, r = comp.ef_compress(residuals[i], u, float(crs[i]),
                                    compress=lambda x, cr: fn(x, cr))
            new_res.append(r)
        else:
            c = fn(u, float(crs[i]))
        vals.append(c.values)
        masks.append(c.mask)
    return (jnp.stack(vals), jnp.stack(masks),
            jnp.stack(new_res) if residuals is not None else None)


# ------------------------------------------------------------- eager rounds
def aggregate(updates: jax.Array, data_fracs: np.ndarray,
              acfg: AggregationConfig,
              links=None, v_bytes: float = 0.0,
              residuals: Optional[jax.Array] = None,
              use_loop: bool = False
              ) -> Tuple[jax.Array, dict, Optional[jax.Array]]:
    """Run one server aggregation. Returns (agg [n], info, new_residuals).

    ``use_loop=True`` compresses via the legacy per-client static-CR loop
    (the seed behavior the fused round is benchmarked against); the default
    is the single-executable traced-k path.
    """
    strat = acfg.strat
    k, n = updates.shape
    crs, weights, info = round_schedule(acfg, k, data_fracs, links, v_bytes)
    coeffs = jnp.asarray(weights, jnp.float32)

    if not strat.compresses:
        agg = jnp.einsum("k,kn->n", coeffs, updates.astype(jnp.float32))
        return agg, info, None

    compress = compress_clients_loop if use_loop else compress_clients
    res = residuals if strat.needs_residuals else None
    vals, masks, new_res = compress(updates, crs, acfg, res)
    if strat.overlap_weighted:
        agg = opwa_mod.opwa_aggregate(vals, masks, coeffs, acfg.gamma,
                                      acfg.overlap_d,
                                      use_kernel=acfg.use_kernel)
    else:
        agg = jnp.einsum("k,kn->n", coeffs, vals.astype(jnp.float32))
    return agg, info, new_res
