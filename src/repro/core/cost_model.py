"""Round-time accounting for the simulated FL network (paper §5.2).

Clients get normally-distributed bandwidth (mean 1 Mbit/s, sd 0.2) and
uniform latency in [50ms, 200ms]. Three accumulated metrics match the paper:
Actual / Max (straggler) / Min communication time per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.bcrs import ClientLink, comm_time


@dataclass(frozen=True)
class LinkArrays:
    """Column-major link table: the population-scale twin of a
    ``List[ClientLink]``. Keeps bandwidth/latency as float64 arrays so
    cohort planning indexes O(C) numpy slices (``bandwidth_bps[ids]``)
    instead of touching P Python objects, while ``links[i]`` still yields a
    ``ClientLink`` for the per-client accounting paths."""
    bandwidth_bps: np.ndarray
    latency_s: np.ndarray

    def __len__(self) -> int:
        return self.bandwidth_bps.shape[0]

    def __getitem__(self, i) -> ClientLink:
        return ClientLink(bandwidth_bps=float(self.bandwidth_bps[i]),
                          latency_s=float(self.latency_s[i]))

    def __iter__(self):
        for i in range(len(self)):
            yield self[i]

    def take(self, ids) -> "LinkArrays":
        return LinkArrays(self.bandwidth_bps[ids], self.latency_s[ids])


def sample_link_arrays(n: int, rng: np.random.Generator,
                       bw_mean_mbps: float = 1.0, bw_sd_mbps: float = 0.2,
                       lat_lo: float = 0.05, lat_hi: float = 0.2
                       ) -> LinkArrays:
    """Array-form ``sample_links``: identical rng draws, identical values
    (``sample_links(n, rng)[i] == sample_link_arrays(n, rng)[i]`` for equal
    generator states), but O(1) Python objects for P up to 10^6."""
    bw = np.maximum(rng.normal(bw_mean_mbps, bw_sd_mbps, n), 0.05) * 1e6
    lat = rng.uniform(lat_lo, lat_hi, n)
    return LinkArrays(bandwidth_bps=bw, latency_s=lat)


def sample_links(n: int, rng: np.random.Generator,
                 bw_mean_mbps: float = 1.0, bw_sd_mbps: float = 0.2,
                 lat_lo: float = 0.05, lat_hi: float = 0.2) -> List[ClientLink]:
    la = sample_link_arrays(n, rng, bw_mean_mbps, bw_sd_mbps, lat_lo, lat_hi)
    return list(la)


@dataclass
class RoundTime:
    actual: float       # equalized/actual upload duration this round
    max: float          # straggler (slowest client) duration
    min: float          # fastest client duration


@dataclass
class TimeAccumulator:
    actual: float = 0.0
    max: float = 0.0
    min: float = 0.0
    per_round: List[RoundTime] = field(default_factory=list)

    def add(self, rt: RoundTime) -> None:
        self.actual += rt.actual
        self.max += rt.max
        self.min += rt.min
        self.per_round.append(rt)


def round_times(links: Sequence[ClientLink], v_bytes: float,
                crs: Sequence[float]) -> RoundTime:
    """Per-round times given each client's CR (uniform CR -> pass a constant
    list; BCRS -> the scheduled list, whose times are ~equal by design)."""
    ts = [comm_time(v_bytes, l, c) for l, c in zip(links, crs)]
    return RoundTime(actual=float(np.max(ts)), max=float(np.max(ts)),
                     min=float(np.min(ts)))


def uncompressed_round(links: Sequence[ClientLink], v_bytes: float) -> RoundTime:
    # dense transmission: no index overhead -> T = L + V/B
    ts = [l.latency_s + 8.0 * v_bytes / l.bandwidth_bps for l in links]
    return RoundTime(actual=float(np.max(ts)), max=float(np.max(ts)),
                     min=float(np.min(ts)))


# ------------------------------------------------------ fault-tolerant uploads
@dataclass(frozen=True)
class RetryPolicy:
    """Retry discipline for a single client upload in the async engine.

    An attempt that fails mid-transfer is resumed from its byte offset after
    an exponential backoff (``backoff_s * backoff_factor**(attempt-1)``), so
    the payload crosses the wire exactly once no matter how many attempts it
    takes — only the per-attempt latency and the backoff sleeps are re-paid.
    ``timeout_s`` is a hard wall-clock deadline measured from dispatch;
    ``max_attempts`` caps the retries. Either bound aborts the upload."""
    max_attempts: int = 3
    backoff_s: float = 0.5
    backoff_factor: float = 2.0
    timeout_s: float = float("inf")


def upload_time_with_retries(link: ClientLink, v_bytes: float, cr: float,
                             fail_fracs: Sequence[float],
                             policy: RetryPolicy) -> "UploadOutcome":
    """Resolve one upload's full timeline given its failure draw.

    ``fail_fracs[j]`` is the fraction of the *remaining* payload transferred
    before attempt ``j+1`` failed; attempts beyond ``len(fail_fracs)`` run
    clean. With resume-from-offset the transfer term ``2*V_bits*cr/B`` is
    paid once total, split across attempts; latency is paid per attempt and
    backoff between attempts. The outcome is clipped against
    ``policy.timeout_s`` (timed out mid-flight) and ``policy.max_attempts``
    (aborted after the last failure's backoff is NOT waited out)."""
    v_bits = 8.0 * v_bytes
    transfer_s = 2.0 * v_bits * cr / link.bandwidth_bps
    t = 0.0
    progress = 0.0            # fraction of the payload already delivered
    for attempt in range(1, policy.max_attempts + 1):
        remaining_s = (1.0 - progress) * transfer_s
        if attempt <= len(fail_fracs):
            frac = float(fail_fracs[attempt - 1])
            t_fail = t + link.latency_s + frac * remaining_s
            progress += frac * (1.0 - progress)
            if t_fail >= policy.timeout_s:
                return UploadOutcome(arrived=False, t_resolve=policy.timeout_s,
                                     attempts=attempt, progress=progress,
                                     timed_out=True)
            if attempt == policy.max_attempts:
                return UploadOutcome(arrived=False, t_resolve=t_fail,
                                     attempts=attempt, progress=progress,
                                     timed_out=False)
            t = t_fail + policy.backoff_s * policy.backoff_factor ** (attempt - 1)
            if t >= policy.timeout_s:
                return UploadOutcome(arrived=False, t_resolve=policy.timeout_s,
                                     attempts=attempt, progress=progress,
                                     timed_out=True)
        else:
            t_done = t + link.latency_s + remaining_s
            if t_done > policy.timeout_s:
                return UploadOutcome(arrived=False, t_resolve=policy.timeout_s,
                                     attempts=attempt, progress=progress,
                                     timed_out=True)
            return UploadOutcome(arrived=True, t_resolve=t_done,
                                 attempts=attempt, progress=1.0,
                                 timed_out=False)
    # unreachable: the loop always returns by attempt == max_attempts
    raise AssertionError("retry loop fell through")  # pragma: no cover


@dataclass(frozen=True)
class UploadOutcome:
    """Resolved timeline of one upload: did it land, when, after how many
    attempts, and how much of the payload made it across the wire."""
    arrived: bool
    t_resolve: float          # seconds after dispatch
    attempts: int
    progress: float           # delivered payload fraction in [0, 1]
    timed_out: bool
