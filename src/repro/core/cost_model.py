"""Round-time accounting for the simulated FL network (paper §5.2).

Clients get normally-distributed bandwidth (mean 1 Mbit/s, sd 0.2) and
uniform latency in [50ms, 200ms]. Three accumulated metrics match the paper:
Actual / Max (straggler) / Min communication time per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.core.bcrs import ClientLink, comm_time


def sample_links(n: int, rng: np.random.Generator,
                 bw_mean_mbps: float = 1.0, bw_sd_mbps: float = 0.2,
                 lat_lo: float = 0.05, lat_hi: float = 0.2) -> List[ClientLink]:
    bw = np.maximum(rng.normal(bw_mean_mbps, bw_sd_mbps, n), 0.05) * 1e6
    lat = rng.uniform(lat_lo, lat_hi, n)
    return [ClientLink(bandwidth_bps=float(b), latency_s=float(l))
            for b, l in zip(bw, lat)]


@dataclass
class RoundTime:
    actual: float       # equalized/actual upload duration this round
    max: float          # straggler (slowest client) duration
    min: float          # fastest client duration


@dataclass
class TimeAccumulator:
    actual: float = 0.0
    max: float = 0.0
    min: float = 0.0
    per_round: List[RoundTime] = field(default_factory=list)

    def add(self, rt: RoundTime) -> None:
        self.actual += rt.actual
        self.max += rt.max
        self.min += rt.min
        self.per_round.append(rt)


def round_times(links: Sequence[ClientLink], v_bytes: float,
                crs: Sequence[float]) -> RoundTime:
    """Per-round times given each client's CR (uniform CR -> pass a constant
    list; BCRS -> the scheduled list, whose times are ~equal by design)."""
    ts = [comm_time(v_bytes, l, c) for l, c in zip(links, crs)]
    return RoundTime(actual=float(np.max(ts)), max=float(np.max(ts)),
                     min=float(np.min(ts)))


def uncompressed_round(links: Sequence[ClientLink], v_bytes: float) -> RoundTime:
    # dense transmission: no index overhead -> T = L + V/B
    ts = [l.latency_s + 8.0 * v_bytes / l.bandwidth_bps for l in links]
    return RoundTime(actual=float(np.max(ts)), max=float(np.max(ts)),
                     min=float(np.min(ts)))
