"""Update/gradient compressors: exact Top-K, block Top-K (TPU-native),
Rand-K, stochastic quantization, and error-feedback wrappers.

All compressors operate on flat f32/bf16 vectors; ``flatten_tree`` /
``unflatten_tree`` move between pytrees and vectors. The dense-masked
representation (values kept, others zero + bool mask) is bit-exact with the
paper's simulation; ``to_sparse``/``from_sparse`` give the (indices, values)
wire format whose byte count the cost model and the compressed pod-sync use.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class Compressed(NamedTuple):
    values: jax.Array   # dense masked vector [n]
    mask: jax.Array     # bool [n]


# ---------------------------------------------------------------- tree utils
def flatten_tree(tree) -> Tuple[jax.Array, Callable]:
    flat, unravel = ravel_pytree(tree)
    return flat, unravel


def k_for_ratio(n: int, cr: float) -> int:
    """Host-side retained count for compression ratio ``cr`` over ``n``
    parameters: round(n·cr) clamped to [1, n] (CR=1 keeps everything
    exactly). The ONE place the rounding rule lives — the traced twin below
    must mirror any change, and every scheduler/engine routes through one of
    the two (duplicating the clip/round inline is a silent-drift hazard)."""
    return max(1, min(n, int(round(n * cr))))


def k_for_ratio_traced(n: int, crs: jax.Array) -> jax.Array:
    """Traced twin of ``k_for_ratio`` for in-jit per-client/per-pod CRs:
    crs (any shape, traced f32) -> i32 retained counts, same
    clip(round(cr·n), 1, n) rule. ``n`` stays static (it is a leaf size).

    The host variant rounds in f64, this one in f32 — for the CR grids the
    schedulers emit the two agree exactly (asserted in tests); keep ratios
    away from .5/n boundaries if bit-parity with host scheduling matters.
    """
    return jnp.clip(jnp.round(crs.astype(jnp.float32) * n).astype(jnp.int32),
                    1, n)


def resolve_use_kernel(flag) -> bool:
    """``use_kernel`` tri-state: True / False / "auto" (Pallas on TPU,
    XLA reference elsewhere — same detection as dist.grad_sync)."""
    if flag == "auto":
        return jax.devices()[0].platform == "tpu"
    return bool(flag)


# ------------------------------------------------------------------- top-k
def topk_compress(u: jax.Array, cr: float) -> Compressed:
    """Exact global magnitude Top-K. u: flat [n]."""
    n = u.shape[0]
    k = k_for_ratio(n, cr)
    mag = jnp.abs(u.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][-1]
    mask = mag >= thresh
    # tie-break: keep at most k (ties at threshold may exceed k; the paper's
    # torch impl keeps exactly k — we keep ties, a <1e-6 measure difference
    # documented in tests)
    return Compressed(jnp.where(mask, u, 0), mask)


def block_topk_compress(u: jax.Array, cr: float, block: int = 8192,
                        use_kernel="auto") -> Compressed:
    """Per-block magnitude Top-K (TPU adaptation; see docs/DESIGN.md §2).

    Pads to a block multiple; each block keeps its own top ``cr`` fraction,
    preserving the global compression ratio exactly while keeping selection
    inside VMEM-sized tiles.
    """
    if resolve_use_kernel(use_kernel):
        from repro.kernels import ops as kops
        return kops.block_topk(u, cr, block=block)
    n = u.shape[0]
    n_pad = (-n) % block
    up = jnp.pad(u, (0, n_pad))
    nb = up.shape[0] // block
    ub = up.reshape(nb, block)
    k = k_for_ratio(block, cr)
    mag = jnp.abs(ub.astype(jnp.float32))
    thresh = jax.lax.top_k(mag, k)[0][:, -1:]
    mask = mag >= thresh
    vals = jnp.where(mask, ub, 0).reshape(-1)[:n]
    return Compressed(vals, mask.reshape(-1)[:n])


def topk_compress_dynamic(u: jax.Array, k: jax.Array,
                          n_iters: int = 32) -> Compressed:
    """Top-K with a *traced* k (per-client BCRS ratios under vmap).

    Threshold bisection on the f32 *bit pattern* of |u|: non-negative IEEE
    floats order identically to their unsigned bit patterns, so bisecting the
    integer interval pins the exact k-th-largest magnitude in <= 32 halvings
    regardless of scale (a value-space bisection needs ~40 iterations and
    still loses exactness when the threshold is denormal-small, e.g. CR→1).
    The mask equals the exact ``|u| >= k-th largest`` selection (ties kept).

    This is the ONE Top-K selection in the tree — every engine (fused round,
    scanned simulation, mesh round, pod sync) routes here through
    ``repro.fed.engine``. Rank-agnostic: reductions run over ALL axes of
    ``u``, so a leaf in its natural (possibly TP-sharded) layout selects
    without being reshaped or gathered.
    """
    mag = jnp.abs(u.astype(jnp.float32))
    bits = jax.lax.bitcast_convert_type(mag, jnp.uint32)
    hi = jnp.max(bits) + 1          # invariant: count(bits >= hi) < k
    lo = jnp.zeros_like(hi)         # invariant: count(bits >= lo) >= k

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + ((hi - lo) >> 1)
        cnt = jnp.sum(bits >= mid)
        pred = cnt >= k
        return jnp.where(pred, mid, lo), jnp.where(pred, hi, mid)

    lo, _ = jax.lax.fori_loop(0, n_iters, body, (lo, hi))
    mask = bits >= lo
    return Compressed(jnp.where(mask, u, 0), mask)


# ------------------------------------------------- batched traced-k top-k
def topk_compress_batch(updates: jax.Array, ks: jax.Array,
                        use_kernel: bool = False) -> Compressed:
    """Per-row dynamic Top-K: updates [K, n], ks int32 [K] (traced).

    One trace serves every BCRS schedule — the per-client ``float(cr)``
    static-arg retrace this replaces cost O(rounds × K) XLA compiles.
    ``use_kernel=True`` finds the thresholds through the Pallas
    ``threshold_find`` kernel (8 streamed HBM sweeps instead of 32 unfused
    bisection passes) and applies them in one more pass — bit-identical
    masks/values, same traced-k contract."""
    if use_kernel:
        from repro.kernels import ops as kops
        th = kops.topk_thresholds(updates, ks)
        bits = jax.lax.bitcast_convert_type(
            jnp.abs(updates.astype(jnp.float32)), jnp.uint32)
        mask = bits >= th[:, None]
        return Compressed(jnp.where(mask, updates, 0), mask)
    return jax.vmap(topk_compress_dynamic)(updates, ks)


def block_topk_compress_batch(updates: jax.Array, ks_block: jax.Array,
                              block: int = 8192) -> Compressed:
    """Per-row *blockwise* dynamic Top-K: each client keeps its top
    ``ks_block[i]`` entries per ``block``-sized tile (traced k)."""
    c, n = updates.shape
    n_pad = (-n) % block
    ub = jnp.pad(updates, ((0, 0), (0, n_pad))).reshape(c, -1, block)
    per_block = jax.vmap(lambda u, k: jax.vmap(
        lambda b: topk_compress_dynamic(b, k))(u))
    comp = per_block(ub, ks_block)
    return Compressed(comp.values.reshape(c, -1)[:, :n],
                      comp.mask.reshape(c, -1)[:, :n])


def ef_compress_batch(residuals: jax.Array, updates: jax.Array,
                      ks: jax.Array,
                      compress_batch: Callable = topk_compress_batch,
                      use_kernel: bool = False
                      ) -> Tuple[Compressed, jax.Array]:
    """Batched EF-TopK: bit-compatible with a per-client ``ef_compress``
    loop (same corrected/send/residual arithmetic, vectorized).
    ``use_kernel=True`` (global Top-K only) selects on
    ``residuals + updates`` through the Pallas threshold kernel without
    materializing the corrected array once per bisection step; combining it
    with a non-global ``compress_batch`` is a loud error, not a silent
    semantic switch."""
    if use_kernel:
        if compress_batch is not topk_compress_batch:
            raise ValueError(
                "ef_compress_batch(use_kernel=True) implements global Top-K "
                "selection only — it cannot honor a custom compress_batch "
                f"({getattr(compress_batch, '__name__', compress_batch)}); "
                "pass use_kernel=False for block/other compressors")
        from repro.kernels import ops as kops
        th = kops.topk_thresholds(updates, ks, residuals=residuals)
        corrected = residuals + updates
        bits = jax.lax.bitcast_convert_type(
            jnp.abs(corrected.astype(jnp.float32)), jnp.uint32)
        mask = bits >= th[:, None]
        vals = jnp.where(mask, corrected, 0)
        return Compressed(vals, mask), corrected - vals
    corrected = residuals + updates
    comp = compress_batch(corrected, ks)
    return comp, corrected - comp.values


def randk_compress(u: jax.Array, cr: float, key) -> Compressed:
    n = u.shape[0]
    k = k_for_ratio(n, cr)
    idx = jax.random.choice(key, n, (k,), replace=False)
    mask = jnp.zeros((n,), bool).at[idx].set(True)
    # unbiased rand-k rescales by n/k
    return Compressed(jnp.where(mask, u * (n / k), 0), mask)


def quantize_stochastic(u: jax.Array, bits: int, key) -> jax.Array:
    """QSGD-style stochastic uniform quantization (dense; no mask)."""
    levels = 2 ** (bits - 1) - 1
    scale = jnp.max(jnp.abs(u)) / levels
    scaled = u / jnp.maximum(scale, 1e-12)
    lower = jnp.floor(scaled)
    p = scaled - lower
    rnd = jax.random.uniform(key, u.shape)
    q = lower + (rnd < p)
    return q * scale


# ------------------------------------------------------------ error feedback
def ef_compress(residual: jax.Array, u: jax.Array, cr: float,
                compress=topk_compress) -> Tuple[Compressed, jax.Array]:
    """EF-TopK (EFSGD): accumulate residual, compress the corrected update,
    keep what was not sent. Returns (compressed, new_residual)."""
    corrected = residual + u
    comp = compress(corrected, cr)
    new_residual = corrected - comp.values
    return comp, new_residual


# ------------------------------------------------------------ sparse format
def to_sparse(comp: Compressed, k: int) -> Tuple[jax.Array, jax.Array]:
    """Dense-masked -> (indices i32 [k], values [k]) wire format. ``k`` must
    be static; entries beyond the actual retained count are index=-1."""
    mag = jnp.where(comp.mask, jnp.abs(comp.values.astype(jnp.float32)), -1.0)
    _, idx = jax.lax.top_k(mag, k)
    valid = jnp.take(comp.mask, idx)
    vals = jnp.take(comp.values, idx) * valid.astype(comp.values.dtype)
    return jnp.where(valid, idx, -1).astype(jnp.int32), vals


def from_sparse(indices: jax.Array, values: jax.Array, n: int) -> jax.Array:
    """(indices, values) -> dense [n]; index -1 entries dropped."""
    safe_idx = jnp.where(indices >= 0, indices, 0)
    contrib = jnp.where(indices >= 0, values, 0)
    return jnp.zeros((n,), values.dtype).at[safe_idx].add(contrib)


COMPRESSORS = {
    "topk": topk_compress,
    "blocktopk": block_topk_compress,
}
