"""Bandwidth-aware Compression Ratio Scheduling (paper Alg. 2 + Eq. 6).

Given per-client links (bandwidth B_i, latency L_i) and an update of V bytes,
the slowest client's post-compression time under the default ratio CR* sets
the benchmark T_bench; every other client's CR is raised to finish at the
same moment:  CR_i = (T_bench - L_i) * B_i / (2 V).

Client-averaging coefficients (Eq. 6):
    p'_i = f_i / max(f_i, Norm(CR_i)) * alpha
The paper leaves Norm() unspecified; we default to sum-normalization
(same scale as the data fractions f_i) and expose the hook.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ClientLink:
    bandwidth_bps: float     # bits per second
    latency_s: float


def comm_time(v_bytes: float, link: ClientLink, cr: float) -> float:
    """Paper cost model (Alg. 2 line 7): T = L + 2 * V * CR / B.

    V in *bits* on the wire; the 2x covers the sparse-format index overhead
    (int32 index + f32 value per retained parameter at fp32 -> 2x values).
    """
    v_bits = 8.0 * v_bytes
    return link.latency_s + 2.0 * v_bits * cr / link.bandwidth_bps


def comm_time_batch(v_bytes: float, bandwidths_bps: np.ndarray,
                    latencies_s: np.ndarray, crs) -> np.ndarray:
    """Vectorized ``comm_time`` over link arrays (population-scale cohort
    planning). Elementwise float64 with the same operation order as the
    scalar form, so ``comm_time_batch(v, bw, lat, cr)[i]`` is bit-identical
    to ``comm_time(v, ClientLink(bw[i], lat[i]), cr_i)`` — the host-side
    planners can vectorize without perturbing committed golden times."""
    bw = np.asarray(bandwidths_bps, np.float64)
    lat = np.asarray(latencies_s, np.float64)
    v_bits = 8.0 * v_bytes
    return lat + 2.0 * v_bits * np.asarray(crs, np.float64) / bw


def schedule_crs(links: Sequence[ClientLink], v_bytes: float, cr_star: float,
                 cr_max: float = 1.0) -> np.ndarray:
    """Alg. 2: equalize upload completion times at the slowest client's pace."""
    times = np.array([comm_time(v_bytes, l, cr_star) for l in links])
    t_bench = float(times.max())
    v_bits = 8.0 * v_bytes
    crs = np.array([(t_bench - l.latency_s) * l.bandwidth_bps / (2.0 * v_bits)
                    for l in links])
    return np.clip(crs, cr_star, cr_max)


def norm_sum(crs: np.ndarray) -> np.ndarray:
    s = crs.sum()
    return crs / s if s > 0 else crs


def client_coefficients(data_fracs: np.ndarray, crs: np.ndarray, alpha: float,
                        norm: Callable[[np.ndarray], np.ndarray] = norm_sum
                        ) -> np.ndarray:
    """Eq. 6: p'_i = f_i / max(f_i, Norm(CR_i)) * alpha (capped at alpha)."""
    ncr = norm(crs)
    return data_fracs / np.maximum(data_fracs, ncr) * alpha


def staleness_discount(weights: np.ndarray, staleness: np.ndarray,
                       alpha: float) -> np.ndarray:
    """FedBuff-style staleness discount on averaging coefficients:
    ``w_i / (1 + s_i)^alpha`` where ``s_i`` is how many server versions
    elapsed between the client's dispatch and its merge. Lives next to the
    Eq. 6 coefficient math because it composes with it: the async buffered
    engine feeds BCRS/data coefficients through this before the merge.
    ``alpha = 0`` is the identity (discount disabled); larger alpha
    downweights stale updates harder. Monotone non-increasing in staleness
    for alpha >= 0 (asserted in tests/test_async_engine.py)."""
    w = np.asarray(weights, np.float64)
    s = np.asarray(staleness, np.float64)
    return w / np.power(1.0 + s, alpha)


@dataclass
class BCRSSchedule:
    crs: np.ndarray           # per-client compression ratio
    coefficients: np.ndarray  # per-client averaging coefficient p'_i
    t_bench: float            # equalized round upload time (seconds)


def make_schedule(links: Sequence[ClientLink], data_fracs: np.ndarray,
                  v_bytes: float, cr_star: float, alpha: float,
                  cr_max: float = 1.0) -> BCRSSchedule:
    crs = schedule_crs(links, v_bytes, cr_star, cr_max)
    coef = client_coefficients(np.asarray(data_fracs, np.float64), crs, alpha)
    t_bench = max(comm_time(v_bytes, l, cr_star) for l in links)
    return BCRSSchedule(crs=crs, coefficients=coef, t_bench=t_bench)


# ------------------------------------------------------- vectorized (R rounds)
def schedule_crs_batch(bandwidths_bps: np.ndarray, latencies_s: np.ndarray,
                       v_bytes: float, cr_star: float, cr_max: float = 1.0,
                       active: np.ndarray | None = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Alg. 2 vectorized over rounds: stacked [R, C] link arrays -> CRs.

    Per-round drivers used to call ``make_schedule`` once per round inside
    the training loop; the scanned mesh driver precomputes every round's
    schedule as xs arrays, so the whole R-round CR plan is one numpy
    broadcast here. ``active`` masks padded cohort slots out of the
    benchmark-time max (their crs are still filled elementwise; callers gate
    them with the same mask). Elementwise arithmetic and reduction order
    match the scalar ``schedule_crs`` exactly, so a row of this equals
    ``schedule_crs`` over that round's selected links bit-for-bit.

    Returns (crs [R, C], t_bench [R]).
    """
    bw = np.asarray(bandwidths_bps, np.float64)
    lat = np.asarray(latencies_s, np.float64)
    v_bits = 8.0 * v_bytes
    times = lat + 2.0 * v_bits * cr_star / bw
    if active is not None:
        times = np.where(active, times, -np.inf)
    t_bench = times.max(axis=-1, keepdims=True)
    crs = (t_bench - lat) * bw / (2.0 * v_bits)
    return np.clip(crs, cr_star, cr_max), t_bench[..., 0]


def make_schedule_batch(bandwidths_bps: np.ndarray, latencies_s: np.ndarray,
                        data_fracs: np.ndarray, v_bytes: float,
                        cr_star: float, alpha: float, cr_max: float = 1.0,
                        active: np.ndarray | None = None,
                        norm: Callable[[np.ndarray], np.ndarray] = norm_sum
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized ``make_schedule`` over R rounds of (possibly padded)
    cohorts. All inputs [R, C]; ``active`` marks real cohort slots (padded
    slots must carry harmless bw/lat placeholders — their crs/coefficients
    come back as 0). Row r is bit-exact with
    ``make_schedule(links_r, fracs_r, ...)`` over that round's active prefix
    (the Eq. 6 normalization runs on exactly the active slice).

    Returns (crs [R, C], coefficients [R, C], t_bench [R]).
    """
    fr = np.asarray(data_fracs, np.float64)
    crs, t_bench = schedule_crs_batch(bandwidths_bps, latencies_s, v_bytes,
                                      cr_star, cr_max, active=active)
    coeffs = np.zeros_like(crs)
    for r in range(crs.shape[0]):
        sel = (slice(None) if active is None
               else np.flatnonzero(active[r]))
        coeffs[r, sel] = client_coefficients(fr[r, sel], crs[r, sel],
                                             alpha, norm)
    if active is not None:
        crs = np.where(active, crs, 0.0)
    return crs, coeffs, t_bench


def pod_link_schedule(dcn_bandwidths_gbps: Sequence[float], v_bytes: float,
                      cr_star: float, latency_s: float = 1e-3,
                      cr_max: float = 0.5) -> np.ndarray:
    """Hierarchical (beyond-paper) variant: per-pod DCN links get CRs from the
    same Alg. 2 schedule — slow pods compress harder, fast pods send more."""
    links = [ClientLink(b * 1e9 * 8, latency_s) for b in dcn_bandwidths_gbps]
    return schedule_crs(links, v_bytes, cr_star, cr_max)
