from repro.optim.optimizers import (Optimizer, adamw, clip_by_global_norm,
                                    make_optimizer, momentum, sgd)
from repro.optim.schedules import constant, cosine

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "make_optimizer",
           "clip_by_global_norm", "constant", "cosine"]
