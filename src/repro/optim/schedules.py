"""LR schedules (pure functions of step)."""
from __future__ import annotations

import math


def constant(lr: float):
    return lambda step: lr


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def f(step: int) -> float:
        if step < warmup:
            return lr * (step + 1) / max(warmup, 1)
        frac = (step - warmup) / max(total - warmup, 1)
        frac = min(max(frac, 0.0), 1.0)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + math.cos(math.pi * frac)))
    return f
