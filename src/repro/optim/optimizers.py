"""Optimizers (functional, pytree state): SGD, momentum-SGD, AdamW.

State shards follow parameter PartitionSpecs (ZeRO-style for FSDP archs).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params):
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                             state, grads)
        new_p = jax.tree.map(lambda p, m: (p - lr * m.astype(p.dtype)).astype(p.dtype),
                             params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        bc1 = 1.0 - b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - b2 ** t.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            step = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "t": t}

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    flats = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads))
    norm = jnp.sqrt(sum(flats))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def make_optimizer(name: str, lr: float, weight_decay: float = 0.0) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
