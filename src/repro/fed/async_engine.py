"""FedBuff-style asynchronous buffered-aggregation engine (``engine="async"``).

Clients train against whatever server version is current when they are
dispatched; their updates stream back through a fault-tolerant arrival
process (``ft.arrivals``: mid-transfer failures, resume-from-offset retries,
exponential backoff, per-upload deadlines) into a K-slot buffer. When the
buffer fills — or stalls past a configurable deadline and flushes partially —
the server merges it in ONE compiled program: the same
``engine.aggregate_updates`` substrate every synchronous engine uses, fed
staleness-discounted coefficients (``w_i / (1 + s_i)^alpha``,
``core.bcrs.staleness_discount``) so updates computed against old versions
count less.

Batched dispatch (docs/DESIGN.md §12): instead of paying one jit dispatch of
the train program per upload, dispatches are recorded as PENDING and
materialized lazily in *waves* — one vmapped/padded program call covering
every buffer member at flush time (plus forced retirements at version-ring
evictions and checkpoint saves). Each wave member trains against the server
version it was dispatched at, gathered by version id from a small ring of
retained parameter versions inside the jit. Waves are padded to power-of-two
shape buckets, so the program compiles once per bucket — a small bounded set
— and the masked trainer's padded rows are exact no-ops, keeping the batched
path bit-exact with per-client dispatch (``async_batch_dispatch=False``).
A bonus of laziness: uploads that abort are never trained at all.

Per-client EF residuals live either in a dense ``[P + 1, n]`` host store
(``async_dense_store`` — sentinel row P, the pop_scan convention; the
small-P reference) or, by default, in PR 7's sparse out-of-core
``population.ClientStateStore``: rows persist in the strategy's declared
``residual_layout`` ("topk_complement" ``(idx32, f32)`` pairs or chunked
dense rows), densified/sparsified INSIDE the merge jit, gathered/scattered
only for the flushed buffer members — so ``engine="async"`` scales to the
population sizes the sync engines reached in PR 7 with no P-sized aval in
any compiled program.

Crash safety: every piece of loop state — params, the residual store, buffer
contents, in-flight uploads (including their updates and retry timelines),
and the dispatch/selection counters — checkpoints through
``repro.checkpoint`` at flush boundaries (pending dispatches are
materialized first, so the checkpoint tree layout is mode-independent; the
sparse store snapshots chunk-wise next to the main file). All randomness is
counter-based (``np.random.default_rng((seed, tag, counter))``), so
restoring the counters reproduces the exact future: a crash-restarted run
is bit-identical to an uninterrupted one.

Degenerate configuration = synchronous parity anchor: with arrivals forced
synchronous (``async_sync_arrivals``), buffer size = cohort size, and zero
staleness (by construction), the engine replays the scan engine's host plans
through the same two compiled programs and reproduces its trajectory
(pop_scan's, for per-client-EF strategies).
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.fed import engine as engine_mod
from repro.ft.arrivals import ArrivalProcess, BATCH_TAG
from repro.ft.straggler import renormalize_coefficients

#: trace counters keyed ("async_train" | "async_merge", strategy) — tests
#: assert the buffer-merge program compiles exactly once per run and the
#: train program once per wave shape bucket (a small bounded set)
TRACE_COUNTS: collections.Counter = collections.Counter()

#: rng-stream tag for free-client selection draws (pinned; keyed on the
#: dispatch counter, so selection needs no extra checkpoint state)
SELECT_TAG = 27_449


def wave_bucket(w: int) -> int:
    """Pad-to-bucket width for a wave of ``w`` members: the next power of
    two. Buckets bound the compile count of the wave train program at
    ``log2(max(K, M)) + 1`` regardless of how wave sizes vary."""
    return 1 << max(0, int(w - 1).bit_length())


def min_version_ring(concurrency: int, buffer_k: int) -> int:
    """Config-time floor on the version-ring depth — the *observable
    staleness bound* a ring must clear to batch at all. With ``M <= K``
    every in-flight upload CAN land in the very next flush, so retaining
    the current version suffices (depth 1). With ``M > K`` the pigeonhole
    guarantees uploads from the previous version are still in flight after
    any flush, so a 1-deep ring would force-retire every wave down to
    near-per-client dispatch — require depth 2. Deeper staleness than the
    ring retains is handled gracefully at runtime (forced retirement
    trains a pending wave before its version is evicted — batching
    degrades, correctness never does)."""
    return 1 if concurrency <= buffer_k else 2


# ----------------------------------------------------- compiled programs
class AsyncTrainStep:
    """Jitted local-training program: flat params + a batch plan for C slots
    -> stacked flat client deltas [C, n]. Same arithmetic as the scanned
    engines' in-loop training (vmapped masked SGD over gathered batches).
    Used by the sync-arrivals parity anchor; the event loop trains through
    ``WaveTrainStep`` (same arithmetic, per-member version gather)."""

    def __init__(self, fn, strategy: str):
        self._fn = fn
        self.strategy = strategy

    def __call__(self, flat, x):
        return self._fn(flat, x)


def make_async_train_step(loss_fn: Callable, params_template, *, lr: float,
                          make_batches: Callable,
                          strategy: str = "") -> AsyncTrainStep:
    unflatten = engine_mod.make_unflatten(params_template)
    local_train = engine_mod.make_masked_local_trainer(loss_fn, lr)

    def _train(flat, x):
        TRACE_COUNTS[("async_train", strategy)] += 1
        params = unflatten(flat)
        deltas, _losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, make_batches(x), x["step_mask"])
        return engine_mod.flatten_client_trees(deltas)

    return AsyncTrainStep(jax.jit(_train), strategy)


class WaveTrainStep:
    """Jitted wave-training program: a ring of retained parameter versions
    [V, n] + a padded wave plan -> stacked flat deltas [Wb, n]. Each wave
    member gathers ITS dispatch-time server version by ring slot
    (``x["ver_idx"]``) inside the jit, so one program call replaces Wb
    per-client dispatches while every member still trains against exactly
    the params it would have seen eagerly. Compiles once per wave shape
    bucket (TRACE_COUNTS key ("async_train", strategy) counts traces)."""

    def __init__(self, fn, strategy: str):
        self._fn = fn
        self.strategy = strategy

    def __call__(self, ring, x):
        return self._fn(ring, x)


def make_wave_train_step(loss_fn: Callable, params_template, *, lr: float,
                         make_batches: Callable,
                         strategy: str = "") -> WaveTrainStep:
    unflatten = engine_mod.make_unflatten(params_template)
    local_train = engine_mod.make_masked_local_trainer(loss_fn, lr)

    def _train(ring, x):
        TRACE_COUNTS[("async_train", strategy)] += 1
        flat_w = ring[x["ver_idx"]]                      # [Wb, n]
        params = jax.vmap(unflatten)(flat_w)
        deltas, _losses = jax.vmap(local_train, in_axes=(0, 0, 0))(
            params, make_batches(x), x["step_mask"])
        return engine_mod.flatten_client_trees(deltas)

    return WaveTrainStep(jax.jit(_train), strategy)


class AsyncMergeStep:
    """Jitted buffer-merge program (the ONE compiled merge per run): K
    buffered flat updates + staleness-discounted weights + per-slot EF
    residuals -> new flat params + new residuals. ``layout`` names the
    residual wire format crossing the jit boundary: "rows" (dense [K, n],
    the in-RAM reference), "topk_complement" (sparse ``(idx, val)`` pairs
    densified on entry / sparsified on exit — the population store's
    format), or None (carry="none")."""

    def __init__(self, fn, spec, layout: Optional[str], width: int):
        self._fn = fn
        self.spec = spec
        self.layout = layout
        self.width = width

    def __call__(self, flat, residuals, x):
        return self._fn(flat, residuals, x)


def make_async_merge_step(acfg, *, eta: float = 1.0,
                          residual_layout: str = "rows",
                          width: int = 0) -> AsyncMergeStep:
    spec = engine_mod.spec_for(acfg)
    ef = spec.needs_residuals
    layout = residual_layout if ef else None
    if layout == "topk_complement" and width <= 0:
        raise ValueError(
            f"{spec.strategy} persists residuals as topk_complement pairs — "
            "make_async_merge_step needs width > 0 (n - k_min)")

    def _merge(flat, residuals, x):
        TRACE_COUNTS[("async_merge", spec.strategy)] += 1
        if layout == "topk_complement":
            res_rows = engine_mod.densify_rows(*residuals, flat.shape[0])
        else:
            res_rows = residuals if ef else None
        agg, new_rows = engine_mod.aggregate_updates(
            spec, x["updates"], x["weights"], x["ks"],
            residuals=res_rows, active=x["active"])
        out = {"flat": flat - eta * agg,
               "overflow": jnp.asarray(False)}
        if layout == "topk_complement":
            idx, val, overflow = engine_mod.sparsify_rows(new_rows, width)
            out["residuals"] = (idx, val)
            out["overflow"] = overflow
        else:
            out["residuals"] = new_rows if ef else residuals
        return out

    fn = jax.jit(_merge, donate_argnums=(0, 1) if ef else (0,))
    return AsyncMergeStep(fn, spec, layout, width)


# -------------------------------------------------------- flush weighting
def flush_weights(member_ids, member_staleness, pending_ids,
                  pending_staleness, *, buffer_k: int, alpha: float,
                  coeff_table: Optional[np.ndarray] = None,
                  fracs_all: Optional[np.ndarray] = None) -> np.ndarray:
    """Final merge coefficients for the ``m`` filled buffer slots.

    Every slot — filled or not — gets the staleness-discounted coefficient
    of its (actual or expected) occupant: filled slots their buffered
    client, unfilled slots the next in-flight uploads the buffer was
    waiting for when it stalled. ``renormalize_coefficients`` then folds
    the missing slots' mass onto the arrived ones, so a partial flush takes
    the same total step magnitude the full buffer would have (the invariant
    tests/test_async_engine.py asserts). A full flush renormalizes to
    itself — the discounted coefficients pass through untouched.

    ``coeff_table`` (whole-population Eq. 6 coefficients) serves
    bcrs-weighted strategies, the ``run_fl_traced`` convention; otherwise
    data fractions are normalized over the slots' occupants."""
    ids = np.concatenate([np.asarray(member_ids, np.int64),
                          np.asarray(pending_ids, np.int64)])[:buffer_k]
    stal = np.concatenate([np.asarray(member_staleness, np.float64),
                           np.asarray(pending_staleness, np.float64)
                           ])[:buffer_k]
    if coeff_table is not None:
        base = np.asarray(coeff_table, np.float64)[ids]
    else:
        fr = np.asarray(fracs_all, np.float64)[ids]
        base = fr / fr.sum()
    disc = bcrs_mod.staleness_discount(base, stal, alpha)
    coeffs_k = np.zeros((buffer_k,), np.float64)
    coeffs_k[: len(ids)] = disc
    arrived = np.zeros((buffer_k,), bool)
    m = len(np.asarray(member_ids))
    arrived[:m] = True
    return renormalize_coefficients(coeffs_k, arrived)[:m]


# ------------------------------------------------------- event-driven loop
class BufferedAsyncLoop:
    """The FedBuff event loop, generic over the model: drivers supply
    ``batch_plan(client, uid) -> {name: np row}`` (one client's local-batch
    plan, NO leading axis; all batch randomness MUST key on
    ``(seed, BATCH_TAG, uid)`` so restarts replay it), a ``wave_train``
    program consuming stacked plan rows, and ``on_flush(flush_idx, flat,
    rt)`` (eval/accounting). The loop owns dispatch, the arrival process,
    the buffer, staleness weighting, the EF residual store, and crash-safe
    checkpointing.

    Virtual time: ``dispatch`` resolves each upload's full retry timeline
    immediately; events pop in time order; a flush happens when the buffer
    fills or — if a stall deadline is set — when the deadline passes with
    the buffer partially full. In-flight concurrency is topped up to M
    after every event; a client is busy from dispatch until its upload
    aborts or its buffered update is flushed, so no client ever has two
    updates in the pipeline (which is what keeps per-client EF exact).

    Training is LAZY by default (``batch_dispatch``): a dispatch records a
    pending entry; pending members materialize in one padded wave program
    call when the buffer flushes, when their parameter version is about to
    leave the retention ring (forced retirement), or when a checkpoint
    saves. Because the masked vmapped trainer is width- and
    padding-invariant, the wave path is bit-exact with eager per-client
    dispatch (``batch_dispatch=False`` trains each dispatch as a wave of
    one — the sequential baseline the dispatch-count benchmark compares
    against).

    ``residual_store``: None -> dense ``[P + 1, n]`` host array for
    carry="ef" strategies (sentinel row P); a
    ``population.ClientStateStore`` -> sparse out-of-core rows in the
    store's layout, which must match ``merge.layout``. Host round state is
    then O(K·n + M·n + V·n + resident-chunks) — never O(P·n)."""

    def __init__(self, *, n_clients: int, n_params: int, buffer_k: int,
                 concurrency: int, target_flushes: int, seed: int,
                 alpha: float, stall_s: float,
                 p_fail: float, retry: cost_model.RetryPolicy,
                 links, v_bytes: float, cr_eff_all: np.ndarray,
                 ks_all: np.ndarray, coeff_table: Optional[np.ndarray],
                 fracs_all: np.ndarray, merge: AsyncMergeStep,
                 wave_train: WaveTrainStep,
                 batch_plan: Callable[[int, int], Dict[str, np.ndarray]],
                 on_flush: Callable, batch_dispatch: bool = True,
                 version_ring: int = 8,
                 residual_store=None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 extra_state: Optional[Callable[[], dict]] = None,
                 load_extra: Optional[Callable[[dict], None]] = None):
        if buffer_k > n_clients:
            raise ValueError(f"async buffer K={buffer_k} exceeds the "
                             f"client population {n_clients}")
        need = min_version_ring(concurrency, buffer_k)
        if version_ring < need:
            raise ValueError(
                f"async version ring depth {version_ring} is below the "
                f"observable staleness bound {need} for M={concurrency} "
                f"in-flight over a K={buffer_k} buffer")
        self.n, self.n_params = n_clients, n_params
        self.k, self.m_conc = buffer_k, concurrency
        self.target = target_flushes
        self.seed, self.alpha, self.stall_s = seed, alpha, stall_s
        self.links, self.v_bytes = links, v_bytes
        self.cr_eff_all = np.asarray(cr_eff_all, np.float64)
        self.ks_all = np.asarray(ks_all, np.int32)
        self.coeff_table = coeff_table
        self.fracs_all = np.asarray(fracs_all, np.float64)
        self.merge = merge
        self.ef = merge.spec.needs_residuals
        self.wave_train, self.batch_plan = wave_train, batch_plan
        self.batch_dispatch = batch_dispatch
        self.on_flush = on_flush
        self.ckpt_dir, self.ckpt_every = checkpoint_dir, checkpoint_every
        self.extra_state = extra_state or (lambda: {})
        self.load_extra = load_extra or (lambda d: None)

        if self.ef and residual_store is None:
            residual_store = np.zeros((n_clients + 1, n_params), np.float32)
        self.store = residual_store if self.ef else None
        self.dense_store = isinstance(self.store, np.ndarray)
        if self.ef and not self.dense_store:
            # store layout "dense" crosses the jit boundary as "rows"
            want = ("topk_complement"
                    if self.store.layout == "topk_complement" else "rows")
            if merge.layout != want:
                raise ValueError(
                    f"merge program speaks residual layout {merge.layout!r} "
                    f"but the client store persists {self.store.layout!r}")
        elif self.ef and merge.layout != "rows":
            raise ValueError(
                f"merge program speaks residual layout {merge.layout!r} but "
                "the dense [P + 1, n] store only carries \"rows\" — pass a "
                "population.ClientStateStore as residual_store")

        self.proc = ArrivalProcess(seed=seed, p_fail=p_fail, retry=retry)
        self.flat: Optional[jax.Array] = None
        self.buffer: List[dict] = []
        #: uid -> (client, version): dispatched but not yet trained
        self.pending: "collections.OrderedDict[int, tuple]" = \
            collections.OrderedDict()
        #: uid -> np [n]: trained updates awaiting flush (or abort)
        self.inflight_updates: Dict[int, np.ndarray] = {}
        #: clients with an update in the pipeline — O(M + K) entries, a
        #: set (not a [P] bool column) so membership state stays O(C)
        self.busy: set = set()
        self.version = 0
        self.flushes = 0
        self.now = 0.0
        self.t_prev_flush = 0.0
        self.stall_t = float("inf")
        # ---- version retention ring (host mirror + lazy device copy) ----
        self.ring_depth = version_ring
        self.ring = np.zeros((version_ring, n_params), np.float32)
        self.ring_ver = np.full((version_ring,), -1, np.int64)
        self._ring_dev = None
        # ---- telemetry the dispatch benchmark reads ---------------------
        self.train_calls = 0          # jit dispatches of the train program
        self.train_rows = 0           # client updates computed (incl. waves)
        self.wave_sizes: List[int] = []
        self.wave_buckets_used: set = set()
        self.forced_retires = 0       # waves forced by ring eviction
        self.aborted_untrained = 0    # aborted uploads never trained (lazy)
        self.peak_round_state_bytes = 0

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, client: int) -> None:
        ev = self.proc.dispatch(client, self.version, self.now,
                                self.links[client], self.v_bytes,
                                float(self.cr_eff_all[client]))
        self.pending[ev.uid] = (client, self.version)
        self.busy.add(client)
        if not self.batch_dispatch:
            self._materialize([ev.uid])

    def _top_up(self) -> None:
        while len(self.proc) < self.m_conc:
            if len(self.busy) >= self.n:
                return
            # O(1)-expected free-client draw: rejection-sample the busy set
            # (|busy| <= M + K << P at population scale) instead of an O(P)
            # flatnonzero scan. Keyed on the dispatch counter, so the draw
            # sequence — including rejections — replays exactly on restore.
            rng = np.random.default_rng(
                (self.seed, SELECT_TAG, self.proc.counter))
            while True:
                client = int(rng.integers(self.n))
                if client not in self.busy:
                    break
            self._dispatch(client)

    # ------------------------------------------------- wave materialization
    def _materialize(self, uids) -> None:
        """Train the pending entries in ``uids`` as ONE padded wave program
        call (uid order — deterministic, and irrelevant to the bits: each
        member's batches key on its own uid and its params come from its
        own dispatch version's ring slot)."""
        uids = sorted(u for u in uids if u in self.pending)
        if not uids:
            return
        members = [(u, *self.pending.pop(u)) for u in uids]
        w = len(members)
        wb = wave_bucket(w)
        plans = [self.batch_plan(c, u) for u, c, _v in members]
        x: Dict[str, jax.Array] = {}
        for key, row0 in plans[0].items():
            row0 = np.asarray(row0)
            buf = np.zeros((wb,) + row0.shape, row0.dtype)
            for j, p in enumerate(plans):
                buf[j] = p[key]
            x[key] = jnp.asarray(buf)
        ver_idx = np.zeros((wb,), np.int32)
        for j, (_u, _c, v) in enumerate(members):
            slot = v % self.ring_depth
            if self.ring_ver[slot] != v:      # pragma: no cover — guarded
                raise RuntimeError(
                    f"version {v} left the retention ring before its wave "
                    "materialized (forced retirement should prevent this)")
            ver_idx[j] = slot
        x["ver_idx"] = jnp.asarray(ver_idx)
        if self._ring_dev is None:
            self._ring_dev = jnp.asarray(self.ring)
        out = np.asarray(self.wave_train(self._ring_dev, x))
        for j, (u, _c, _v) in enumerate(members):
            self.inflight_updates[u] = out[j]
        self.train_calls += 1
        self.train_rows += w
        self.wave_sizes.append(w)
        self.wave_buckets_used.add(wb)
        self._note_state()

    def _advance_version(self) -> None:
        """Retire the new server version into the ring. If the slot being
        overwritten still holds a version some pending dispatch trained
        against, that wave materializes NOW (forced retirement) — batching
        degrades gracefully instead of losing the params."""
        self.version += 1
        slot = self.version % self.ring_depth
        evicted = int(self.ring_ver[slot])
        if evicted >= 0:
            stale = [u for u, (_c, v) in self.pending.items()
                     if v == evicted]
            if stale:
                self.forced_retires += 1
                self._materialize(stale)
        self.ring[slot] = np.asarray(self.flat)
        self.ring_ver[slot] = self.version
        self._ring_dev = None

    def _note_state(self) -> None:
        """Peak host round-state telemetry: ring + trained updates + store
        residency (+ the [K, n] flush staging buffer, counted at flush).
        Registry-style O(P) planning columns (links, per-client CRs/ks) are
        setup state, not round state — the PR 7 accounting convention."""
        b = self.ring.nbytes
        b += sum(u.nbytes for u in self.inflight_updates.values())
        if self.ef:
            b += (self.store.nbytes if self.dense_store
                  else self.store.resident_bytes())
        self.peak_round_state_bytes = max(self.peak_round_state_bytes, b)

    # --------------------------------------------------------------- flush
    def _flush(self, t_flush: float) -> None:
        m = len(self.buffer)
        self._materialize([b["uid"] for b in self.buffer])
        ids = np.array([b["client"] for b in self.buffer], np.int64)
        stal = self.version - np.array([b["version"] for b in self.buffer],
                                       np.int64)
        pend = self.proc.in_flight()[: self.k - m]
        w = flush_weights(
            ids, stal, [e.client for e in pend],
            [self.version - e.version for e in pend],
            buffer_k=self.k, alpha=self.alpha,
            coeff_table=self.coeff_table, fracs_all=self.fracs_all)
        updates = np.zeros((self.k, self.n_params), np.float32)
        wpad = np.zeros((self.k,), np.float32)
        kpad = np.ones((self.k,), np.int32)
        act = np.zeros((self.k,), bool)
        for j, b in enumerate(self.buffer):
            updates[j] = self.inflight_updates.pop(b["uid"])
        wpad[:m], kpad[:m], act[:m] = w, self.ks_all[ids], True
        out = self.merge(self.flat, self._gather_residuals(ids),
                         {"updates": jnp.asarray(updates),
                          "weights": jnp.asarray(wpad),
                          "ks": jnp.asarray(kpad),
                          "active": jnp.asarray(act)})
        self.flat = out["flat"]
        if self.ef:
            if (self.merge.layout == "topk_complement"
                    and bool(out["overflow"])):
                raise RuntimeError(
                    f"flush {self.flushes}: EF residual outgrew the sparse "
                    f"width {self.merge.width} — the schedule emitted a k "
                    "below the width's k_min")
            self._scatter_residuals(ids, out["residuals"], m)
        dur = [b["t_arrive"] - b["t_dispatch"] for b in self.buffer]
        rt = cost_model.RoundTime(actual=t_flush - self.t_prev_flush,
                                  max=float(np.max(dur)),
                                  min=float(np.min(dur)))
        self.busy.difference_update(int(c) for c in ids)
        self.buffer.clear()
        self.t_prev_flush = t_flush
        self.stall_t = float("inf")
        self.peak_round_state_bytes = max(
            self.peak_round_state_bytes,
            self.ring.nbytes + updates.nbytes)
        self.on_flush(self.flushes, self.flat, rt)
        self._advance_version()
        self.flushes += 1
        self._note_state()

    def _gather_residuals(self, ids: np.ndarray):
        """Buffer members' residuals, padded to the K static slots, in the
        merge program's layout. Dense mode gathers by sentinel-padded row
        ids (row P is never written, so padded slots read exact zeros);
        store mode gathers only the real members and zero-pads — the same
        values, since a never-flushed client's store rows are zeros."""
        if not self.ef:
            return jnp.zeros((0,), jnp.float32)
        if self.dense_store:
            ids_pad = np.full((self.k,), self.n, np.int64)
            ids_pad[: len(ids)] = ids
            return jnp.asarray(self.store[ids_pad])
        rows = self.store.gather(ids)
        padded = []
        for a in rows:
            buf = np.zeros((self.k,) + a.shape[1:], a.dtype)
            buf[: len(ids)] = a
            padded.append(jnp.asarray(buf))
        return (tuple(padded) if self.merge.layout == "topk_complement"
                else padded[0])

    def _scatter_residuals(self, ids: np.ndarray, res_out, m: int) -> None:
        if self.dense_store:
            self.store[ids] = np.asarray(res_out)[:m]
        else:
            arrays = res_out if isinstance(res_out, tuple) else (res_out,)
            self.store.scatter(ids, tuple(np.asarray(a)[:m]
                                          for a in arrays))

    # ------------------------------------------------------- checkpointing
    # Large f32 tensors ride in the checkpoint TREE; every scalar /
    # timestamp / counter rides in msgpack ``extra`` — msgpack floats are
    # exact float64 round-trips, whereas restored tree leaves come back as
    # jnp arrays (float64 would be squashed to f32 under the default x64
    # setting, silently perturbing the replayed event timeline).
    _EV_COLS = ("uid", "client", "version", "t_dispatch", "t_resolve",
                "arrived", "attempts", "progress", "timed_out")

    def _ckpt_like(self) -> dict:
        return {
            "flat": jnp.zeros((self.n_params,), jnp.float32),
            "residuals": (np.zeros_like(self.store) if self.dense_store
                          else np.zeros((0,), np.float32)),
            "buf_updates": np.zeros((self.k, self.n_params), np.float32),
            "if_updates": np.zeros((self.m_conc, self.n_params),
                                   np.float32),
        }

    def _save(self) -> None:
        from repro import checkpoint as ckpt_mod
        from repro.fed import population as pop_mod
        # materialize every pending dispatch so the in-flight update tensor
        # is complete — the checkpoint layout is dispatch-mode-independent
        # (and bit-safe: training is wave-composition-invariant)
        self._materialize(list(self.pending))
        tree = self._ckpt_like()
        tree["flat"] = self.flat
        if self.ef and self.dense_store:
            tree["residuals"] = self.store
        st = self.proc.state()
        uids = [int(u) for u in st["uid"]]
        for j, b in enumerate(self.buffer):
            tree["buf_updates"][j] = self.inflight_updates[int(b["uid"])]
        for j, uid in enumerate(uids):
            tree["if_updates"][j] = self.inflight_updates[uid]
        extra = {
            "counter": self.proc.counter, "version": self.version,
            "flushes": self.flushes, "now": self.now,
            "t_prev_flush": self.t_prev_flush,
            "stall_t": None if np.isinf(self.stall_t) else self.stall_t,
            "buffer": [[int(b["client"]), int(b["version"]), int(b["uid"]),
                        float(b["t_arrive"]), float(b["t_dispatch"])]
                       for b in self.buffer],
            "inflight": {col: [c.item() for c in st[col]]
                         for col in self._EV_COLS},
        }
        if self.ef and not self.dense_store:
            extra["client_store"] = self.store.save(self.ckpt_dir,
                                                    self.flushes)
        extra.update(self.extra_state())
        ckpt_mod.save(self.ckpt_dir, self.flushes, tree, extra=extra)
        if self.ef and not self.dense_store:
            # retention just ran on the step files; drop the client-store
            # snapshots whose step it pruned
            pop_mod.prune_client_snapshots(
                self.ckpt_dir, ckpt_mod.list_steps(self.ckpt_dir))

    def _restore(self) -> bool:
        from repro import checkpoint as ckpt_mod
        from repro.fed import population as pop_mod
        if not self.ckpt_dir or not ckpt_mod.list_steps(self.ckpt_dir):
            return False
        tree, step, extra = ckpt_mod.restore_latest_valid(
            self.ckpt_dir, self._ckpt_like())
        self.flat = tree["flat"]
        if self.ef and self.dense_store:
            # np.array (copy): asarray of a jnp leaf is a read-only view,
            # and the store is scattered into on every flush
            self.store = np.array(tree["residuals"], np.float32)
        elif self.ef:
            man = extra["client_store"]
            if (man["layout"], man["width"]) != (self.store.layout,
                                                 self.store.width):
                raise ValueError(
                    f"client-store snapshot persists layout "
                    f"{man['layout']!r} width {man['width']} but this run "
                    f"expects {self.store.layout!r}/{self.store.width} — "
                    "the strategy or schedule changed across the restart")
            self.store = pop_mod.ClientStateStore.restore(
                self.ckpt_dir, step, man,
                max_resident_chunks=self.store.max_resident_chunks,
                spill_dir=self.store.spill_dir)
        self.buffer = [
            {"client": c, "version": v, "uid": u, "t_arrive": ta,
             "t_dispatch": td}
            for c, v, u, ta, td in extra["buffer"]]
        inflight = extra["inflight"]
        dtypes = {"uid": np.int64, "client": np.int64, "version": np.int64,
                  "t_dispatch": np.float64, "t_resolve": np.float64,
                  "arrived": bool, "attempts": np.int64,
                  "progress": np.float64, "timed_out": bool}
        state = {col: np.asarray(inflight[col], dtypes[col])
                 for col in self._EV_COLS}
        state["counter"] = np.array([extra["counter"]], np.int64)
        self.proc.load_state(state)
        self.pending.clear()
        self.inflight_updates = {
            int(uid): np.asarray(tree["if_updates"][j])
            for j, uid in enumerate(inflight["uid"])}
        for j, b in enumerate(self.buffer):
            self.inflight_updates[int(b["uid"])] = \
                np.asarray(tree["buf_updates"][j])
        self.version, self.flushes = extra["version"], extra["flushes"]
        self.now = extra["now"]
        self.t_prev_flush = extra["t_prev_flush"]
        self.stall_t = (float("inf") if extra["stall_t"] is None
                        else extra["stall_t"])
        self.busy = {b["client"] for b in self.buffer}
        self.busy |= self.proc.busy_clients()
        # pending is empty after a restore (the save materialized it), so
        # retaining only the current version reproduces the exact future
        self.ring[:] = 0.0
        self.ring_ver[:] = -1
        slot = self.version % self.ring_depth
        self.ring[slot] = np.asarray(self.flat)
        self.ring_ver[slot] = self.version
        self._ring_dev = None
        self.load_extra(extra)
        return True

    # ----------------------------------------------------------- main loop
    def run(self, flat0, stop_after: Optional[int] = None) -> jax.Array:
        """Drive the loop to ``target_flushes`` (or ``stop_after``, to
        simulate a crash at a flush boundary). Resumes from the newest
        intact checkpoint when one exists. Returns the final flat params."""
        self.flat = flat0
        if not self._restore():
            self.ring[0] = np.asarray(self.flat)
            self.ring_ver[0] = self.version
            self._ring_dev = None
        # top-up is idempotent at full concurrency; after a restore it
        # replays the dispatches the original run made right after the
        # checkpointed flush (counter-keyed draws -> identical events)
        self._top_up()
        # no-progress guard: a config whose uploads can NEVER arrive (e.g.
        # a timeout below every link's latency) would otherwise redispatch
        # aborts forever; at any positive arrival probability the chance of
        # this many consecutive aborts is astronomically small
        aborts_in_a_row, abort_limit = 0, 1000 * max(self.m_conc, 8)
        while self.flushes < self.target:
            if stop_after is not None and self.flushes >= stop_after:
                return self.flat
            t_next = self.proc.peek_time()
            if self.buffer and (t_next is None or self.stall_t < t_next):
                # stall deadline passed (or nothing else can ever arrive):
                # flush partially with renormalized coefficients
                t = self.now if t_next is None and np.isinf(self.stall_t) \
                    else self.stall_t
                self.now = max(self.now, t)
                self._flush(self.now)
                self._after_flush()
                self._top_up()
                continue
            if t_next is None:
                break        # nothing in flight, nothing buffered
            ev = self.proc.pop()
            self.now = ev.t_resolve
            if ev.arrived:
                aborts_in_a_row = 0
                self.buffer.append({
                    "client": ev.client, "version": ev.version,
                    "uid": ev.uid, "t_arrive": ev.t_resolve,
                    "t_dispatch": ev.t_dispatch})
                if len(self.buffer) == 1:
                    self.stall_t = self.now + self.stall_s
                if len(self.buffer) >= self.k:
                    self._flush(self.now)
                    self._after_flush()
            else:
                # upload aborted (retries exhausted or deadline hit): if
                # still pending it was NEVER trained — lazy dispatch saves
                # the work outright; EF untouched either way (residuals
                # only change on merge)
                if ev.uid in self.pending:
                    self.pending.pop(ev.uid)
                    self.aborted_untrained += 1
                else:
                    self.inflight_updates.pop(ev.uid)
                self.busy.discard(ev.client)
                aborts_in_a_row += 1
                if aborts_in_a_row > abort_limit:
                    raise RuntimeError(
                        f"{abort_limit} consecutive upload aborts without "
                        "one arrival — the failure/timeout config admits "
                        "no progress (is async_upload_timeout_s below the "
                        "links' latencies?)")
            self._top_up()
        return self.flat

    def _after_flush(self) -> None:
        if (self.ckpt_dir and self.ckpt_every
                and self.flushes % self.ckpt_every == 0):
            self._save()


# ------------------------------------------------------ simulation driver
def validate_async_config(sim, n_clients: Optional[int] = None) -> None:
    """Config-time validation of the ``async_*`` knobs (run_fl and the mesh
    driver both call this BEFORE any loop state exists): the buffer must
    fit the population, and the version ring must clear the observable
    staleness bound (``min_version_ring``) for the effective concurrency."""
    from repro.fed import simulation as sim_mod
    n = sim.n_clients if n_clients is None else n_clients
    n_sel = sim_mod.cohort_slots(n, sim.participation)
    k_buf = sim.async_buffer_k or n_sel
    if k_buf > n:
        raise ValueError(f"async buffer K={k_buf} exceeds the client "
                         f"population {n}")
    m_conc = sim.async_concurrency or max(1, min(2 * k_buf, n - k_buf))
    need = min_version_ring(m_conc, k_buf)
    if sim.async_version_ring < need:
        raise ValueError(
            f"async_version_ring={sim.async_version_ring} is below the "
            f"observable staleness bound {need} for M={m_conc} in-flight "
            f"over a K={k_buf} buffer — deepen the ring (depth 2 suffices "
            "for any M > K; forced retirement covers deeper staleness)")
    if sim.async_store_resident and not sim.async_store_spill:
        raise ValueError("async_store_resident bounds the sparse store's "
                         "resident chunks — set async_store_spill to the "
                         "directory evicted chunks spill into")


def run_async_sim(sim, acfg, rng, clients, parts, fracs_all, links, server,
                  steps_by_client, s_max, x_train, y_train, x_test, y_test,
                  failure, straggler, checkpoint_dir: Optional[str] = None,
                  checkpoint_every: int = 0,
                  stop_after: Optional[int] = None):
    """``run_fl(engine="async")`` body. Two modes:

    * ``sim.async_sync_arrivals``: the parity anchor — replays the shared
      host round plans (``_plan_rounds``, same rng stream as every sync
      engine) through the async train + merge programs with zero staleness.
      Reproduces the scan engine's trajectory (pop_scan's for EF
      strategies, whose per-client residual convention this engine shares).
    * general: the event-driven FedBuff loop with the fault-tolerant
      arrival process; ``sim.rounds`` counts buffer flushes. ``failure`` /
      ``straggler`` are subsumed by the arrival process here (slow links
      arrive late, uploads fail/retry/abort per ``async_p_fail_upload``).
      EF residuals default to the sparse ``ClientStateStore``
      (``sim.async_dense_store`` opts back into the dense ``[P + 1, n]``
      reference); dispatches batch into waves unless
      ``sim.async_batch_dispatch`` is off.
    """
    from repro.core import aggregation as agg_mod
    from repro.core.compression import k_for_ratio
    from repro.fed import population as pop_mod
    from repro.fed import simulation as sim_mod

    validate_async_config(sim)
    result = sim_mod.FLSimResult()
    n, n_params, v_bytes = sim.n_clients, server.n_params, server.v_bytes
    strat, ef, bs = acfg.strat, acfg.strat.needs_residuals, sim.batch_size
    n_sel = sim_mod.cohort_slots(n, sim.participation)
    x_all, y_all = jnp.asarray(x_train), jnp.asarray(y_train)
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)

    def gather_batches(x):
        idx = x["sample_idx"]
        return {"x": x_all[idx], "y": y_all[idx]}

    if sim.async_sync_arrivals:
        train = make_async_train_step(
            sim_mod.mlp_loss, server.params, lr=sim.lr,
            make_batches=gather_batches, strategy=acfg.strategy)
        merge = make_async_merge_step(acfg, eta=server.eta)
        return _run_sync_parity(sim, acfg, rng, clients, parts, fracs_all,
                                links, server, steps_by_client, s_max,
                                failure, straggler, train, merge, xt, yt,
                                result)

    # -------------------------------------------------- general async mode
    k_buf = sim.async_buffer_k or n_sel
    m_conc = sim.async_concurrency or max(1, min(2 * k_buf, n - k_buf))
    fracs_norm = np.asarray(fracs_all, np.float64)
    fracs_norm = fracs_norm / fracs_norm.sum()
    crs_all, coeffs_all, _info = agg_mod.round_schedule(
        acfg, n, fracs_norm, links, v_bytes)
    ks_all = agg_mod.ks_for_schedule(n_params, crs_all, acfg)
    # dense wire formats return a scalar 1.0 — broadcast to per-client
    cr_eff_all = np.broadcast_to(np.asarray(
        strat.wire.cr_eff(np.asarray(crs_all, np.float64), n_params),
        np.float64), (n,))
    retry = cost_model.RetryPolicy(
        max_attempts=sim.async_max_attempts, backoff_s=sim.async_backoff_s,
        backoff_factor=sim.async_backoff_factor,
        timeout_s=sim.async_upload_timeout_s)

    store = None
    if ef and not sim.async_dense_store:
        layout = strat.residual_layout
        width = (pop_mod.residual_width(n_params, int(ks_all.min()))
                 if layout == "topk_complement" else 0)
        store = pop_mod.ClientStateStore(
            n, n_params, layout=layout, width=width,
            chunk_clients=min(sim.async_store_chunk, n),
            max_resident_chunks=sim.async_store_resident or None,
            spill_dir=sim.async_store_spill or None)
        merge = make_async_merge_step(
            acfg, eta=server.eta,
            residual_layout=("topk_complement"
                             if layout == "topk_complement" else "rows"),
            width=width)
    else:
        merge = make_async_merge_step(acfg, eta=server.eta)

    wave_train = make_wave_train_step(
        sim_mod.mlp_loss, server.params, lr=sim.lr,
        make_batches=gather_batches, strategy=acfg.strategy)

    def batch_plan(client: int, uid: int) -> Dict[str, np.ndarray]:
        rng_b = np.random.default_rng((sim.seed, BATCH_TAG, uid))
        steps = int(steps_by_client[client])
        local = clients[client].fixed_batch_indices(bs, steps, rng_b)
        idx = np.zeros((s_max, bs), np.int32)
        idx[:steps] = parts[client][local].reshape(steps, bs)
        smask = np.zeros((s_max,), bool)
        smask[:steps] = True
        return {"sample_idx": idx, "step_mask": smask}

    def on_flush(flush_idx: int, flat, rt: cost_model.RoundTime) -> None:
        server.times.add(rt)
        result.executed_rounds.append(flush_idx)
        if sim_mod._is_eval_round(sim, flush_idx):
            acc = float(sim_mod.mlp_accuracy(server._unravel(flat), xt, yt))
            result.accuracies.append((flush_idx, acc))

    def extra_state() -> dict:
        return {"accuracies": [[int(r), float(a)]
                               for r, a in result.accuracies],
                "executed_rounds": [int(r) for r in result.executed_rounds],
                "times": [[float(t.actual), float(t.max), float(t.min)]
                          for t in server.times.per_round]}

    def load_extra(extra: dict) -> None:
        result.accuracies = [(int(r), float(a))
                             for r, a in extra["accuracies"]]
        result.executed_rounds = list(extra["executed_rounds"])
        for a, mx, mn in extra["times"]:
            server.times.add(cost_model.RoundTime(a, mx, mn))

    loop = BufferedAsyncLoop(
        n_clients=n, n_params=n_params, buffer_k=k_buf, concurrency=m_conc,
        target_flushes=sim.rounds, seed=sim.seed, alpha=sim.async_alpha,
        stall_s=sim.async_stall_s, p_fail=sim.async_p_fail_upload,
        retry=retry, links=links, v_bytes=v_bytes, cr_eff_all=cr_eff_all,
        ks_all=ks_all,
        coeff_table=(coeffs_all if strat.weighting == "bcrs" else None),
        fracs_all=fracs_all, merge=merge, wave_train=wave_train,
        batch_plan=batch_plan, on_flush=on_flush,
        batch_dispatch=sim.async_batch_dispatch,
        version_ring=sim.async_version_ring, residual_store=store,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        extra_state=extra_state, load_extra=load_extra)
    t0 = time.perf_counter()
    flat = loop.run(server._flat, stop_after=stop_after)
    wall = time.perf_counter() - t0

    server._flat = flat
    server.params = server._unravel(flat)
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    nf = max(len(result.executed_rounds), 1)
    result.wall_per_round = [wall / nf] * len(result.executed_rounds)
    if ef:
        result.final_residuals = (np.asarray(loop.store[:n])
                                  if loop.dense_store
                                  else loop.store.dump_dense())
    result.async_loop = loop
    return result


def _run_sync_parity(sim, acfg, rng, clients, parts, fracs_all, links,
                     server, steps_by_client, s_max, failure, straggler,
                     train, merge, xt, yt, result):
    """Degenerate-async parity mode: synchronous arrivals, buffer = cohort,
    staleness 0 (discount is the exact identity at s=0 for any alpha)."""
    from repro.fed import simulation as sim_mod
    n, n_params, bs = sim.n_clients, server.n_params, sim.batch_size
    n_sel = sim_mod.cohort_slots(n, sim.participation)
    ef = acfg.strat.needs_residuals

    plans = sim_mod._plan_rounds(sim, acfg, rng, clients, parts, fracs_all,
                                 links, server, steps_by_client, s_max,
                                 failure, straggler, False)
    if not plans:
        result.times = server.times
        return result
    store = (np.zeros((n + 1, n_params), np.float32) if ef
             else np.zeros((0,), np.float32))
    flat = server._flat
    for rnd, selected, weights, ks, _ko, idx in plans:
        t0 = time.perf_counter()
        c_r = len(selected)
        x = {"sample_idx": np.zeros((n_sel, s_max, bs), np.int32),
             "step_mask": np.zeros((n_sel, s_max), bool)}
        x["sample_idx"][:c_r] = idx.reshape(c_r, s_max, bs)
        for j, c in enumerate(selected):
            x["step_mask"][j, : int(steps_by_client[c])] = True
        updates = train(flat, {k: jnp.asarray(v) for k, v in x.items()})
        ids_pad = np.full((n_sel,), n, np.int64)
        ids_pad[:c_r] = selected
        wpad = np.zeros((n_sel,), np.float32)
        wpad[:c_r] = weights
        kpad = np.ones((n_sel,), np.int32)
        kpad[:c_r] = ks
        act = np.zeros((n_sel,), bool)
        act[:c_r] = True
        res_rows = (jnp.asarray(store[ids_pad]) if ef
                    else jnp.zeros((0,), jnp.float32))
        out = merge(flat, res_rows, {"updates": updates,
                                     "weights": jnp.asarray(wpad),
                                     "ks": jnp.asarray(kpad),
                                     "active": jnp.asarray(act)})
        flat = out["flat"]
        if ef:
            store[selected] = np.asarray(out["residuals"])[:c_r]
        result.wall_per_round.append(time.perf_counter() - t0)
        result.executed_rounds.append(rnd)
        if sim_mod._is_eval_round(sim, rnd):
            acc = float(sim_mod.mlp_accuracy(server._unravel(flat), xt, yt))
            result.accuracies.append((rnd, acc))

    server._flat = flat
    server.params = server._unravel(flat)
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    if ef:
        result.final_residuals = np.asarray(store[:n])
    return result
