"""FedBuff-style asynchronous buffered-aggregation engine (``engine="async"``).

Clients train against whatever server version is current when they are
dispatched; their updates stream back through a fault-tolerant arrival
process (``ft.arrivals``: mid-transfer failures, resume-from-offset retries,
exponential backoff, per-upload deadlines) into a K-slot buffer. When the
buffer fills — or stalls past a configurable deadline and flushes partially —
the server merges it in ONE compiled program: the same
``engine.aggregate_updates`` substrate every synchronous engine uses, fed
staleness-discounted coefficients (``w_i / (1 + s_i)^alpha``,
``core.bcrs.staleness_discount``) so updates computed against old versions
count less. OPWA overlap counts and EF residuals work unchanged: residuals
live in a per-client ``[P + 1, n]`` host store (sentinel row P, the pop_scan
convention) gathered/scattered by buffer slot, so ``carry="ef"`` strategies
stay bit-exact per client no matter how dispatches and arrivals interleave.

Crash safety: every piece of loop state — params, the residual store, buffer
contents, in-flight uploads (including their already-computed updates and
retry timelines), and the dispatch/selection counters — checkpoints through
``repro.checkpoint`` at flush boundaries. All randomness is counter-based
(``np.random.default_rng((seed, tag, counter))``), so restoring the counters
reproduces the exact future: a crash-restarted run is bit-identical to an
uninterrupted one.

Degenerate configuration = synchronous parity anchor: with arrivals forced
synchronous (``async_sync_arrivals``), buffer size = cohort size, and zero
staleness (by construction), the engine replays the scan engine's host plans
through the same two compiled programs and reproduces its trajectory
(pop_scan's, for per-client-EF strategies).
"""
from __future__ import annotations

import collections
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.fed import engine as engine_mod
from repro.ft.arrivals import ArrivalProcess, BATCH_TAG
from repro.ft.straggler import renormalize_coefficients

#: trace counters keyed ("async_train" | "async_merge", strategy) — tests
#: assert the buffer-merge program compiles exactly once per run
TRACE_COUNTS: collections.Counter = collections.Counter()

#: rng-stream tag for free-client selection draws (pinned; keyed on the
#: dispatch counter, so selection needs no extra checkpoint state)
SELECT_TAG = 27_449


# ----------------------------------------------------- compiled programs
class AsyncTrainStep:
    """Jitted local-training program: flat params + a batch plan for C slots
    -> stacked flat client deltas [C, n]. Same arithmetic as the scanned
    engines' in-loop training (vmapped masked SGD over gathered batches)."""

    def __init__(self, fn, strategy: str):
        self._fn = fn
        self.strategy = strategy

    def __call__(self, flat, x):
        return self._fn(flat, x)


def make_async_train_step(loss_fn: Callable, params_template, *, lr: float,
                          make_batches: Callable,
                          strategy: str = "") -> AsyncTrainStep:
    unflatten = engine_mod.make_unflatten(params_template)
    local_train = engine_mod.make_masked_local_trainer(loss_fn, lr)

    def _train(flat, x):
        TRACE_COUNTS[("async_train", strategy)] += 1
        params = unflatten(flat)
        deltas, _losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, make_batches(x), x["step_mask"])
        return engine_mod.flatten_client_trees(deltas)

    return AsyncTrainStep(jax.jit(_train), strategy)


class AsyncMergeStep:
    """Jitted buffer-merge program (the ONE compiled merge per run): K
    buffered flat updates + staleness-discounted weights + per-slot EF
    residual rows -> new flat params + new residual rows."""

    def __init__(self, fn, spec):
        self._fn = fn
        self.spec = spec

    def __call__(self, flat, residuals, x):
        return self._fn(flat, residuals, x)


def make_async_merge_step(acfg, *, eta: float = 1.0) -> AsyncMergeStep:
    spec = engine_mod.spec_for(acfg)
    ef = spec.needs_residuals

    def _merge(flat, residuals, x):
        TRACE_COUNTS[("async_merge", spec.strategy)] += 1
        agg, new_res = engine_mod.aggregate_updates(
            spec, x["updates"], x["weights"], x["ks"],
            residuals=residuals if ef else None, active=x["active"])
        return {"flat": flat - eta * agg,
                "residuals": new_res if ef else residuals}

    fn = jax.jit(_merge, donate_argnums=(0, 1) if ef else (0,))
    return AsyncMergeStep(fn, spec)


# -------------------------------------------------------- flush weighting
def flush_weights(member_ids, member_staleness, pending_ids,
                  pending_staleness, *, buffer_k: int, alpha: float,
                  coeff_table: Optional[np.ndarray] = None,
                  fracs_all: Optional[np.ndarray] = None) -> np.ndarray:
    """Final merge coefficients for the ``m`` filled buffer slots.

    Every slot — filled or not — gets the staleness-discounted coefficient
    of its (actual or expected) occupant: filled slots their buffered
    client, unfilled slots the next in-flight uploads the buffer was
    waiting for when it stalled. ``renormalize_coefficients`` then folds
    the missing slots' mass onto the arrived ones, so a partial flush takes
    the same total step magnitude the full buffer would have (the invariant
    tests/test_async_engine.py asserts). A full flush renormalizes to
    itself — the discounted coefficients pass through untouched.

    ``coeff_table`` (whole-population Eq. 6 coefficients) serves
    bcrs-weighted strategies, the ``run_fl_traced`` convention; otherwise
    data fractions are normalized over the slots' occupants."""
    ids = np.concatenate([np.asarray(member_ids, np.int64),
                          np.asarray(pending_ids, np.int64)])[:buffer_k]
    stal = np.concatenate([np.asarray(member_staleness, np.float64),
                           np.asarray(pending_staleness, np.float64)
                           ])[:buffer_k]
    if coeff_table is not None:
        base = np.asarray(coeff_table, np.float64)[ids]
    else:
        fr = np.asarray(fracs_all, np.float64)[ids]
        base = fr / fr.sum()
    disc = bcrs_mod.staleness_discount(base, stal, alpha)
    coeffs_k = np.zeros((buffer_k,), np.float64)
    coeffs_k[: len(ids)] = disc
    arrived = np.zeros((buffer_k,), bool)
    m = len(np.asarray(member_ids))
    arrived[:m] = True
    return renormalize_coefficients(coeffs_k, arrived)[:m]


# ------------------------------------------------------- event-driven loop
class BufferedAsyncLoop:
    """The FedBuff event loop, generic over the model: drivers supply
    ``train_update(client, uid, flat) -> np [n]`` (run local training
    against the current params; all batch randomness MUST key on
    ``(seed, BATCH_TAG, uid)`` so restarts replay it) and
    ``on_flush(flush_idx, flat, rt)`` (eval/accounting). The loop owns
    dispatch, the arrival process, the buffer, staleness weighting, the EF
    residual store, and crash-safe checkpointing.

    Virtual time: ``dispatch`` resolves each upload's full retry timeline
    immediately; events pop in time order; a flush happens when the buffer
    fills or — if a stall deadline is set — when the deadline passes with
    the buffer partially full. In-flight concurrency is topped up to M
    after every event; a client is busy from dispatch until its upload
    aborts or its buffered update is flushed, so no client ever has two
    updates in the pipeline (which is what keeps per-client EF exact)."""

    def __init__(self, *, n_clients: int, n_params: int, buffer_k: int,
                 concurrency: int, target_flushes: int, seed: int,
                 alpha: float, stall_s: float,
                 p_fail: float, retry: cost_model.RetryPolicy,
                 links, v_bytes: float, cr_eff_all: np.ndarray,
                 ks_all: np.ndarray, coeff_table: Optional[np.ndarray],
                 fracs_all: np.ndarray, merge: AsyncMergeStep,
                 train_update: Callable[[int, int, jax.Array], np.ndarray],
                 on_flush: Callable, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 extra_state: Optional[Callable[[], dict]] = None,
                 load_extra: Optional[Callable[[dict], None]] = None):
        if buffer_k > n_clients:
            raise ValueError(f"async buffer K={buffer_k} exceeds the "
                             f"client population {n_clients}")
        self.n, self.n_params = n_clients, n_params
        self.k, self.m_conc = buffer_k, concurrency
        self.target = target_flushes
        self.seed, self.alpha, self.stall_s = seed, alpha, stall_s
        self.links, self.v_bytes = links, v_bytes
        self.cr_eff_all = np.asarray(cr_eff_all, np.float64)
        self.ks_all = np.asarray(ks_all, np.int32)
        self.coeff_table = coeff_table
        self.fracs_all = np.asarray(fracs_all, np.float64)
        self.merge = merge
        self.ef = merge.spec.needs_residuals
        self.train_update, self.on_flush = train_update, on_flush
        self.ckpt_dir, self.ckpt_every = checkpoint_dir, checkpoint_every
        self.extra_state = extra_state or (lambda: {})
        self.load_extra = load_extra or (lambda d: None)

        self.proc = ArrivalProcess(seed=seed, p_fail=p_fail, retry=retry)
        self.flat: Optional[jax.Array] = None
        self.store = (np.zeros((n_clients + 1, n_params), np.float32)
                      if self.ef else np.zeros((0,), np.float32))
        self.buffer: List[dict] = []
        self.inflight_updates: Dict[int, np.ndarray] = {}
        self.busy = np.zeros(n_clients, bool)
        self.version = 0
        self.flushes = 0
        self.now = 0.0
        self.t_prev_flush = 0.0
        self.stall_t = float("inf")

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, client: int) -> None:
        uid = self.proc.counter       # the uid dispatch() assigns next
        update = self.train_update(client, uid, self.flat)
        ev = self.proc.dispatch(client, self.version, self.now,
                                self.links[client], self.v_bytes,
                                float(self.cr_eff_all[client]))
        self.inflight_updates[ev.uid] = np.asarray(update, np.float32)
        self.busy[client] = True

    def _top_up(self) -> None:
        while len(self.proc) < self.m_conc:
            free = np.flatnonzero(~self.busy)
            if free.size == 0:
                return
            rng = np.random.default_rng(
                (self.seed, SELECT_TAG, self.proc.counter))
            self._dispatch(int(free[rng.integers(free.size)]))

    # --------------------------------------------------------------- flush
    def _flush(self, t_flush: float) -> None:
        m = len(self.buffer)
        ids = np.array([b["client"] for b in self.buffer], np.int64)
        stal = self.version - np.array([b["version"] for b in self.buffer],
                                       np.int64)
        pend = self.proc.in_flight()[: self.k - m]
        w = flush_weights(
            ids, stal, [e.client for e in pend],
            [self.version - e.version for e in pend],
            buffer_k=self.k, alpha=self.alpha,
            coeff_table=self.coeff_table, fracs_all=self.fracs_all)
        updates = np.zeros((self.k, self.n_params), np.float32)
        wpad = np.zeros((self.k,), np.float32)
        kpad = np.ones((self.k,), np.int32)
        act = np.zeros((self.k,), bool)
        ids_pad = np.full((self.k,), self.n, np.int64)
        for j, b in enumerate(self.buffer):
            updates[j] = b["update"]
        wpad[:m], kpad[:m], act[:m], ids_pad[:m] = w, self.ks_all[ids], \
            True, ids
        res_rows = (jnp.asarray(self.store[ids_pad]) if self.ef
                    else jnp.zeros((0,), jnp.float32))
        out = self.merge(self.flat, res_rows,
                         {"updates": jnp.asarray(updates),
                          "weights": jnp.asarray(wpad),
                          "ks": jnp.asarray(kpad),
                          "active": jnp.asarray(act)})
        self.flat = out["flat"]
        if self.ef:
            self.store[ids] = np.asarray(out["residuals"])[:m]
        dur = [b["t_arrive"] - b["t_dispatch"] for b in self.buffer]
        rt = cost_model.RoundTime(actual=t_flush - self.t_prev_flush,
                                  max=float(np.max(dur)),
                                  min=float(np.min(dur)))
        self.busy[ids] = False
        self.buffer.clear()
        self.t_prev_flush = t_flush
        self.stall_t = float("inf")
        self.on_flush(self.flushes, self.flat, rt)
        self.version += 1
        self.flushes += 1

    # ------------------------------------------------------- checkpointing
    # Large f32 tensors ride in the checkpoint TREE; every scalar /
    # timestamp / counter rides in msgpack ``extra`` — msgpack floats are
    # exact float64 round-trips, whereas restored tree leaves come back as
    # jnp arrays (float64 would be squashed to f32 under the default x64
    # setting, silently perturbing the replayed event timeline).
    _EV_COLS = ("uid", "client", "version", "t_dispatch", "t_resolve",
                "arrived", "attempts", "progress", "timed_out")

    def _ckpt_like(self) -> dict:
        return {
            "flat": jnp.zeros((self.n_params,), jnp.float32),
            "residuals": np.zeros_like(self.store),
            "buf_updates": np.zeros((self.k, self.n_params), np.float32),
            "if_updates": np.zeros((self.m_conc, self.n_params),
                                   np.float32),
        }

    def _save(self) -> None:
        from repro import checkpoint as ckpt_mod
        tree = self._ckpt_like()
        tree["flat"] = self.flat
        tree["residuals"] = self.store
        for j, b in enumerate(self.buffer):
            tree["buf_updates"][j] = b["update"]
        st = self.proc.state()
        uids = [int(u) for u in st["uid"]]
        for j, uid in enumerate(uids):
            tree["if_updates"][j] = self.inflight_updates[uid]
        extra = {
            "counter": self.proc.counter, "version": self.version,
            "flushes": self.flushes, "now": self.now,
            "t_prev_flush": self.t_prev_flush,
            "stall_t": None if np.isinf(self.stall_t) else self.stall_t,
            "buffer": [[int(b["client"]), int(b["version"]), int(b["uid"]),
                        float(b["t_arrive"]), float(b["t_dispatch"])]
                       for b in self.buffer],
            "inflight": {col: [c.item() for c in st[col]]
                         for col in self._EV_COLS},
        }
        extra.update(self.extra_state())
        ckpt_mod.save(self.ckpt_dir, self.flushes, tree, extra=extra)

    def _restore(self) -> bool:
        from repro import checkpoint as ckpt_mod
        if not self.ckpt_dir or not ckpt_mod.list_steps(self.ckpt_dir):
            return False
        tree, _step, extra = ckpt_mod.restore_latest_valid(
            self.ckpt_dir, self._ckpt_like())
        self.flat = tree["flat"]
        if self.ef:
            # np.array (copy): asarray of a jnp leaf is a read-only view,
            # and the store is scattered into on every flush
            self.store = np.array(tree["residuals"], np.float32)
        self.buffer = [
            {"client": c, "version": v, "uid": u, "t_arrive": ta,
             "t_dispatch": td, "update": np.asarray(tree["buf_updates"][j])}
            for j, (c, v, u, ta, td) in enumerate(extra["buffer"])]
        inflight = extra["inflight"]
        dtypes = {"uid": np.int64, "client": np.int64, "version": np.int64,
                  "t_dispatch": np.float64, "t_resolve": np.float64,
                  "arrived": bool, "attempts": np.int64,
                  "progress": np.float64, "timed_out": bool}
        state = {col: np.asarray(inflight[col], dtypes[col])
                 for col in self._EV_COLS}
        state["counter"] = np.array([extra["counter"]], np.int64)
        self.proc.load_state(state)
        self.inflight_updates = {
            int(uid): np.asarray(tree["if_updates"][j])
            for j, uid in enumerate(inflight["uid"])}
        self.version, self.flushes = extra["version"], extra["flushes"]
        self.now = extra["now"]
        self.t_prev_flush = extra["t_prev_flush"]
        self.stall_t = (float("inf") if extra["stall_t"] is None
                        else extra["stall_t"])
        self.busy[:] = False
        for b in self.buffer:
            self.busy[b["client"]] = True
        for ev in self.proc.in_flight():
            self.busy[ev.client] = True
        self.load_extra(extra)
        return True

    # ----------------------------------------------------------- main loop
    def run(self, flat0, stop_after: Optional[int] = None) -> jax.Array:
        """Drive the loop to ``target_flushes`` (or ``stop_after``, to
        simulate a crash at a flush boundary). Resumes from the newest
        intact checkpoint when one exists. Returns the final flat params."""
        self.flat = flat0
        self._restore()
        # top-up is idempotent at full concurrency; after a restore it
        # replays the dispatches the original run made right after the
        # checkpointed flush (counter-keyed draws -> identical events)
        self._top_up()
        while self.flushes < self.target:
            if stop_after is not None and self.flushes >= stop_after:
                return self.flat
            t_next = self.proc.peek_time()
            if self.buffer and (t_next is None or self.stall_t < t_next):
                # stall deadline passed (or nothing else can ever arrive):
                # flush partially with renormalized coefficients
                t = self.now if t_next is None and np.isinf(self.stall_t) \
                    else self.stall_t
                self.now = max(self.now, t)
                self._flush(self.now)
                self._after_flush()
                self._top_up()
                continue
            if t_next is None:
                break        # nothing in flight, nothing buffered
            ev = self.proc.pop()
            self.now = ev.t_resolve
            if ev.arrived:
                self.buffer.append({
                    "client": ev.client, "version": ev.version,
                    "uid": ev.uid, "t_arrive": ev.t_resolve,
                    "t_dispatch": ev.t_dispatch,
                    "update": self.inflight_updates.pop(ev.uid)})
                if len(self.buffer) == 1:
                    self.stall_t = self.now + self.stall_s
                if len(self.buffer) >= self.k:
                    self._flush(self.now)
                    self._after_flush()
            else:
                # upload aborted (retries exhausted or deadline hit): the
                # trained update is dropped; EF untouched (residuals only
                # change on merge), so nothing is lost but the work
                self.inflight_updates.pop(ev.uid)
                self.busy[ev.client] = False
            self._top_up()
        return self.flat

    def _after_flush(self) -> None:
        if (self.ckpt_dir and self.ckpt_every
                and self.flushes % self.ckpt_every == 0):
            self._save()


# ------------------------------------------------------ simulation driver
def run_async_sim(sim, acfg, rng, clients, parts, fracs_all, links, server,
                  steps_by_client, s_max, x_train, y_train, x_test, y_test,
                  failure, straggler, checkpoint_dir: Optional[str] = None,
                  checkpoint_every: int = 0,
                  stop_after: Optional[int] = None):
    """``run_fl(engine="async")`` body. Two modes:

    * ``sim.async_sync_arrivals``: the parity anchor — replays the shared
      host round plans (``_plan_rounds``, same rng stream as every sync
      engine) through the async train + merge programs with zero staleness.
      Reproduces the scan engine's trajectory (pop_scan's for EF
      strategies, whose per-client residual convention this engine shares).
    * general: the event-driven FedBuff loop with the fault-tolerant
      arrival process; ``sim.rounds`` counts buffer flushes. ``failure`` /
      ``straggler`` are subsumed by the arrival process here (slow links
      arrive late, uploads fail/retry/abort per ``async_p_fail_upload``).
    """
    from repro.core import aggregation as agg_mod
    from repro.fed import simulation as sim_mod

    result = sim_mod.FLSimResult()
    n, n_params, v_bytes = sim.n_clients, server.n_params, server.v_bytes
    strat, ef, bs = acfg.strat, acfg.strat.needs_residuals, sim.batch_size
    n_sel = sim_mod.cohort_slots(n, sim.participation)
    x_all, y_all = jnp.asarray(x_train), jnp.asarray(y_train)
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)

    def gather_batches(x):
        idx = x["sample_idx"]
        return {"x": x_all[idx], "y": y_all[idx]}

    train = make_async_train_step(sim_mod.mlp_loss, server.params, lr=sim.lr,
                                  make_batches=gather_batches,
                                  strategy=acfg.strategy)
    merge = make_async_merge_step(acfg, eta=server.eta)

    if sim.async_sync_arrivals:
        return _run_sync_parity(sim, acfg, rng, clients, parts, fracs_all,
                                links, server, steps_by_client, s_max,
                                failure, straggler, train, merge, xt, yt,
                                result)

    # -------------------------------------------------- general async mode
    k_buf = sim.async_buffer_k or n_sel
    m_conc = sim.async_concurrency or max(1, min(2 * k_buf, n - k_buf))
    fracs_norm = np.asarray(fracs_all, np.float64)
    fracs_norm = fracs_norm / fracs_norm.sum()
    crs_all, coeffs_all, _info = agg_mod.round_schedule(
        acfg, n, fracs_norm, links, v_bytes)
    ks_all = agg_mod.ks_for_schedule(n_params, crs_all, acfg)
    # dense wire formats return a scalar 1.0 — broadcast to per-client
    cr_eff_all = np.broadcast_to(np.asarray(
        strat.wire.cr_eff(np.asarray(crs_all, np.float64), n_params),
        np.float64), (n,))
    retry = cost_model.RetryPolicy(
        max_attempts=sim.async_max_attempts, backoff_s=sim.async_backoff_s,
        backoff_factor=sim.async_backoff_factor,
        timeout_s=sim.async_upload_timeout_s)

    def train_update(client: int, uid: int, flat) -> np.ndarray:
        rng_b = np.random.default_rng((sim.seed, BATCH_TAG, uid))
        steps = int(steps_by_client[client])
        local = clients[client].fixed_batch_indices(bs, steps, rng_b)
        idx = np.zeros((1, s_max, bs), np.int32)
        idx[0, :steps] = parts[client][local].reshape(steps, bs)
        smask = np.zeros((1, s_max), bool)
        smask[0, :steps] = True
        upd = train(flat, {"sample_idx": jnp.asarray(idx),
                           "step_mask": jnp.asarray(smask)})
        return np.asarray(upd[0])

    def on_flush(flush_idx: int, flat, rt: cost_model.RoundTime) -> None:
        server.times.add(rt)
        result.executed_rounds.append(flush_idx)
        if sim_mod._is_eval_round(sim, flush_idx):
            acc = float(sim_mod.mlp_accuracy(server._unravel(flat), xt, yt))
            result.accuracies.append((flush_idx, acc))

    def extra_state() -> dict:
        return {"accuracies": [[int(r), float(a)]
                               for r, a in result.accuracies],
                "executed_rounds": [int(r) for r in result.executed_rounds],
                "times": [[float(t.actual), float(t.max), float(t.min)]
                          for t in server.times.per_round]}

    def load_extra(extra: dict) -> None:
        result.accuracies = [(int(r), float(a))
                             for r, a in extra["accuracies"]]
        result.executed_rounds = list(extra["executed_rounds"])
        for a, mx, mn in extra["times"]:
            server.times.add(cost_model.RoundTime(a, mx, mn))

    loop = BufferedAsyncLoop(
        n_clients=n, n_params=n_params, buffer_k=k_buf, concurrency=m_conc,
        target_flushes=sim.rounds, seed=sim.seed, alpha=sim.async_alpha,
        stall_s=sim.async_stall_s, p_fail=sim.async_p_fail_upload,
        retry=retry, links=links, v_bytes=v_bytes, cr_eff_all=cr_eff_all,
        ks_all=ks_all,
        coeff_table=(coeffs_all if strat.weighting == "bcrs" else None),
        fracs_all=fracs_all, merge=merge, train_update=train_update,
        on_flush=on_flush, checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every, extra_state=extra_state,
        load_extra=load_extra)
    t0 = time.perf_counter()
    flat = loop.run(server._flat, stop_after=stop_after)
    wall = time.perf_counter() - t0

    server._flat = flat
    server.params = server._unravel(flat)
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    nf = max(len(result.executed_rounds), 1)
    result.wall_per_round = [wall / nf] * len(result.executed_rounds)
    if ef:
        result.final_residuals = np.asarray(loop.store[:n])
    result.async_loop = loop
    return result


def _run_sync_parity(sim, acfg, rng, clients, parts, fracs_all, links,
                     server, steps_by_client, s_max, failure, straggler,
                     train, merge, xt, yt, result):
    """Degenerate-async parity mode: synchronous arrivals, buffer = cohort,
    staleness 0 (discount is the exact identity at s=0 for any alpha)."""
    from repro.fed import simulation as sim_mod
    n, n_params, bs = sim.n_clients, server.n_params, sim.batch_size
    n_sel = sim_mod.cohort_slots(n, sim.participation)
    ef = acfg.strat.needs_residuals

    plans = sim_mod._plan_rounds(sim, acfg, rng, clients, parts, fracs_all,
                                 links, server, steps_by_client, s_max,
                                 failure, straggler, False)
    if not plans:
        result.times = server.times
        return result
    store = (np.zeros((n + 1, n_params), np.float32) if ef
             else np.zeros((0,), np.float32))
    flat = server._flat
    for rnd, selected, weights, ks, _ko, idx in plans:
        t0 = time.perf_counter()
        c_r = len(selected)
        x = {"sample_idx": np.zeros((n_sel, s_max, bs), np.int32),
             "step_mask": np.zeros((n_sel, s_max), bool)}
        x["sample_idx"][:c_r] = idx.reshape(c_r, s_max, bs)
        for j, c in enumerate(selected):
            x["step_mask"][j, : int(steps_by_client[c])] = True
        updates = train(flat, {k: jnp.asarray(v) for k, v in x.items()})
        ids_pad = np.full((n_sel,), n, np.int64)
        ids_pad[:c_r] = selected
        wpad = np.zeros((n_sel,), np.float32)
        wpad[:c_r] = weights
        kpad = np.ones((n_sel,), np.int32)
        kpad[:c_r] = ks
        act = np.zeros((n_sel,), bool)
        act[:c_r] = True
        res_rows = (jnp.asarray(store[ids_pad]) if ef
                    else jnp.zeros((0,), jnp.float32))
        out = merge(flat, res_rows, {"updates": updates,
                                     "weights": jnp.asarray(wpad),
                                     "ks": jnp.asarray(kpad),
                                     "active": jnp.asarray(act)})
        flat = out["flat"]
        if ef:
            store[selected] = np.asarray(out["residuals"])[:c_r]
        result.wall_per_round.append(time.perf_counter() - t0)
        result.executed_rounds.append(rnd)
        if sim_mod._is_eval_round(sim, rnd):
            acc = float(sim_mod.mlp_accuracy(server._unravel(flat), xt, yt))
            result.accuracies.append((rnd, acc))

    server._flat = flat
    server.params = server._unravel(flat)
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    if ef:
        result.final_residuals = np.asarray(store[:n])
    return result
