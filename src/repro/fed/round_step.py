"""Fused FL round: ONE jitted program per simulation (paper Alg. 1 hot path).

The legacy harness dispatches ``local_train`` once per client from Python,
compresses each client at a *static* CR (re-lowering ``lax.top_k`` for every
distinct BCRS ratio: O(rounds × K) XLA compiles), restacks pytrees on host,
and applies the server update eagerly. This module collapses local training,
compression, error feedback, OPWA aggregation, and the server update into a
single compiled round:

  * clients are stacked on a leading axis and the local trainer is vmapped;
    ragged per-client step counts are handled with a step mask (padded steps
    are exact no-ops, so parity with the sequential loop is preserved);
  * per-client compression uses the traced-k bisection Top-K
    (``topk_compress_batch``) — one trace serves every BCRS schedule;
  * on TPU the EF step runs through the fused ``ef_update`` Pallas kernel
    and OPWA through ``overlap_combine`` (CPU/GPU interpret or XLA paths);
  * the server update ``w ← w − η·agg`` happens inside the same jit with the
    flat parameter and residual buffers donated.

Per-round *scalars* (BCRS CRs, Eq. 6 coefficients, retained counts) stay
host-scheduled numpy — they enter as traced [K] inputs, never as static args.
"""
from __future__ import annotations

import collections
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg_mod
from repro.core import compression as comp
from repro.core import opwa as opwa_mod
from repro.models import flags

#: module-wide retrace telemetry: (strategy, with_overlap) -> number of times
#: a fused round step was traced. A simulation is O(1)-compile iff this stays
#: constant as rounds/clients grow (asserted in tests/test_round_step.py).
TRACE_COUNTS: collections.Counter = collections.Counter()


# ------------------------------------------------------------- flat <-> tree
def _leaf_specs(params_template):
    leaves, treedef = jax.tree.flatten(params_template)
    specs = [(l.shape, l.dtype, int(np.prod(l.shape, dtype=np.int64)))
             for l in leaves]
    return treedef, specs, int(sum(s for _, _, s in specs))


def make_unflatten(params_template) -> Callable:
    """[n] flat f32 -> pytree shaped/dtyped like ``params_template`` (same
    leaf order as ``ravel_pytree``, so it round-trips with ``flatten_tree``)."""
    treedef, specs, n = _leaf_specs(params_template)

    def unflatten(flat):
        out, off = [], 0
        for shape, dtype, size in specs:
            out.append(flat[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return unflatten


def flatten_client_trees(deltas) -> jax.Array:
    """pytree with leading [C, ...] leaves -> [C, n] f32, ravel order."""
    leaves = jax.tree.leaves(deltas)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1)


# ----------------------------------------------------------- masked trainer
def make_masked_local_trainer(loss_fn: Callable, lr: float):
    """``local_train(params, batches, step_mask) -> (delta, last_loss)``.

    Same SGD arithmetic as ``fed.client.make_local_trainer`` but scans a
    *fixed* number of padded steps; steps with ``step_mask`` False leave the
    parameters untouched, so clients with fewer real steps match the ragged
    sequential loop bit-for-bit while keeping one static shape for vmap.
    The reported loss is the pre-update loss of the last real step (one
    forward pass per step via value_and_grad — the legacy trainer's
    post-update loss recompute is a third of its step FLOPs and feeds
    nothing downstream; the deltas are unaffected).
    """
    vg_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def sgd_step(carry, xs):
        params, last_loss = carry
        batch, m = xs
        loss, grads = vg_fn(params, batch)
        new = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
        new = jax.tree.map(lambda a, b: jnp.where(m, a, b), new, params)
        loss = jnp.where(m, loss, last_loss)
        return (new, loss), None

    def local_train(params, batches, step_mask):
        n_steps = jax.tree.leaves(batches)[0].shape[0]
        (final, loss), _ = jax.lax.scan(
            sgd_step, (params, jnp.float32(0.0)), (batches, step_mask),
            unroll=flags.scan_unroll(n_steps))
        delta = jax.tree.map(lambda a, b: (a - b).astype(a.dtype),
                             params, final)
        return delta, loss

    return local_train


# -------------------------------------------------------------- fused round
class FusedRoundStep:
    """Callable wrapper around the jitted round (retrace telemetry lives in
    the module-level TRACE_COUNTS)."""

    def __init__(self, fn, strategy: str, with_overlap: bool):
        self._fn = fn
        self.strategy = strategy
        self.with_overlap = with_overlap

    def __call__(self, flat, residuals, batches, step_mask, weights, ks,
                 ks_overlap):
        return self._fn(flat, residuals, batches, step_mask, weights, ks,
                        ks_overlap)


def make_round_step(loss_fn: Callable, params_template, *, lr: float,
                    acfg: agg_mod.AggregationConfig, eta: float = 1.0,
                    with_overlap: bool = False) -> FusedRoundStep:
    """Build the fused round program.

    Returned step signature (all array args traced)::

        step(flat [n] f32,            # donated: global model, ravel order
             residuals [C, n] | None, # donated for eftopk
             batches,                 # pytree of [C, S, ...] stacked batches
             step_mask [C, S] bool,   # padded-step validity
             weights [C] f32,         # data fracs or BCRS Eq. 6 coefficients
             ks [C] i32,              # retained count per client (per block
                                      # when acfg.block_topk)
             ks_overlap [C] i32)      # global top-k count for the Fig. 4
                                      # overlap counts (overlap variant only)
        -> {"flat", "residuals", "loss"[, "overlap_counts"]}
    """
    strategy = acfg.strategy
    if strategy not in ("fedavg", "topk", "eftopk", "bcrs", "bcrs_opwa"):
        raise ValueError(f"unknown strategy {strategy!r}")
    use_kernel = comp.resolve_use_kernel(acfg.use_kernel)
    # the fused EF Pallas kernel selects per block at a static k — only a
    # faithful route when the config already asks for block top-k; global
    # top-k configs stay on the traced-k path so TPU matches CPU/legacy
    use_ef_kernel = use_kernel and acfg.block_topk
    unflatten = make_unflatten(params_template)
    local_train = make_masked_local_trainer(loss_fn, lr)
    if acfg.block_topk:
        def compress_batch(u, ks):
            return comp.block_topk_compress_batch(u, ks,
                                                  block=acfg.block_size)
    else:
        compress_batch = comp.topk_compress_batch

    def ef_kernel_step(updates, residuals):
        """Clients-as-rows fused EF Pallas step (uniform static CR)."""
        from repro.kernels.ef_update import ROWS_TILE, ef_update_pallas
        from repro.kernels.ops import _interpret
        c, n = updates.shape
        block = acfg.block_size
        kb = comp.k_for_ratio(block, acfg.cr)
        n_pad = (-n) % block
        g = jnp.pad(updates, ((0, 0), (0, n_pad)))
        e = jnp.pad(residuals, ((0, 0), (0, n_pad)))
        nb = g.shape[1] // block
        g2d = g.reshape(c * nb, block)
        e2d = e.reshape(c * nb, block)
        rpad = (-(c * nb)) % ROWS_TILE
        if rpad:
            g2d = jnp.pad(g2d, ((0, rpad), (0, 0)))
            e2d = jnp.pad(e2d, ((0, rpad), (0, 0)))
        send, new_e = ef_update_pallas(g2d, e2d, kb, interpret=_interpret())
        send = send[:c * nb].reshape(c, nb * block)[:, :n]
        new_e = new_e[:c * nb].reshape(c, nb * block)[:, :n]
        return send, new_e

    def _step(flat, residuals, batches, step_mask, weights, ks, ks_overlap):
        # host side effect: runs only at trace time
        TRACE_COUNTS[(strategy, with_overlap)] += 1

        params = unflatten(flat)
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, batches, step_mask)
        updates = flatten_client_trees(deltas)          # [C, n] f32
        w = weights.astype(jnp.float32)
        new_res = residuals

        if strategy == "fedavg":
            agg = jnp.einsum("k,kn->n", w, updates)
        elif strategy in ("topk", "bcrs"):
            cvals, _ = compress_batch(updates, ks)
            agg = jnp.einsum("k,kn->n", w, cvals.astype(jnp.float32))
        elif strategy == "eftopk":
            if use_ef_kernel:
                cvals, new_res = ef_kernel_step(updates, residuals)
            else:
                c_obj, new_res = comp.ef_compress_batch(
                    residuals, updates, ks, compress_batch=compress_batch)
                cvals = c_obj.values
            agg = jnp.einsum("k,kn->n", w, cvals.astype(jnp.float32))
        else:  # bcrs_opwa
            cvals, cmask = compress_batch(updates, ks)
            agg = opwa_mod.opwa_aggregate(cvals, cmask, w, acfg.gamma,
                                          acfg.overlap_d,
                                          use_kernel=use_kernel)

        out = {"flat": flat - eta * agg,
               "residuals": new_res,
               "loss": jnp.mean(losses)}
        if with_overlap:
            # Fig. 4 instrumentation: global top-k masks on the RAW deltas
            # (mirrors the legacy host-side recomputation)
            masks_o = comp.topk_compress_batch(updates, ks_overlap).mask
            out["overlap_counts"] = opwa_mod.overlap_counts(masks_o)
        return out

    donate = (0, 1) if strategy == "eftopk" else (0,)
    fn = jax.jit(_step, donate_argnums=donate)
    return FusedRoundStep(fn, strategy, with_overlap)
