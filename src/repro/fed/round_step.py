"""Fused FL round: ONE jitted program per round (paper Alg. 1 hot path).

Thin adapter over the shared substrate in ``repro.fed.engine``: the masked
vmapped local trainer, traced-k compression, batched EF, OPWA merge, and the
server update all come from there — this module only assembles them into the
per-round program and owns its retrace telemetry. The whole-simulation
``lax.scan`` lowering lives in ``engine.make_sim_scan``; the legacy eager
loop stays in ``fed.server.FLServer.round``.

  * clients are stacked on a leading axis and the local trainer is vmapped;
    ragged per-client step counts are handled with a step mask (padded steps
    are exact no-ops, so parity with the sequential loop is preserved);
  * per-client compression uses the traced-k bisection Top-K
    (``topk_compress_batch``) — one trace serves every BCRS schedule;
  * on TPU the EF step runs through the fused ``ef_update`` Pallas kernel
    and OPWA through ``overlap_combine`` (CPU/GPU interpret or XLA paths);
  * the server update ``w ← w − η·agg`` happens inside the same jit with the
    flat parameter and residual buffers donated; the stacked client batch
    buffers are re-staged every round by the harness's double-buffered
    prefetch (round r+1 transfers while round r computes).

Per-round *scalars* (BCRS CRs, Eq. 6 coefficients, retained counts) stay
host-scheduled numpy — they enter as traced [K] inputs, never as static args.
"""
from __future__ import annotations

import collections
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import aggregation as agg_mod
from repro.core import compression as comp
from repro.core import opwa as opwa_mod
from repro.fed import engine
# re-exported for API stability (previous home of these helpers)
from repro.fed.engine import (flatten_client_trees, make_masked_local_trainer,
                              make_unflatten)

#: module-wide retrace telemetry: (strategy, with_overlap) -> number of times
#: a fused round step was traced. A simulation is O(1)-compile iff this stays
#: constant as rounds/clients grow (asserted in tests/test_round_step.py).
TRACE_COUNTS: collections.Counter = collections.Counter()


# -------------------------------------------------------------- fused round
class FusedRoundStep:
    """Callable wrapper around the jitted round (retrace telemetry lives in
    the module-level TRACE_COUNTS)."""

    def __init__(self, fn, strategy: str, with_overlap: bool):
        self._fn = fn
        self.strategy = strategy
        self.with_overlap = with_overlap

    def __call__(self, flat, residuals, batches, step_mask, weights, ks,
                 ks_overlap):
        return self._fn(flat, residuals, batches, step_mask, weights, ks,
                        ks_overlap)


def make_round_step(loss_fn: Callable, params_template, *, lr: float,
                    acfg: agg_mod.AggregationConfig, eta: float = 1.0,
                    with_overlap: bool = False) -> FusedRoundStep:
    """Build the fused round program.

    Returned step signature (all array args traced)::

        step(flat [n] f32,            # donated: global model, ravel order
             residuals [C, n] | None, # donated for eftopk
             batches,                 # pytree of [C, S, ...] stacked batches
             step_mask [C, S] bool,   # padded-step validity
             weights [C] f32,         # data fracs or BCRS Eq. 6 coefficients
             ks [C] i32,              # retained count per client (per block
                                      # when acfg.block_topk)
             ks_overlap [C] i32)      # global top-k count for the Fig. 4
                                      # overlap counts (overlap variant only)
        -> {"flat", "residuals", "loss"[, "overlap_counts"]}
    """
    spec = engine.spec_for(acfg)
    strategy = spec.strategy
    unflatten = engine.make_unflatten(params_template)
    local_train = engine.make_masked_local_trainer(loss_fn, lr)

    def _step(flat, residuals, batches, step_mask, weights, ks, ks_overlap):
        # host side effect: runs only at trace time
        TRACE_COUNTS[(strategy, with_overlap)] += 1

        params = unflatten(flat)
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, batches, step_mask)
        updates = engine.flatten_client_trees(deltas)   # [C, n] f32
        agg, new_res = engine.aggregate_updates(
            spec, updates, weights, ks, residuals=residuals)

        out = {"flat": flat - eta * agg,
               "residuals": new_res,
               "loss": jnp.mean(losses)}
        if with_overlap:
            # Fig. 4 instrumentation: global top-k masks on the RAW deltas
            # (mirrors the legacy host-side recomputation)
            masks_o = comp.topk_compress_batch(updates, ks_overlap).mask
            out["overlap_counts"] = opwa_mod.overlap_counts(masks_o)
        return out

    # batches/step_mask are deliberately NOT donated: none of the outputs
    # match their byte size, so XLA cannot alias them and the donation would
    # only emit "donated buffers were not usable" warnings. Their staging
    # cost is hidden instead by the harness's double-buffered prefetch
    # (simulation.run_fl stages round r+1 while round r computes).
    donate = (0, 1) if spec.needs_residuals else (0,)
    fn = jax.jit(_step, donate_argnums=donate)
    return FusedRoundStep(fn, strategy, with_overlap)


# -------------------------------------------- population slot-gather round
class PopulationRoundStep:
    """Callable wrapper around the jitted population round: the slot-gather
    adapter between a ``population.ClientStateStore`` and the unchanged
    compress/EF/merge substrate. Residual I/O happens in the store's wire
    layout (sparse ``(idx, val)`` pairs for "topk_complement", full rows
    for "dense"), densified/sparsified INSIDE the jit boundary — the host
    never materializes a ``[P, n]`` (or even a second ``[C, n]``) buffer."""

    def __init__(self, fn, spec, layout, width):
        self._fn = fn
        self.spec = spec
        self.strategy = spec.strategy
        self.layout = layout       # None when the strategy carries no EF
        self.width = width         # sparse pair width (topk_complement only)

    def __call__(self, flat, residuals, x):
        return self._fn(flat, residuals, x)

    def init_residuals(self, cohort: int, n: int):
        """Zero residual buffers in this step's wire layout (what a client
        that never participated gathers from the store)."""
        if self.layout is None:
            return jnp.zeros((0,), jnp.float32)
        if self.layout == "topk_complement":
            return (jnp.zeros((cohort, self.width), jnp.int32),
                    jnp.zeros((cohort, self.width), jnp.float32))
        return jnp.zeros((cohort, n), jnp.float32)


def make_population_round_step(loss_fn: Callable, params_template, *,
                               lr: float, acfg: agg_mod.AggregationConfig,
                               eta: float = 1.0, width: int = 0,
                               make_batches: Callable = None
                               ) -> PopulationRoundStep:
    """Build the population (streaming-cohort) round program.

    The round body is the fused step's, but EF residuals arrive in the
    client store's persisted layout and leave the same way — gather input /
    scatter output instead of a resident donated carry:

        step(flat [n] f32,                        # donated
             residuals,                           # donated; layout-typed:
                                                  #  topk_complement:
                                                  #    (idx [C, W] i32,
                                                  #     val [C, W] f32)
                                                  #  dense: [C, n] f32
                                                  #  carry="none": [0] f32
             x: {"step_mask" [C, S] bool,
                 "active"    [C]    bool,         # padded cohort slots
                 "weights"   [C]    f32,          # 0 at inactive slots
                 "ks"        [C]    i32,
                 + whatever ``make_batches`` consumes (default "batches",
                   a pytree of [C, S, ...] stacked client batches)})
        -> {"flat", "residuals" (same layout), "loss", "overflow"}

    ``width`` is the static sparse-pair width for "topk_complement"
    strategies — ``population.residual_width`` derives it from the whole
    plan's minimum retained count (nnz <= n - k_min, ties only shrink it).
    ``overflow`` (bool scalar) is True iff a row's residual outgrew the
    width; callers assert on it rather than silently truncating EF state.
    Inactive slots round-trip their residuals unchanged (same ``active``
    semantics as ``aggregate_updates``), so the host can scatter only the
    real cohort prefix back to the store.
    """
    spec = engine.spec_for(acfg)
    strategy = spec.strategy
    strat = spec.strat
    unflatten = engine.make_unflatten(params_template)
    local_train = engine.make_masked_local_trainer(loss_fn, lr)
    get_batches = make_batches or (lambda x: x["batches"])
    ef = spec.needs_residuals
    layout = strat.residual_layout if ef else None
    if layout == "topk_complement" and width <= 0:
        raise ValueError(
            f"{strategy} persists residuals as topk_complement pairs — "
            "make_population_round_step needs width > 0 (n - k_min)")

    def _step(flat, residuals, x):
        # host side effect: runs only at trace time
        TRACE_COUNTS[("population", strategy)] += 1

        params = unflatten(flat)
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, get_batches(x), x["step_mask"])
        updates = engine.flatten_client_trees(deltas)   # [C, n] f32
        active = x["active"]
        n = updates.shape[1]

        if layout == "topk_complement":
            res_rows = engine.densify_rows(*residuals, n)
        else:
            res_rows = residuals if ef else None
        agg, new_rows = engine.aggregate_updates(
            spec, updates, x["weights"], x["ks"],
            residuals=res_rows, active=active)

        n_act = jnp.maximum(jnp.sum(active.astype(jnp.int32)), 1)
        out = {"flat": flat - eta * agg,
               "loss": jnp.sum(jnp.where(active, losses, 0.0)) / n_act,
               "overflow": jnp.asarray(False)}
        if layout == "topk_complement":
            idx, val, overflow = engine.sparsify_rows(new_rows, width)
            out["residuals"] = (idx, val)
            out["overflow"] = overflow
        elif ef:
            out["residuals"] = new_rows
        else:
            out["residuals"] = residuals
        return out

    fn = jax.jit(_step, donate_argnums=(0, 1) if ef else (0,))
    return PopulationRoundStep(fn, spec, layout, width)
