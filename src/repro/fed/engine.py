"""One compression/aggregation substrate for every FL round engine.

Before this module the repo carried three divergent implementations of
"compress each client's update, then merge": the fused round program
(``fed.round_step``), the mesh-parallel round's inline float-space bisection
(``fed.mesh_round``), and the compressed pod sync (``dist.grad_sync``). They
are now thin adapters over the pure functions here:

  * ``ClientUpdateSpec``      — static description of the client-update
                                pipeline (strategy, block/kernel routing,
                                OPWA constants), derived from an
                                ``AggregationConfig`` via ``spec_for``;
  * ``aggregate_updates``     — flat-space path: [C, n] stacked updates ->
                                traced-k compression (integer-bit bisection),
                                batched error feedback, OPWA/weighted merge.
                                Used by the fused per-round program and the
                                scanned simulation;
  * ``compress_merge_leaf``   — per-leaf path: [C, *shape] updates in their
                                natural (possibly TP-sharded) layout. The
                                bisection reduces over the non-client axes,
                                so sharded leaves stay sharded. Used by
                                ``mesh_round`` and ``grad_sync``;
  * ``make_sim_scan``         — the fourth entry point: the ENTIRE
                                multi-round simulation lowered into one
                                ``lax.scan`` over rounds (server flat params
                                + EF residuals threaded as carry, host-
                                precomputed per-round schedules as xs).
                                ONE compile per simulation, zero per-round
                                dispatch.

Every Top-K selection in the tree routes through
``core.compression.topk_compress_dynamic`` semantics — the traced-k
bit-pattern bisection (ties kept). With ``use_kernel`` on, the flat-space
path lowers the WHOLE compress->EF->merge pipeline to two Pallas kernels
(``kernels.threshold_find`` + ``kernels.fused_merge``) that are bit-exact
with the jnp lowering while making ~9 logical HBM passes over the [C, n]
update matrix instead of ~35; the jnp path stays as the parity reference.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core import opwa as opwa_mod
from repro.core import strategies as strat_mod
from repro.models import flags

#: module-wide retrace telemetry for the scanned simulation:
#: ("sim_scan", strategy, with_overlap) -> number of traces. A simulation is
#: O(1)-compile iff this stays at 1 regardless of rounds/clients (asserted in
#: tests/test_sim_scan.py).
TRACE_COUNTS: collections.Counter = collections.Counter()


# ------------------------------------------------------------------- spec
@dataclass(frozen=True)
class ClientUpdateSpec:
    """Static (trace-time) description of the per-client update pipeline:
    compress (traced-k Top-K / blockwise / EF) -> OPWA or weighted merge.
    All runtime quantities (per-client retained counts ``ks``, weights,
    residuals) stay traced arguments of the functions below. Everything
    strategy-shaped is read from the capability record
    (``core.strategies.get``) — this module never matches strategy names."""
    strategy: str = "fedavg"
    cr: float = 0.1                # static CR* (only the EF Pallas kernel
    block_topk: bool = False       # needs it — everything else is traced)
    block_size: int = 8192
    gamma: float = 5.0
    overlap_d: int = 1
    use_kernel: bool = False       # resolved bool (never "auto")

    def __post_init__(self):
        strat_mod.get(self.strategy)   # config-time error, names listed

    @property
    def strat(self) -> strat_mod.Strategy:
        """The registered capability record (dict lookup — trace-time cheap)."""
        return strat_mod.get(self.strategy)

    @property
    def needs_residuals(self) -> bool:
        return self.strat.needs_residuals

    @property
    def use_megakernel(self) -> bool:
        # the traced-k Pallas pipeline (threshold_find + fused_merge) serves
        # every global-top-k strategy at per-client traced ks — the paper's
        # BCRS-faithful default. Block-top-k configs keep the traced-k jnp
        # block path (per-block thresholds), dense strategies are already a
        # single einsum pass, and codec strategies route through the
        # kernel's quantize/dequantize stage iff they registered a
        # kernel_codec (the megakernel capability is per-codec). NOTE the
        # old `use_ef_kernel` route (static-CR ef_update kernel) is gone:
        # it silently compressed at spec.cr even when the schedule passed
        # varying traced ks.
        return (self.use_kernel and not self.block_topk
                and self.strat.megakernel and self.strat.compresses)


def spec_for(acfg) -> ClientUpdateSpec:
    """AggregationConfig -> ClientUpdateSpec (resolves use_kernel="auto")."""
    return ClientUpdateSpec(
        strategy=acfg.strategy, cr=acfg.cr, block_topk=acfg.block_topk,
        block_size=acfg.block_size, gamma=acfg.gamma,
        overlap_d=acfg.overlap_d,
        use_kernel=comp.resolve_use_kernel(acfg.use_kernel))


def compress_batch_fn(spec: ClientUpdateSpec) -> Callable:
    """Batched traced-k compressor for the spec: [C, n], ks [C] -> Compressed.
    When the strategy declares a ``value_codec``, the survivors come back
    already dequantized — downstream EF/merge code needs no codec branch."""
    if spec.block_topk:
        base = lambda u, ks: comp.block_topk_compress_batch(
            u, ks, block=spec.block_size)
    else:
        base = comp.topk_compress_batch
    codec = spec.strat.value_codec
    if codec is None:
        return base

    def compress(u, ks):
        c = base(u, ks)
        return comp.Compressed(codec(c.values, c.mask), c.mask)

    return compress


# ------------------------------------------------------------- flat <-> tree
def _leaf_specs(params_template):
    leaves, treedef = jax.tree.flatten(params_template)
    specs = [(l.shape, l.dtype, int(np.prod(l.shape, dtype=np.int64)))
             for l in leaves]
    return treedef, specs, int(sum(s for _, _, s in specs))


def make_unflatten(params_template) -> Callable:
    """[n] flat f32 -> pytree shaped/dtyped like ``params_template`` (same
    leaf order as ``ravel_pytree``, so it round-trips with ``flatten_tree``)."""
    treedef, specs, n = _leaf_specs(params_template)

    def unflatten(flat):
        out, off = [], 0
        for shape, dtype, size in specs:
            out.append(flat[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, out)

    return unflatten


def flatten_client_trees(deltas) -> jax.Array:
    """pytree with leading [C, ...] leaves -> [C, n] f32, ravel order."""
    leaves = jax.tree.leaves(deltas)
    return jnp.concatenate(
        [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
        axis=1)


# ------------------------------------------------- sparse EF residual codec
def sparsify_rows(rows: jax.Array, width: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """[C, n] f32 -> (idx [C, width] i32, val [C, width] f32, overflow).

    The jit-side half of the population client-state store's
    "topk_complement" residual layout: a pure-Top-K EF residual is nonzero
    only on the coordinates the selection dropped, so nnz <= n - k and a
    static ``width = n - k_min`` buffer holds it losslessly. A stable
    argsort on the zero-flag packs the nonzero coordinates first (ascending
    index order — deterministic), padding entries carry the zero values at
    their own coordinates, so ``densify_rows`` scatter-adds them back as
    exact no-ops. ``overflow`` is True iff some row has nnz > width — the
    host asserts on it rather than silently truncating a residual.

    Returns (idx i32, val f32, overflow bool scalar).
    """
    zero = rows == 0.0
    order = jnp.argsort(zero, axis=1, stable=True)
    idx = order[:, :width].astype(jnp.int32)
    val = jnp.take_along_axis(rows, order[:, :width], axis=1)
    overflow = jnp.any(jnp.sum(~zero, axis=1) > width)
    return idx, val, overflow


def densify_rows(idx: jax.Array, val: jax.Array, n: int) -> jax.Array:
    """(idx [C, W] i32, val [C, W] f32) -> [C, n] f32 — inverse of
    ``sparsify_rows``. Within a row the indices are a slice of a
    permutation (all distinct), so the scatter-add reconstructs each stored
    value exactly; padding entries add 0.0 at their own coordinate."""
    c = idx.shape[0]
    rows = jnp.zeros((c, n), val.dtype)
    return rows.at[jnp.arange(c)[:, None], idx].add(val)


# ----------------------------------------------------------- masked trainer
def make_masked_local_trainer(loss_fn: Callable, lr: float):
    """``local_train(params, batches, step_mask) -> (delta, last_loss)``.

    Same SGD arithmetic as ``fed.client.make_local_trainer`` but scans a
    *fixed* number of padded steps; steps with ``step_mask`` False leave the
    parameters untouched, so clients with fewer real steps match the ragged
    sequential loop bit-for-bit while keeping one static shape for vmap.
    The reported loss is the pre-update loss of the last real step (one
    forward pass per step via value_and_grad — the legacy trainer's
    post-update loss recompute is a third of its step FLOPs and feeds
    nothing downstream; the deltas are unaffected).

    Wave-composition contract (the async engine's batched dispatch leans on
    this): each vmapped lane reads only its own (params, batches, mask)
    slice, so a client's delta is invariant to the WIDTH of the vmap it
    rides in and to which other clients share the batch — training clients
    one-at-a-time, in eager waves of one, or in padded pow2 wave buckets
    produces bit-identical deltas. Anything added here must preserve that
    (no cross-lane reductions, no width-dependent arithmetic).
    """
    vg_fn = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def sgd_step(carry, xs):
        params, last_loss = carry
        batch, m = xs
        loss, grads = vg_fn(params, batch)
        new = jax.tree.map(
            lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
            params, grads)
        new = jax.tree.map(lambda a, b: jnp.where(m, a, b), new, params)
        loss = jnp.where(m, loss, last_loss)
        return (new, loss), None

    def local_train(params, batches, step_mask):
        n_steps = jax.tree.leaves(batches)[0].shape[0]
        (final, loss), _ = jax.lax.scan(
            sgd_step, (params, jnp.float32(0.0)), (batches, step_mask),
            unroll=flags.scan_unroll(n_steps))
        delta = jax.tree.map(lambda a, b: (a - b).astype(a.dtype),
                             params, final)
        return delta, loss

    return local_train


# -------------------------------------------------------- megakernel routing
def _aggregate_megakernel(spec: ClientUpdateSpec, updates: jax.Array,
                          w: jax.Array, ks: jax.Array,
                          residuals: Optional[jax.Array],
                          active: Optional[jax.Array]
                          ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Traced-k Pallas pipeline: exact per-client thresholds in 8 streamed
    HBM sweeps (``threshold_find``), then EF correction, masking, overlap
    counts, the OPWA mask, and the weighted merge in ONE further pass
    (``fused_merge``) — bit-exact with the jnp path below, ~9 logical HBM
    passes over [C, n] instead of ~35 (see repro.roofline.kernel_bytes).

    This route REPLACES the old ``ef_kernel_step`` (static-CR ``ef_update``
    kernel), which silently compressed at ``spec.cr`` even when the BCRS
    schedule passed varying traced ``ks`` — the megakernel honors the traced
    per-client counts exactly (regression-tested in
    tests/test_megakernel.py).

    Codec strategies ride the same pipeline: the registered
    ``kernel_codec`` selects fused_merge's quantize/dequantize stage, with
    the per-client scale emitted by threshold_find on its already-streamed
    sweep — bit-exact with the jnp ``value_codec`` path (DESIGN.md §10)."""
    codec = spec.strat.kernel_codec or "none"
    if spec.strat.overlap_weighted and not spec.needs_residuals:
        agg = opwa_mod.opwa_aggregate_traced_k(
            updates, ks, w, spec.gamma, spec.overlap_d, active=active,
            use_kernel=True)
        return agg, residuals
    from repro.kernels import ops as kops
    agg, new_res = kops.megakernel_aggregate(
        updates, ks, w, residuals=residuals, active=active,
        opwa=spec.strat.overlap_weighted, gamma=spec.gamma,
        d=spec.overlap_d, codec=codec)
    return agg, (new_res if spec.needs_residuals else residuals)


# ------------------------------------------------------------ flat-space path
def aggregate_updates(spec: ClientUpdateSpec, updates: jax.Array,
                      weights: jax.Array, ks: jax.Array,
                      residuals: Optional[jax.Array] = None,
                      active: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Compress + merge stacked flat client updates (pure, jit/vmap-safe).

    updates [C, n] f32; weights [C] (data fracs or BCRS Eq. 6 coefficients);
    ks [C] i32 traced retained counts (per block when ``spec.block_topk``);
    residuals [C, n] EF state (required iff ``spec.needs_residuals``);
    active: optional bool [C] — inactive rows (padded cohort slots in the
    scanned simulation) contribute nothing to the merge or the OPWA overlap
    counts, and their residuals pass through unchanged. Active rows are
    multiplied by 1.0 / masked with True, so the no-mask arithmetic is
    preserved bit-for-bit.

    Returns (agg [n] f32, new_residuals | None).
    """
    w = weights.astype(jnp.float32)
    strat = spec.strat
    if strat.needs_residuals and residuals is None:
        raise ValueError(f"{spec.strategy} needs residuals")
    if spec.use_megakernel:
        # traced-k Pallas pipeline: selection thresholds + the whole
        # apply/merge in ~9 HBM passes; EF, OPWA, and active gating happen
        # inside the kernels. Bit-exact with the jnp path below.
        return _aggregate_megakernel(spec, updates, w, ks, residuals, active)

    compress = compress_batch_fn(spec)
    mask = None
    new_res = residuals

    if not strat.compresses:
        vals = updates
    elif strat.needs_residuals:
        c_obj, new_res = comp.ef_compress_batch(
            residuals, updates, ks, compress_batch=compress)
        vals, mask = c_obj.values, c_obj.mask
        if active is not None:
            new_res = jnp.where(active[:, None], new_res, residuals)
    else:
        c_obj = compress(updates, ks)
        vals, mask = c_obj.values, c_obj.mask

    if active is not None:
        # padded rows are all-zero updates, but a Top-K mask over zeros is
        # all-True (ties at the threshold) — force them out of the overlap
        # counts and the merge
        vals = vals * active[:, None]
        if mask is not None:
            mask = mask & active[:, None]

    if strat.overlap_weighted:
        agg = opwa_mod.opwa_aggregate(vals, mask, w, spec.gamma,
                                      spec.overlap_d,
                                      use_kernel=spec.use_kernel)
    else:
        agg = jnp.einsum("k,kn->n", w, vals.astype(jnp.float32))
    return agg, new_res


# ------------------------------------------------------------- per-leaf path
def compress_merge_leaf(updates: jax.Array, coeffs: jax.Array, ks: jax.Array,
                        *, gamma: float = 1.0, overlap_d: int = 1,
                        opwa: bool = True, use_kernel="auto",
                        residuals: Optional[jax.Array] = None,
                        active: Optional[jax.Array] = None,
                        value_codec: Optional[Callable] = None,
                        kernel_codec: Optional[str] = None
                        ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Compress + merge ONE leaf in its natural layout.

    updates: [C, *shape] per-client (or per-pod) leaf updates — the bisection
    Top-K reduces over all non-client axes, so a TP-sharded leaf never gets
    reshaped/gathered (see mesh_round). coeffs [C]; ks [C] i32 traced.
    ``residuals`` (matching [C, *shape], f32) switches on error feedback.
    ``opwa=False`` skips the overlap mask (plain weighted merge of the
    compressed values). ``active`` (bool [C]) gates padded cohort slots out
    of the merge, the overlap counts, and the residual update — the same
    semantics as ``aggregate_updates``. ``use_kernel`` is the usual
    tri-state (True / False / "auto" = TPU only, resolved here via
    ``resolve_use_kernel`` so callers can pass "auto" straight through).
    ``value_codec`` (a registry ``Strategy.value_codec``) is applied to the
    survivors before the merge AND before the residual update, so EF absorbs
    the codec error. ``kernel_codec`` (the registry's
    ``Strategy.kernel_codec``) is the codec's kernel-route capability: when
    set, the megakernel runs fused_merge's matching quantize/dequantize
    stage — bit-exact with the jnp ``value_codec`` path — instead of
    forcing the leaf back onto the jnp lowering.

    The kernel route runs the whole leaf through the traced-k megakernel
    pipeline (``threshold_find`` + ``fused_merge``) on a [C, leaf_n] view —
    bit-exact with the jnp path (per-client selection is over the whole leaf
    either way, so the reshape changes nothing numerically). NOTE the view
    merges the leaf's non-client axes, so on a TP-sharded leaf XLA inserts a
    gather first; the jnp path stays fully sharding-preserving and remains
    the default off-TPU.

    Returns (agg [*shape] f32, new_residuals | None).
    """
    w = coeffs.astype(jnp.float32)
    if active is not None:
        w = jnp.where(active, w, 0.0)
    if ((value_codec is None or kernel_codec is not None)
            and comp.resolve_use_kernel(use_kernel)):
        from repro.kernels import ops as kops
        c, shape = updates.shape[0], updates.shape[1:]
        u2 = updates.astype(jnp.float32).reshape(c, -1)
        r2 = (residuals.astype(jnp.float32).reshape(c, -1)
              if residuals is not None else None)
        agg2, new_res2 = kops.megakernel_aggregate(
            u2, ks, w, residuals=r2, active=active, opwa=opwa,
            gamma=float(gamma), d=int(overlap_d),
            codec=kernel_codec or "none")
        return (agg2.reshape(shape),
                new_res2.reshape((c,) + shape) if residuals is not None
                else None)
    x = updates.astype(jnp.float32)
    if residuals is not None:
        x = residuals + x
    c_obj = jax.vmap(comp.topk_compress_dynamic)(x, ks)
    vals, mask = c_obj.values, c_obj.mask
    if value_codec is not None:
        vals = value_codec(vals, mask)
    new_res = (x - vals) if residuals is not None else None
    if active is not None:
        # padded rows are all-zero updates whose tie-at-zero Top-K mask is
        # all-True — gate them out of the merge/counts; their residuals
        # pass through unchanged
        ax = active.reshape((-1,) + (1,) * (updates.ndim - 1))
        vals = vals * ax
        mask = mask & ax
        if new_res is not None:
            new_res = jnp.where(ax, new_res,
                                residuals.astype(jnp.float32))
    if opwa:
        agg = opwa_mod.opwa_aggregate(vals, mask, w, gamma,
                                      overlap_d, use_kernel=False)
    else:
        agg = jnp.tensordot(w, vals, axes=(0, 0))
    return agg, new_res


# ---------------------------------------------------------- scanned simulation
class SimScan:
    """Callable wrapper around the jitted whole-simulation scan program."""

    def __init__(self, fn, spec: ClientUpdateSpec, with_overlap: bool):
        self._fn = fn
        self.spec = spec
        self.with_overlap = with_overlap

    def __call__(self, flat, residuals, evals, xs):
        return self._fn(flat, residuals, evals, xs)

    def compile(self, flat, residuals, evals, xs):
        """AOT lower+compile for the given arguments. The returned compiled
        executable lets callers separate the one-off trace/compile cost from
        steady-state execution (``benchmarks.bench_round --sim-scan`` times
        the executable alone)."""
        return self._fn.lower(flat, residuals, evals, xs).compile()


def make_sim_scan(loss_fn: Callable, params_template, *, lr: float,
                  acfg, eta: float = 1.0, with_overlap: bool = False,
                  make_batches: Optional[Callable] = None,
                  plan_fn: Optional[Callable] = None,
                  population: Optional[int] = None) -> SimScan:
    """Lower the ENTIRE multi-round FL simulation into one ``lax.scan``.

    Where ``round_step.make_round_step`` compiles one round and Python
    dispatches it R times, this compiles the R-round trajectory into a single
    program: the server's flat params and EF residuals thread through the
    scan carry, and everything the host scheduler decides per round (cohort
    composition, BCRS CR schedules, failure/straggler survivors) arrives as
    stacked ``[R, ...]`` scan xs. One compile, zero per-round dispatch.

    Returned program signature (flat, residuals, and evals donated)::

        sim(flat [n] f32,
            residuals [C, n] f32 ([0] when the strategy carries no EF),
            evals [E, n] f32 (zeros; E = number of host eval rounds >= 1),
            xs: {
              "step_mask"  [R, C, S] bool,   # padded-step validity
              "active"     [R, C]    bool,   # padded cohort-slot validity
              "weights"    [R, C]    f32,    # 0 at inactive slots
              "ks"         [R, C]    i32,
              "eval_write" [R]       bool,   # snapshot the model this round
              "eval_slot"  [R]       i32,    # evals row it lands in
              "reset_ef"   [R]       bool,   # eftopk only: cohort resized
              + whatever ``make_batches`` consumes (default: "batches", a
                pytree of [R, C, S, ...] stacked client batches; the
                simulation harness passes [R, C, S, B] sample indices and a
                gather closure instead, which is ~250x smaller host->device),
              + with_overlap: "ks_overlap" [R, C] i32, "overlap_round" [R]
            })
        -> {"flat": [n], "residuals": [C, n], "evals": [E, n],
            "ys": {"loss" [R][, "overlap_counts" [R, n]]}}

    ``evals[xs["eval_slot"][r]]`` is the server model AFTER each round r
    with ``eval_write``, so the accuracy trajectory is computed by the exact
    same jitted eval as the per-round engines. The buffer is carried through
    the scan and indexed by eval slot — O(E x n) device memory instead of
    the O(rounds x n) a per-round ``ys["flat"]`` stack would cost (asserted
    in tests/test_sim_scan.py). Eval bookkeeping is read from the RAW xs
    row, never from ``plan_fn``'s output, so traced-sampling plans need not
    thread it through.

    Rounds skipped by failure injection (empty cohort) should simply not be
    included in the xs — the carry is untouched by construction, which
    matches the per-round engines' ``continue``.

    ``plan_fn`` (optional) maps each raw xs slice to the per-round plan dict
    consumed above — the hook that lets cohort sampling, survival draws, and
    straggler arrivals run fully *inside* the jit from a threaded PRNG key
    (``simulation.run_fl_traced``) instead of arriving host-precomputed.
    When a traced plan omits "reset_ef", EF residuals are never reset (the
    traced stream has its own slot semantics).

    ``population=P`` switches the carry contract to PER-CLIENT residual
    semantics (the "pop_scan" engine — the dense reference for the sparse
    out-of-core client store): ``residuals`` becomes a ``[P + 1, n]``
    per-client matrix, the xs gain ``"cohort" [R, C] i32`` (slot -> client
    id), and every round gathers the sampled clients' rows into the static
    ``[C, n]`` slots, runs the unchanged round body, and scatters the
    updated rows back. Row P is a sentinel: padded cohort slots point at it
    and scatter back exactly what they gathered (zeros), so duplicate
    sentinel writes are value-identical and the row provably stays zero.
    ``reset_ef`` is ignored — per-client residuals survive cohort resizes
    by construction, which is the point. Only meaningful for small P (the
    dense carry is O(P x n)); the O(P x (n - k_min)) production path is
    ``round_step.make_population_round_step`` + ``population.ClientStateStore``.
    """
    spec = spec_for(acfg)
    unflatten = make_unflatten(params_template)
    local_train = make_masked_local_trainer(loss_fn, lr)
    get_batches = make_batches or (lambda x: x["batches"])
    ef = spec.needs_residuals
    per_client = population is not None

    def body(carry, x):
        flat, res, evals = carry
        p = plan_fn(x) if plan_fn is not None else x
        params = unflatten(flat)
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, get_batches(p), p["step_mask"])
        updates = flatten_client_trees(deltas)     # [C, n] f32
        active = p["active"]

        if ef and per_client:
            res_in = res[x["cohort"]]              # [C, n] slot gather
        else:
            res_in = res
            if ef and "reset_ef" in p:
                res_in = jnp.where(p["reset_ef"], jnp.zeros_like(res), res)
        agg, new_res = aggregate_updates(
            spec, updates, p["weights"], p["ks"],
            residuals=res_in if ef else None, active=active)
        if ef and per_client:
            # scatter updated rows back to the per-client store; padded
            # slots rewrite the sentinel row with what they read (zeros),
            # so duplicate sentinel writes stay deterministic
            rows = jnp.where(active[:, None], new_res, res_in)
            new_res = res.at[x["cohort"]].set(rows)
        new_flat = flat - eta * agg

        # eval-round snapshot: O(E x n) carried buffer instead of emitting
        # the model every round (eval fields come from the raw xs row)
        evals = jax.lax.cond(
            x["eval_write"],
            lambda ev: ev.at[x["eval_slot"]].set(new_flat),
            lambda ev: ev, evals)

        n_act = jnp.maximum(jnp.sum(active.astype(jnp.int32)), 1)
        loss = jnp.sum(jnp.where(active, losses, 0.0)) / n_act
        ys = {"loss": loss}
        # a traced plan_fn can surface per-round plan facts (e.g. the in-jit
        # sampled cohort) to the host via "ys_extra"
        if "ys_extra" in p:
            ys.update(p["ys_extra"])
        if with_overlap:
            # Fig. 4 instrumentation: global top-k masks on the RAW deltas,
            # computed only on the flagged round (cond skips the work
            # everywhere else)
            def counts_fn(args):
                u, ko, act = args
                m = comp.topk_compress_batch(u, ko).mask & act[:, None]
                return opwa_mod.overlap_counts(m)

            ys["overlap_counts"] = jax.lax.cond(
                p["overlap_round"], counts_fn,
                lambda args: jnp.zeros((updates.shape[1],), jnp.int32),
                (updates, p["ks_overlap"], active))
        return (new_flat, new_res if ef else res, evals), ys

    scan_kind = "pop_scan" if per_client else "sim_scan"

    def _sim(flat, residuals, evals, xs):
        # host side effect: runs only at trace time
        TRACE_COUNTS[(scan_kind, spec.strategy, with_overlap)] += 1
        (flat, residuals, evals), ys = jax.lax.scan(
            body, (flat, residuals, evals), xs)
        return {"flat": flat, "residuals": residuals, "evals": evals,
                "ys": ys}

    fn = jax.jit(_sim, donate_argnums=(0, 1, 2))
    return SimScan(fn, spec, with_overlap)


# ------------------------------------------------------- scanned mesh driver
class MeshSimScan:
    """Callable wrapper around the jitted multi-round mesh program (one
    ``lax.scan`` chunk of the real-model FL trajectory)."""

    def __init__(self, fn, strategy: str, ef: bool):
        self._fn = fn
        self.strategy = strategy
        self.ef = ef

    def __call__(self, params, residuals, xs):
        return self._fn(params, residuals, xs)

    def compile(self, params, residuals, xs):
        """AOT lower+compile for the given chunk shapes. The jit cache keys
        on shapes, so chunks of equal length reuse ONE executable; callers
        (``launch.fl_train``) use this to separate the per-chunk-shape
        compile from steady-state dispatch."""
        return self._fn.lower(params, residuals, xs).compile()


def init_mesh_residuals(params_template, cohort: int):
    """Per-leaf EF residual pytree for the mesh engines: one f32
    ``[cohort, *leaf]`` buffer per parameter leaf (the per-leaf twin of the
    flat-space ``[C, n]`` residual matrix the simulation engines carry)."""
    return jax.tree.map(
        lambda l: jnp.zeros((cohort,) + tuple(l.shape), jnp.float32),
        params_template)


def make_mesh_sim_scan(loss_fn: Callable, params_template, *, lr: float,
                       strategy: str = "bcrs_opwa", eta: float = 1.0,
                       gamma: float = 5.0, overlap_d: int = 1,
                       use_kernel="auto") -> MeshSimScan:
    """Lower a multi-round REAL-MODEL FL trajectory into one ``lax.scan``.

    The pytree-native twin of ``make_sim_scan``: where the simulation scan
    carries a flat ``[n]`` vector, this carries the (possibly TP/FSDP-
    sharded) params pytree itself plus a per-leaf EF residual pytree
    (``[C, *leaf]`` per leaf, eftopk only) — every round body operates on
    leaves in their natural layout through ``mesh_round.make_round_body`` /
    ``compress_merge_leaf``, so sharded tensors stay sharded across the
    whole compiled program and the carry buffers are donated in place.

    Returned program signature (params and residuals donated)::

        run(params,                      # pytree, any leaf dtypes/shardings
            residuals,                   # per-leaf [C, *leaf] f32 pytree
                                         # (zeros-[0] placeholder when the
                                         # strategy carries no EF)
            xs: {"batches"   pytree of [T, C, S, ...] stacked client batches,
                 "step_mask" [T, C, S] bool,   # padded-step validity
                 "active"    [T, C]    bool,   # padded cohort-slot validity
                 "weights"   [T, C]    f32,    # 0 at inactive slots
                 "crs"       [T, C]    f32})   # per-client BCRS ratios
        -> {"params", "residuals", "ys": {"loss" [T]}}

    ``T`` is a CHUNK of rounds, not necessarily the whole run: the driver
    scans checkpoint_every-round chunks so every checkpoint boundary is a
    host round-trip (params + residuals come back, get persisted, and are
    fed — donated — into the next chunk). Chunks of equal length hit the
    same jit cache entry, so a run compiles once per distinct chunk length
    (tracked in TRACE_COUNTS[("mesh_scan", strategy)]).

    Per-leaf retained counts are derived in-body from the per-client ``crs``
    via ``core.compression.k_for_ratio_traced`` — the same rounding rule the
    host scheduler uses, applied to each leaf's element count.
    """
    from repro.fed.mesh_round import make_round_body  # cycle-free at runtime
    body_fn = make_round_body(loss_fn, lr_local=lr, eta=eta,
                              strategy=strategy, gamma=gamma,
                              overlap_d=overlap_d, use_kernel=use_kernel)
    ef = strat_mod.get(strategy).needs_residuals

    def scan_body(carry, x):
        params, res = carry
        new_params, new_res, loss = body_fn(
            params, res if ef else None, x["batches"], x["step_mask"],
            x["weights"], x["crs"], x["active"])
        return (new_params, new_res if ef else res), {"loss": loss}

    def _run(params, residuals, xs):
        # host side effect: runs only at trace time
        TRACE_COUNTS[("mesh_scan", strategy)] += 1
        (params, residuals), ys = jax.lax.scan(
            scan_body, (params, residuals), xs)
        return {"params": params, "residuals": residuals, "ys": ys}

    fn = jax.jit(_run, donate_argnums=(0, 1))
    return MeshSimScan(fn, strategy, ef)
