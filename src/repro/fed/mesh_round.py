"""Mesh-parallel FL round: clients vmapped over the ``data``(×``pod``) axes,
model TP-sharded over ``model``, aggregation via sharded reductions (psum in
the compiled HLO). This is the paper's system as a first-class distributed
feature — the dry-run lowers this step for the paper-representative cells.

Body adapter over ``repro.fed.engine``: ``make_round_body`` assembles ONE
round of the real-model trajectory — masked vmapped local SGD, per-leaf
traced-k compression with EF residuals, OPWA/weighted merge, server update —
entirely from the shared substrate (``engine.make_masked_local_trainer`` +
``engine.compress_merge_leaf``; every Top-K selection has
``core.compression.topk_compress_dynamic`` semantics, megakernel-routed per
leaf under ``use_kernel="auto"`` on TPU). The same body serves both
dispatch granularities:

  * ``make_mesh_round_step`` — one jitted program per round (the legacy
    dispatch loop, kept as the scan's bit-parity reference);
  * ``engine.make_mesh_sim_scan`` — the whole multi-round trajectory as one
    ``lax.scan`` with the params/residual pytrees threaded through the
    donated carry (the ``launch.fl_train`` default).

``fl_train --engine async`` deliberately does NOT route through this body:
its wave trainer (``async_engine.make_wave_train_step``) vmaps the same
``engine.make_masked_local_trainer`` over per-member params gathered from
the version ring — a [Wb, n] second params axis this round-synchronous body
has no slot for — and compresses at the buffer merge, not per upload. The
two legs share the trainer's wave-composition contract (see its docstring),
which is what keeps the mesh sync legs and the async leg comparable.

Per-leaf selection (vs the host-loop simulator's whole-model flatten) keeps
every tensor sharded; per-leaf retained counts come from the shared
``k_for_ratio_traced`` rounding rule, so the host scheduler and the traced
body can never drift. See docs/DESIGN.md §7.
"""
from __future__ import annotations

import collections
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.core import strategies as strat_mod
from repro.fed.engine import (compress_merge_leaf, densify_rows,
                              flatten_client_trees, make_masked_local_trainer,
                              make_unflatten, sparsify_rows)

#: retrace telemetry for the per-round mesh step: (strategy,) -> traces.
#: The scanned driver's counter lives in engine.TRACE_COUNTS under
#: ("mesh_scan", strategy).
TRACE_COUNTS: collections.Counter = collections.Counter()


def make_round_body(loss_fn: Callable, *, lr_local: float = 1e-2,
                    eta: float = 1.0, strategy: str = "bcrs_opwa",
                    gamma: float = 5.0, overlap_d: int = 1,
                    use_kernel="auto") -> Callable:
    """One real-model FL round as a pure traceable function.

    Returns ``body(params, residuals, batches, step_mask, coeffs, crs,
    active) -> (new_params, new_residuals, loss)``:

      params      pytree (leaves keep their dtypes/shardings);
      residuals   per-leaf EF pytree ([C, *leaf] f32) — required iff the
                  registered strategy carries EF, pass None otherwise;
      batches     pytree with leading [C, S, ...] axes (C cohort slots,
                  sharded over the batch mesh axes);
      step_mask   bool [C, S] — padded local steps are exact no-ops;
      coeffs      f32 [C] merge weights (data fracs or BCRS Eq. 6 p'_i),
                  0 at padded slots;
      crs         f32 [C] traced per-client compression ratios (per-leaf
                  retained counts are ``k_for_ratio_traced(leaf_n, crs)``);
      active      optional bool [C] — padded cohort slots contribute nothing
                  to the merge, the OPWA overlap counts, the loss, or the
                  residual update. None means every slot is real.

    The reported loss is the active-masked mean of each client's last real
    local step's pre-update loss (``make_masked_local_trainer`` semantics).
    """
    strat = strat_mod.get(strategy)   # config-time error, names listed
    ef = strat.needs_residuals
    compress = strat.compresses
    opwa = strat.overlap_weighted
    value_codec = strat.value_codec
    kernel_codec = strat.kernel_codec
    local_train = make_masked_local_trainer(loss_fn, lr_local)

    def body(params, residuals, batches, step_mask, coeffs, crs, active):
        if ef and residuals is None:
            raise ValueError(f"{strategy} needs per-leaf residuals")
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0, 0))(
            params, batches, step_mask)
        w = coeffs.astype(jnp.float32)
        if active is not None:
            w = jnp.where(active, w, 0.0)

        def agg_leaf(p, dl, res):
            """Sharding-preserving per-leaf compression: the bisection and
            aggregation operate on the leaf's natural (TP-sharded) layout —
            reshape(c, -1) would merge sharded dims and force XLA to gather
            the whole leaf per device (§Perf iteration 1)."""
            if not compress:
                dl32 = dl.astype(jnp.float32)
                if active is not None:
                    dl32 = dl32 * active.reshape(
                        (-1,) + (1,) * (dl32.ndim - 1))
                agg, new_res = jnp.tensordot(w, dl32, axes=(0, 0)), res
            else:
                n = dl.size // dl.shape[0]
                ks = comp.k_for_ratio_traced(n, crs)
                agg, new_res = compress_merge_leaf(
                    dl, w, ks, gamma=gamma, overlap_d=overlap_d, opwa=opwa,
                    use_kernel=use_kernel, residuals=res, active=active,
                    value_codec=value_codec, kernel_codec=kernel_codec)
            return (p.astype(jnp.float32) - eta * agg).astype(p.dtype), new_res

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_d = treedef.flatten_up_to(deltas)
        leaves_r = (treedef.flatten_up_to(residuals) if ef
                    else [None] * len(leaves_p))
        out = [agg_leaf(p, d, r)
               for p, d, r in zip(leaves_p, leaves_d, leaves_r)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_res = (jax.tree.unflatten(treedef, [o[1] for o in out])
                   if ef else residuals)

        if active is None:
            loss = jnp.mean(losses)
        else:
            n_act = jnp.maximum(jnp.sum(active.astype(jnp.int32)), 1)
            loss = jnp.sum(jnp.where(active, losses, 0.0)) / n_act
        return new_params, new_res, loss

    return body


def make_mesh_round_step(loss_fn: Callable, *, lr_local: float = 1e-2,
                         eta: float = 1.0, strategy: str = "bcrs_opwa",
                         gamma: float = 5.0, overlap_d: int = 1,
                         use_kernel="auto", donate: bool = True) -> Callable:
    """One jitted per-round program over ``make_round_body`` — the legacy
    dispatch granularity (one compile + R dispatches), kept as the scanned
    driver's bit-parity reference and the ``fl_train --engine round`` path.
    Params and residual buffers are donated (``donate=False`` for callers
    that reuse inputs, e.g. parity tests)."""
    body = make_round_body(loss_fn, lr_local=lr_local, eta=eta,
                           strategy=strategy, gamma=gamma,
                           overlap_d=overlap_d, use_kernel=use_kernel)

    def _step(params, residuals, batches, step_mask, coeffs, crs, active):
        TRACE_COUNTS[(strategy,)] += 1   # host side effect: trace time only
        return body(params, residuals, batches, step_mask, coeffs, crs,
                    active)

    return jax.jit(_step, donate_argnums=(0, 1) if donate else ())


def mesh_residual_width(params_template, cr_min: float) -> int:
    """Conservative sparse-pair width for the mesh population step: the
    per-leaf Top-K keeps >= k_for_ratio_traced(leaf_n, cr) survivors per
    leaf, so a client's whole-model residual nnz is at most
    ``sum_l (leaf_n - k_l)`` at the plan's smallest cr. The traced k uses
    f32 arithmetic where the host uses f64, so each leaf's bound is slacked
    by one survivor — a few extra columns, never a silent overflow."""
    import numpy as np
    n_total, k_total = 0, 0
    for leaf in jax.tree.leaves(params_template):
        ln = int(np.prod(leaf.shape, dtype=np.int64))
        n_total += ln
        k_total += max(1, min(ln, int(np.floor(ln * cr_min)) - 1))
    return max(1, n_total - k_total)


def make_population_round_step(loss_fn: Callable, params_template, *,
                               lr_local: float = 1e-2, eta: float = 1.0,
                               strategy: str = "bcrs_opwa",
                               gamma: float = 5.0, overlap_d: int = 1,
                               use_kernel="auto", width: int = 0,
                               donate: bool = True) -> Callable:
    """Per-leaf population round: ``make_round_body`` with EF residuals
    arriving in the client store's persisted wire layout instead of a
    resident per-leaf carry pytree — the mesh twin of
    ``round_step.make_population_round_step``.

    Inside the jit the wire rows are densified to ``[C, n]``, split per
    row into the per-leaf ``[C, *leaf]`` pytree the body compresses in
    natural layout, then the updated residual pytree is re-flattened and
    re-sparsified. One flat store serves any parameter pytree; the
    conversion is O(C x n) compute with no new HBM-resident state (the
    round body already materializes [C, *leaf] deltas of the same size).

    Signature::

        step(params, res_wire, batches, step_mask, coeffs, crs, active)
          -> (new_params, new_res_wire, loss, overflow)

    ``res_wire`` is ``(idx [C, W] i32, val [C, W] f32)`` for
    "topk_complement" strategies (``width`` from ``mesh_residual_width``),
    a dense ``[C, n]`` f32 matrix for "dense"-layout EF strategies, and a
    ``[0]`` placeholder for carry="none" (passed through).
    """
    strat = strat_mod.get(strategy)
    ef = strat.needs_residuals
    layout = strat.residual_layout if ef else None
    if layout == "topk_complement" and width <= 0:
        raise ValueError(f"{strategy}: topk_complement wire layout needs "
                         "width > 0 (use mesh_residual_width)")
    body = make_round_body(loss_fn, lr_local=lr_local, eta=eta,
                           strategy=strategy, gamma=gamma,
                           overlap_d=overlap_d, use_kernel=use_kernel)
    res_template = jax.tree.map(
        lambda l: jnp.zeros(l.shape, jnp.float32), params_template)
    unflatten_row = make_unflatten(res_template)
    import numpy as np
    n_total = int(sum(np.prod(l.shape, dtype=np.int64)
                      for l in jax.tree.leaves(params_template)))

    def _step(params, res_wire, batches, step_mask, coeffs, crs, active):
        TRACE_COUNTS[("population", strategy)] += 1   # trace time only
        if layout == "topk_complement":
            rows = densify_rows(*res_wire, n_total)
        else:
            rows = res_wire
        res_tree = (jax.vmap(unflatten_row)(rows) if ef else None)
        new_params, new_res_tree, loss = body(
            params, res_tree, batches, step_mask, coeffs, crs, active)
        overflow = jnp.asarray(False)
        if layout == "topk_complement":
            idx, val, overflow = sparsify_rows(
                flatten_client_trees(new_res_tree), width)
            new_wire = (idx, val)
        elif ef:
            new_wire = flatten_client_trees(new_res_tree)
        else:
            new_wire = res_wire
        return new_params, new_wire, loss, overflow

    donate_nums = ((0, 1) if ef else (0,)) if donate else ()
    return jax.jit(_step, donate_argnums=donate_nums)


def make_fl_round_step(model, *, lr_local: float = 1e-2, eta: float = 1.0,
                       gamma: float = 5.0, overlap_d: int = 1,
                       compress: bool = True, use_kernel="auto") -> Callable:
    """Returns jittable ``fl_round(params, client_batches, coeffs, crs)`` —
    the original single-round convenience surface (full cohort, full step
    count, no EF), now a thin wrapper over ``make_round_body``.

    client_batches: pytree with leading [C, n_steps, ...] axes (C = cohort,
    sharded over the batch mesh axes). coeffs: [C] BCRS p'_i. crs: [C] f32
    per-client compression ratios (traced — scheduled per round on host).
    """
    body = make_round_body(model.loss_fn, lr_local=lr_local, eta=eta,
                           strategy="bcrs_opwa" if compress else "fedavg",
                           gamma=gamma, overlap_d=overlap_d,
                           use_kernel=use_kernel)

    def fl_round(params, client_batches, coeffs, crs):
        c, s = jax.tree.leaves(client_batches)[0].shape[:2]
        step_mask = jnp.ones((c, s), bool)
        new_params, _, loss = body(params, None, client_batches, step_mask,
                                   coeffs, crs, None)
        return new_params, loss

    return fl_round
