"""Mesh-parallel FL round: clients vmapped over the ``data``(×``pod``) axes,
model TP-sharded over ``model``, aggregation via sharded reductions (psum in
the compiled HLO). This is the paper's system as a first-class distributed
feature — the dry-run lowers this step for the paper-representative cells.

Per-client compression uses the traced-k bisection Top-K so BCRS can assign
*different* CRs per client inside one compiled step. Per-leaf selection (vs
the host-loop simulator's whole-model flatten) keeps every tensor sharded;
see DESIGN.md §7.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import topk_compress_dynamic
from repro.fed.client import make_local_trainer


def make_fl_round_step(model, *, lr_local: float = 1e-2, eta: float = 1.0,
                       gamma: float = 5.0, overlap_d: int = 1,
                       compress: bool = True) -> Callable:
    """Returns jittable ``fl_round(params, client_batches, coeffs, crs)``.

    client_batches: pytree with leading [C, n_steps, ...] axes (C = cohort,
    sharded over the batch mesh axes). coeffs: [C] BCRS p'_i. crs: [C] f32
    per-client compression ratios (traced — scheduled per round on host).
    """
    local_train = make_local_trainer(model.loss_fn, lr_local)

    def fl_round(params, client_batches, coeffs, crs):
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0))(
            params, client_batches)

        def agg_leaf(p, dl):
            """Sharding-preserving per-leaf compression: the bisection and
            aggregation operate on the leaf's natural (TP-sharded) layout —
            reshape(c, -1) would merge sharded dims and force XLA to gather
            the whole leaf per device (§Perf iteration 1)."""
            c = dl.shape[0]
            axes = tuple(range(1, dl.ndim))
            n = dl.size // c
            cexp = (slice(None),) + (None,) * (dl.ndim - 1)
            magf = jnp.abs(dl.astype(jnp.float32))
            if compress:
                k = jnp.maximum((crs * n).astype(jnp.int32), 1)
                hi = jnp.max(magf, axis=axes)
                lo = jnp.zeros_like(hi)

                def body(_, lohi):
                    lo, hi = lohi
                    mid = 0.5 * (lo + hi)
                    cnt = jnp.sum(magf >= mid[cexp], axis=axes)
                    pred = cnt >= k
                    return jnp.where(pred, mid, lo), jnp.where(pred, hi, mid)

                lo, _ = jax.lax.fori_loop(0, 40, body, (lo, hi))
                mask = magf >= lo[cexp]
                vals = jnp.where(mask, dl.astype(jnp.float32), 0.0)
                counts = jnp.sum(mask.astype(jnp.int32), axis=0)
                m = jnp.where((counts > 0) & (counts <= overlap_d),
                              jnp.float32(gamma), jnp.float32(1.0))
                agg = m * jnp.tensordot(coeffs.astype(jnp.float32), vals,
                                        axes=(0, 0))
            else:
                agg = jnp.tensordot(coeffs.astype(jnp.float32),
                                    dl.astype(jnp.float32), axes=(0, 0))
            return (p.astype(jnp.float32) - eta * agg).astype(p.dtype)

        new_params = jax.tree.map(agg_leaf, params, deltas)
        return new_params, jnp.mean(losses)

    return fl_round
