"""Mesh-parallel FL round: clients vmapped over the ``data``(×``pod``) axes,
model TP-sharded over ``model``, aggregation via sharded reductions (psum in
the compiled HLO). This is the paper's system as a first-class distributed
feature — the dry-run lowers this step for the paper-representative cells.

Thin adapter over ``repro.fed.engine``: per-client selection routes through
the shared traced-k integer-bit bisection (``core.compression.
topk_compress_dynamic``) via ``engine.compress_merge_leaf`` — the private
float-space bisection this module used to carry is gone (it needed ~40
iterations, lost exactness near denormal thresholds, and kept ties
inconsistently with the other engines; the integer-bit bisection is exact in
<= 32 halvings including the CR=1 / k=n edge). Per-leaf selection (vs the
host-loop simulator's whole-model flatten) keeps every tensor sharded; see
DESIGN.md §7.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.fed.client import make_local_trainer
from repro.fed.engine import compress_merge_leaf


def make_fl_round_step(model, *, lr_local: float = 1e-2, eta: float = 1.0,
                       gamma: float = 5.0, overlap_d: int = 1,
                       compress: bool = True) -> Callable:
    """Returns jittable ``fl_round(params, client_batches, coeffs, crs)``.

    client_batches: pytree with leading [C, n_steps, ...] axes (C = cohort,
    sharded over the batch mesh axes). coeffs: [C] BCRS p'_i. crs: [C] f32
    per-client compression ratios (traced — scheduled per round on host).
    """
    local_train = make_local_trainer(model.loss_fn, lr_local)

    def fl_round(params, client_batches, coeffs, crs):
        deltas, losses = jax.vmap(local_train, in_axes=(None, 0))(
            params, client_batches)

        def agg_leaf(p, dl):
            """Sharding-preserving per-leaf compression: the bisection and
            aggregation operate on the leaf's natural (TP-sharded) layout —
            reshape(c, -1) would merge sharded dims and force XLA to gather
            the whole leaf per device (§Perf iteration 1)."""
            if compress:
                n = dl.size // dl.shape[0]
                # same rounding as the host scheduler's k_for_ratio, clamped
                # to [1, n] so CR=1 keeps the whole leaf exactly
                ks = jnp.clip(jnp.round(crs.astype(jnp.float32) * n)
                              .astype(jnp.int32), 1, n)
                agg, _ = compress_merge_leaf(dl, coeffs, ks, gamma=gamma,
                                             overlap_d=overlap_d, opwa=True,
                                             use_kernel=False)
            else:
                agg = jnp.tensordot(coeffs.astype(jnp.float32),
                                    dl.astype(jnp.float32), axes=(0, 0))
            return (p.astype(jnp.float32) - eta * agg).astype(p.dtype)

        new_params = jax.tree.map(agg_leaf, params, deltas)
        return new_params, jnp.mean(losses)

    return fl_round
