"""Population-scale FL: streaming cohorts over an out-of-core client store.

The paper's setting is *cross-device* FL — populations far larger than any
cohort — but until this module every engine sized its buffers by the
registered client count: dense ``[C, n]`` slots for the whole population and
EF residuals resident in the scan carry, an O(P x n) memory bill that caps
P at cohort scale. This module splits "registered" from "participating":

  * ``Population``       — the registry: per-client data weight, bandwidth
                           profile (``cost_model.LinkArrays`` — arrays, not
                           P Python objects), and a non-IID skew seed.
                           O(P) numpy built once; every per-round read is an
                           O(C) slice.
  * ``ClientStateStore`` — durable per-client EF state, chunked and
                           spillable. ``carry="ef"`` strategies declare
                           their residual layout in the registry
                           (``Strategy.residual_layout``): pure Top-K
                           residuals are nonzero only on the coordinates the
                           selection dropped, so "topk_complement" persists
                           ``(idx32, f32)`` pairs of static width
                           ``n - k_min`` — O(P x (n - k_min)); codec
                           strategies (qtopk) are honest about their dense
                           residual and persist full rows, chunked and
                           resident-bounded but not sparsified. Chunks
                           spill to disk through the checkpointer (one
                           msgpack file per chunk, CRC-checked, ``keep=None``
                           retention), so populations that exceed host RAM
                           stream through a bounded LRU window.
  * ``run_population_rounds`` — the streaming-cohort driver: each round
                           samples a C-slot cohort from P (``rng.choice``
                           without replacement is O(C)), gathers just those
                           clients' state into the static slots, runs the
                           ONE compiled round program
                           (``round_step.make_population_round_step`` —
                           densify-on-gather / sparsify-on-scatter live
                           inside the jit boundary), and scatters updated
                           state back. Round cost is O(C), independent of P
                           (``benchmarks/bench_round.py --population``
                           sweeps P 10^3 -> 10^6 and commits the flatness
                           evidence to BENCH_population.json).

The dense reference for all of this is ``engine.make_sim_scan(...,
population=P)`` (the "pop_scan" engine): a ``[P + 1, n]`` per-client carry
with in-scan slot gather/scatter — bit-exact with the store path at small P
(asserted in tests/test_population.py), absurd at large P by design.
"""
from __future__ import annotations

import collections
import os
import shutil
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.core import cost_model

_LAYOUTS = ("topk_complement", "dense")


# ---------------------------------------------------------------- registry
@dataclass(frozen=True)
class Population:
    """Registered client population: everything the host planner needs to
    sample and price a cohort, held as O(P) numpy columns (built once) so
    per-round planning touches only O(C) slices."""
    weights: np.ndarray            # data weights, sum to 1 [P] f64
    links: cost_model.LinkArrays   # bandwidth/latency columns [P]
    skew_seeds: np.ndarray         # per-client non-IID seed [P] i64

    @property
    def n_clients(self) -> int:
        return self.weights.shape[0]


def make_population(n_clients: int, seed: int = 0, *,
                    weight_sigma: float = 0.5) -> Population:
    """Sample a population registry: log-normal data weights (heavy-tailed
    client data sizes), the paper's bandwidth/latency link model
    (``sample_link_arrays`` — same draws as ``sample_links``, array form),
    and integer skew seeds driving each client's synthetic label bias."""
    rng = np.random.default_rng(seed)
    w = rng.lognormal(mean=0.0, sigma=weight_sigma, size=n_clients)
    links = cost_model.sample_link_arrays(n_clients, rng)
    skew = rng.integers(0, np.iinfo(np.int32).max, size=n_clients)
    return Population(weights=w / w.sum(), links=links,
                      skew_seeds=skew.astype(np.int64))


def sample_cohort(rng: np.random.Generator, n_clients: int,
                  cohort: int) -> np.ndarray:
    """Draw a C-slot cohort from P registered clients without replacement —
    O(C) (numpy's Floyd-style sampler), the planning primitive that keeps
    round cost flat as P grows. Uniform draw: per-client data weights enter
    the *averaging coefficients*, not the sampling distribution (a weighted
    ``choice`` computes an O(P) cdf per round)."""
    return rng.choice(n_clients, size=min(cohort, n_clients), replace=False)


def residual_width(n_params: int, k_min: int) -> int:
    """Static sparse-pair width for the "topk_complement" layout: a pure
    Top-K EF residual has nnz <= n - k (ties at the threshold only shrink
    it — the bisection keeps >= k survivors), so the smallest retained count
    anywhere in the plan bounds every row. Clamped to >= 1 so the store's
    arrays keep a real shape even at CR = 1 (residual identically zero)."""
    return max(1, int(n_params) - int(k_min))


# ----------------------------------------------------------- chunked store
class ClientStateStore:
    """Out-of-core per-client EF residual store: P rows in the strategy's
    declared wire layout, chunked ``chunk_clients`` rows per chunk, with an
    LRU window of at most ``max_resident_chunks`` chunks in host RAM (the
    rest live as one checkpointer msgpack file per chunk under
    ``spill_dir``). Never allocates anything O(P x n): sparse chunks are
    ``[m, width]`` pairs, and only touched chunks exist at all — a client
    that never participated gathers implicit zeros.

    ``gather(ids)`` / ``scatter(ids, arrays)`` move the sampled cohort's
    rows between the store and the static jit slots; callers pass only the
    REAL cohort prefix (padded slots never reach the store — the jit
    program's ``active`` mask already round-trips their rows unchanged).

    ``save``/``restore`` snapshot the full store bit-exactly for restarts:
    resident chunks are written fresh, on-disk chunks are copied file-wise
    (CRC intact), and a restored store treats the snapshot directory as a
    read-only base — later evictions write to ``spill_dir`` only.
    """

    def __init__(self, n_clients: int, n_coords: int, *,
                 layout: str = "topk_complement", width: int = 0,
                 chunk_clients: int = 256,
                 max_resident_chunks: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 base_dir: Optional[str] = None,
                 base_chunks: Iterable[int] = ()):
        if layout not in _LAYOUTS:
            raise ValueError(f"unknown residual layout {layout!r} "
                             f"(one of {_LAYOUTS})")
        if layout == "topk_complement" and width <= 0:
            raise ValueError("topk_complement store needs width > 0 "
                             "(use population.residual_width)")
        if max_resident_chunks is not None:
            if spill_dir is None:
                raise ValueError("bounding resident chunks needs a "
                                 "spill_dir to evict into")
            if max_resident_chunks < 1:
                raise ValueError("max_resident_chunks must be >= 1")
        if spill_dir is not None and spill_dir == base_dir:
            raise ValueError("spill_dir must differ from the read-only "
                             "restore base_dir")
        self.n_clients = int(n_clients)
        self.n_coords = int(n_coords)
        self.layout = layout
        self.width = int(width) if layout == "topk_complement" else n_coords
        self.chunk_clients = int(min(chunk_clients, n_clients))
        self.max_resident_chunks = max_resident_chunks
        self.spill_dir = spill_dir
        self._base_dir = base_dir
        #: chunk id -> directory holding its newest on-disk file
        self._disk: Dict[int, str] = {int(c): base_dir for c in base_chunks}
        #: chunk id -> {"arrays": {...}, "dirty": bool} in LRU order
        self._chunks: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()
        # telemetry the population bench reports
        self._resident_bytes = 0
        self.peak_resident_bytes = 0
        self.gather_seconds = 0.0
        self.scatter_seconds = 0.0
        self.chunk_loads = 0
        self.chunk_spills = 0

    # -- chunk plumbing --------------------------------------------------
    def _rows_of(self, cid: int) -> int:
        lo = cid * self.chunk_clients
        return min(self.chunk_clients, self.n_clients - lo)

    def _blank(self, cid: int) -> Dict[str, np.ndarray]:
        m = self._rows_of(cid)
        if self.layout == "topk_complement":
            return {"idx": np.zeros((m, self.width), np.int32),
                    "val": np.zeros((m, self.width), np.float32)}
        return {"val": np.zeros((m, self.n_coords), np.float32)}

    @staticmethod
    def _nbytes(arrays: Dict[str, np.ndarray]) -> int:
        return sum(a.nbytes for a in arrays.values())

    def _load(self, cid: int) -> Dict[str, np.ndarray]:
        """Make chunk ``cid`` resident (LRU-touched) and return its arrays."""
        entry = self._chunks.get(cid)
        if entry is not None:
            self._chunks.move_to_end(cid)
            return entry["arrays"]
        if cid in self._disk:
            tree, _, _ = ckpt.restore(self._disk[cid], self._blank(cid),
                                      step=cid)
            # np.array, not asarray: the checkpointer hands back device
            # arrays whose numpy views are read-only, and chunks are
            # scattered into in place
            arrays = {k: np.array(v) for k, v in tree.items()}
            self.chunk_loads += 1
        else:
            arrays = self._blank(cid)
        self._chunks[cid] = {"arrays": arrays, "dirty": False}
        self._resident_bytes += self._nbytes(arrays)
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self._resident_bytes)
        self._evict()
        return arrays

    def _evict(self) -> None:
        if self.max_resident_chunks is None:
            return
        while len(self._chunks) > self.max_resident_chunks:
            cid, entry = self._chunks.popitem(last=False)
            self._resident_bytes -= self._nbytes(entry["arrays"])
            if entry["dirty"] or cid not in self._disk:
                ckpt.save(self.spill_dir, cid, entry["arrays"], keep=None)
                self._disk[cid] = self.spill_dir
                self.chunk_spills += 1

    def _known_chunks(self) -> List[int]:
        return sorted(set(self._chunks) | set(self._disk))

    # -- cohort I/O ------------------------------------------------------
    def gather(self, ids) -> Tuple[np.ndarray, ...]:
        """Rows for the sampled cohort, in the store's wire layout:
        ``(idx [C, W] i32, val [C, W] f32)`` for "topk_complement",
        ``(rows [C, n] f32,)`` for "dense". Chunk-grouped, O(C) per round
        plus at most C chunk loads."""
        t0 = time.perf_counter()
        ids = np.asarray(ids, np.int64)
        out = self._blank_rows(len(ids))
        for cid, sel in self._by_chunk(ids):
            arrays = self._load(cid)
            rows = ids[sel] - cid * self.chunk_clients
            for k, o in zip(self._keys(), out):
                o[sel] = arrays[k][rows]
        self.gather_seconds += time.perf_counter() - t0
        return out

    def scatter(self, ids, arrays: Tuple[np.ndarray, ...]) -> None:
        """Write the cohort's updated rows back (inverse of ``gather``;
        same layout-ordered tuple). Marks touched chunks dirty so eviction
        and snapshots persist them."""
        t0 = time.perf_counter()
        ids = np.asarray(ids, np.int64)
        arrays = tuple(np.asarray(a) for a in arrays)
        for cid, sel in self._by_chunk(ids):
            chunk = self._load(cid)
            rows = ids[sel] - cid * self.chunk_clients
            for k, a in zip(self._keys(), arrays):
                chunk[k][rows] = a[sel]
            self._chunks[cid]["dirty"] = True
        self.scatter_seconds += time.perf_counter() - t0

    def _keys(self) -> Tuple[str, ...]:
        return (("idx", "val") if self.layout == "topk_complement"
                else ("val",))

    def _blank_rows(self, c: int) -> Tuple[np.ndarray, ...]:
        if self.layout == "topk_complement":
            return (np.zeros((c, self.width), np.int32),
                    np.zeros((c, self.width), np.float32))
        return (np.zeros((c, self.n_coords), np.float32),)

    def _by_chunk(self, ids: np.ndarray):
        cids = ids // self.chunk_clients
        order = np.argsort(cids, kind="stable")
        for cid in np.unique(cids):
            yield int(cid), order[cids[order] == cid]

    # -- persistence -----------------------------------------------------
    def resident_bytes(self) -> int:
        return self._resident_bytes

    def manifest(self) -> dict:
        """Layout metadata a driver embeds in its checkpoint ``extra`` so
        ``restore`` can rebuild the store without guessing shapes."""
        return {"layout": self.layout, "width": self.width,
                "n_clients": self.n_clients, "n_coords": self.n_coords,
                "chunk_clients": self.chunk_clients,
                "chunks": self._known_chunks()}

    def save(self, ckpt_dir: str, step: int) -> dict:
        """Snapshot every touched chunk under
        ``<ckpt_dir>/clients_step_<step>/`` (resident chunks written fresh,
        on-disk chunks copied file-wise — CRC intact either way) and return
        the manifest. Untouched chunks are implicit zeros and cost nothing.
        """
        snap = client_snapshot_dir(ckpt_dir, step)
        os.makedirs(snap, exist_ok=True)
        for cid in self._known_chunks():
            entry = self._chunks.get(cid)
            if entry is not None:
                ckpt.save(snap, cid, entry["arrays"], keep=None)
            else:
                shutil.copyfile(
                    os.path.join(self._disk[cid], f"step_{cid}.msgpack"),
                    os.path.join(snap, f"step_{cid}.msgpack"))
        return self.manifest()

    @classmethod
    def restore(cls, ckpt_dir: str, step: int, manifest: dict, *,
                chunk_clients: Optional[int] = None,
                max_resident_chunks: Optional[int] = None,
                spill_dir: Optional[str] = None) -> "ClientStateStore":
        """Rebuild a store from a ``save`` snapshot, lazily: no chunk is
        read until a cohort touches it. The snapshot stays read-only."""
        if chunk_clients is not None and \
                chunk_clients != manifest["chunk_clients"]:
            raise ValueError(
                f"snapshot was chunked {manifest['chunk_clients']} "
                f"clients/chunk; cannot restore at {chunk_clients}")
        return cls(manifest["n_clients"], manifest["n_coords"],
                   layout=manifest["layout"], width=manifest["width"],
                   chunk_clients=manifest["chunk_clients"],
                   max_resident_chunks=max_resident_chunks,
                   spill_dir=spill_dir,
                   base_dir=client_snapshot_dir(ckpt_dir, step),
                   base_chunks=manifest["chunks"])

    def dump_dense(self) -> np.ndarray:
        """Materialize the FULL ``[P, n]`` residual matrix — parity tests
        and debugging only (small P); the whole point of the store is that
        nothing else ever allocates this."""
        rows = np.zeros((self.n_clients, self.n_coords), np.float32)
        for cid in self._known_chunks():
            arrays = self._load(cid)
            lo = cid * self.chunk_clients
            m = self._rows_of(cid)
            if self.layout == "dense":
                rows[lo:lo + m] = arrays["val"]
            else:
                np.add.at(rows[lo:lo + m],
                          (np.arange(m)[:, None], arrays["idx"]),
                          arrays["val"])
        return rows


def client_snapshot_dir(ckpt_dir: str, step: int) -> str:
    """Per-step client-store snapshot directory (sibling of the driver's
    ``step_<step>.msgpack`` file, so checkpoint retention can prune both)."""
    return os.path.join(ckpt_dir, f"clients_step_{step}")


def prune_client_snapshots(ckpt_dir: str, keep_steps: Iterable[int]) -> None:
    """Drop ``clients_step_*`` snapshot dirs whose step the main checkpoint
    retention already pruned — the store twin of ``_apply_retention``."""
    keep = set(int(s) for s in keep_steps)
    if not os.path.isdir(ckpt_dir):
        return
    for name in os.listdir(ckpt_dir):
        if name.startswith("clients_step_"):
            try:
                step = int(name[len("clients_step_"):])
            except ValueError:
                continue
            if step not in keep:
                shutil.rmtree(os.path.join(ckpt_dir, name),
                              ignore_errors=True)


# ----------------------------------------------------- streaming-cohort run
@dataclass
class PopulationRunConfig:
    """Streaming-cohort driver knobs (synthetic per-client data generated
    on the fly from each client's skew seed — at P = 10^6 there is no global
    dataset to partition)."""
    cohort: int = 16
    rounds: int = 6
    local_steps: int = 2
    batch_size: int = 8
    dim: int = 64
    hidden: int = 64
    n_classes: int = 10
    lr: float = 0.05
    seed: int = 0


@dataclass
class PopulationRunResult:
    losses: List[float] = field(default_factory=list)
    wall_per_round: List[float] = field(default_factory=list)
    comm_actual_s: float = 0.0
    gather_seconds: float = 0.0
    scatter_seconds: float = 0.0
    peak_state_bytes: int = 0
    final_flat: Optional[np.ndarray] = None


def _client_batches(cfg: PopulationRunConfig, means: np.ndarray,
                    skew_seed: int, rnd: int) -> Tuple[np.ndarray, np.ndarray]:
    """One client's [S, B] synthetic batches for round ``rnd``: Gaussian
    features around per-class means, labels biased to the client's skew
    classes (non-IID), all deterministic in (skew_seed, round)."""
    rng = np.random.default_rng((int(skew_seed), rnd))
    half = max(1, cfg.n_classes // 2)
    y = (int(skew_seed) + rng.integers(0, half,
                                       (cfg.local_steps, cfg.batch_size))) \
        % cfg.n_classes
    x = rng.standard_normal(
        (cfg.local_steps, cfg.batch_size, cfg.dim)).astype(np.float32)
    return x + means[y], y.astype(np.int32)


def run_population_rounds(pop: Population, cfg: PopulationRunConfig, *,
                          acfg=None, step=None,
                          store: Optional[ClientStateStore] = None,
                          chunk_clients: int = 32,
                          max_resident_chunks: Optional[int] = None,
                          spill_dir: Optional[str] = None
                          ) -> Tuple[PopulationRunResult, object,
                                     Optional[ClientStateStore]]:
    """Run ``cfg.rounds`` streaming-cohort rounds against ``pop``.

    Every per-round quantity is O(C): the cohort draw, the state
    gather/scatter, the BCRS schedule over the cohort's links, the comm-time
    accounting, and the synthetic batch generation. Pass ``step`` (a
    ``PopulationRoundStep`` from a previous call) to reuse the compiled
    round program across population sizes — the bench sweep's proof that
    ONE compile serves P = 10^3..10^6 (only the gather source scales).

    Returns (result, step, store) so callers can chain sweeps.
    """
    import jax.numpy as jnp

    from repro.core import aggregation as agg_mod
    from repro.fed import round_step as rs_mod
    from repro.fed import simulation as sim_mod

    if acfg is None:
        acfg = agg_mod.AggregationConfig(strategy="eftopk", cr=0.1)
    model_rng = np.random.default_rng(cfg.seed)
    means = (0.5 * model_rng.standard_normal(
        (cfg.n_classes, cfg.dim))).astype(np.float32)
    import jax
    params = sim_mod.mlp_init(jax.random.PRNGKey(cfg.seed), cfg.dim,
                              cfg.n_classes, hidden=cfg.hidden)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                            for l in jax.tree.leaves(params)])
    n_params = int(flat.shape[0])
    v_bytes = 4.0 * n_params
    c_slots = min(cfg.cohort, pop.n_clients)
    strat = acfg.strat
    ef = strat.needs_residuals

    if step is None:
        # width from the schedule's floor: every retained count the plan can
        # emit is >= k_for_ratio(n, cr_star), so n - that bounds every row
        from repro.core.compression import k_for_ratio
        width = residual_width(n_params, k_for_ratio(n_params, acfg.cr))
        step = rs_mod.make_population_round_step(
            sim_mod.mlp_loss, params, lr=cfg.lr, acfg=acfg, width=width)
    if ef and store is None:
        store = ClientStateStore(
            pop.n_clients, n_params, layout=strat.residual_layout,
            width=step.width or n_params, chunk_clients=chunk_clients,
            max_resident_chunks=max_resident_chunks, spill_dir=spill_dir)

    smask = jnp.ones((c_slots, cfg.local_steps), bool)
    active = jnp.ones((c_slots,), bool)
    result = PopulationRunResult()
    res_dev = step.init_residuals(c_slots, n_params)
    for rnd in range(cfg.rounds):
        t0 = time.perf_counter()
        rng = np.random.default_rng((cfg.seed, rnd))
        ids = sample_cohort(rng, pop.n_clients, c_slots)
        fr = pop.weights[ids]
        fr = fr / fr.sum()
        links_sel = [pop.links[c] for c in ids]          # O(C)
        crs, weights, info = agg_mod.round_schedule(acfg, len(ids), fr,
                                                    links_sel, v_bytes)
        ks = agg_mod.ks_for_schedule(n_params, crs, acfg)
        if strat.wire.dense:
            rt = cost_model.uncompressed_round(links_sel, v_bytes)
        else:
            rt = cost_model.round_times(
                links_sel, v_bytes, strat.wire.cr_eff(crs, n_params))
        result.comm_actual_s += rt.actual

        xs, ys = zip(*(_client_batches(cfg, means, pop.skew_seeds[c], rnd)
                       for c in ids))
        x = {"step_mask": smask, "active": active,
             "weights": jnp.asarray(weights, jnp.float32),
             "ks": jnp.asarray(ks, jnp.int32),
             "batches": {"x": jnp.asarray(np.stack(xs)),
                         "y": jnp.asarray(np.stack(ys))}}
        if ef:
            gathered = store.gather(ids)
            res_dev = (tuple(jnp.asarray(a) for a in gathered)
                       if step.layout == "topk_complement"
                       else jnp.asarray(gathered[0]))
        out = step(flat, res_dev, x)
        flat = out["flat"]
        if ef:
            if bool(out["overflow"]):
                raise RuntimeError(
                    f"round {rnd}: EF residual outgrew the sparse width "
                    f"{step.width} — plan emitted a k below the width's "
                    "k_min")
            res_dev = out["residuals"]
            new = (res_dev if isinstance(res_dev, tuple) else (res_dev,))
            store.scatter(ids, tuple(np.asarray(a) for a in new))
        result.losses.append(float(out["loss"]))
        result.wall_per_round.append(time.perf_counter() - t0)

    result.final_flat = np.asarray(flat)
    if store is not None:
        result.gather_seconds = store.gather_seconds
        result.scatter_seconds = store.scatter_seconds
        result.peak_state_bytes = store.peak_resident_bytes
    return result, step, store
