from repro.fed.client import make_local_trainer
from repro.fed.engine import (ClientUpdateSpec, MeshSimScan, SimScan,
                              aggregate_updates, compress_merge_leaf,
                              init_mesh_residuals, make_mesh_sim_scan,
                              make_sim_scan, spec_for)
from repro.fed.mesh_round import (make_fl_round_step, make_mesh_round_step,
                                  make_round_body)
from repro.fed.round_step import (FusedRoundStep, make_masked_local_trainer,
                                  make_round_step)
from repro.fed.server import FLServer
from repro.fed.simulation import (FLSimConfig, FLSimResult, mlp_accuracy,
                                  mlp_init, mlp_loss, plan_cohort, run_fl,
                                  run_fl_traced)

__all__ = ["make_local_trainer", "FLServer", "make_fl_round_step",
           "make_mesh_round_step", "make_round_body",
           "make_round_step", "make_masked_local_trainer", "FusedRoundStep",
           "ClientUpdateSpec", "spec_for", "aggregate_updates",
           "compress_merge_leaf", "make_sim_scan", "SimScan",
           "make_mesh_sim_scan", "MeshSimScan", "init_mesh_residuals",
           "FLSimConfig", "FLSimResult", "run_fl", "run_fl_traced",
           "plan_cohort", "mlp_init", "mlp_loss", "mlp_accuracy"]
