"""End-to-end FL simulation harness (paper §5 experiment loop).

Reproduces the paper's protocol on synthetic Dirichlet-partitioned data with
a small MLP classifier (offline stand-in for ResNet18/CIFAR — validation
targets the paper's *relative* claims; see DESIGN.md §7):

  for each round: sample C·N clients -> E local epochs SGD -> compress ->
  aggregate (fedavg | topk | eftopk | bcrs | bcrs_opwa) -> time accounting.

Two round engines (``fused`` flag):

  * fused (default): the whole round is ONE jitted program
    (repro.fed.round_step) — clients vmapped, traced-k compression, server
    update with donated buffers. O(1) XLA compiles per simulation.
  * legacy: the original per-client Python loop, kept as the parity
    reference (same rng stream, same schedules -> accuracies match the
    fused path within float-accumulation noise).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg_mod
from repro.core import cost_model
from repro.core.opwa import overlap_counts
from repro.data import (build_client_datasets, data_fractions,
                        dirichlet_partition, synthetic_classification)
from repro.fed.client import make_local_trainer
from repro.fed.server import FLServer
from repro.ft import FailureInjector, renormalize_coefficients


# --------------------------------------------------------------- small model
def mlp_init(key, dim: int, n_classes: int, hidden: int = 128):
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2 = 1 / np.sqrt(dim), 1 / np.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * s2,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, n_classes)) * s2,
        "b3": jnp.zeros((n_classes,)),
    }


def mlp_loss(params, batch):
    x, y = batch["x"], batch["y"]
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                                         axis=1))
    return loss, logits


@jax.jit
def mlp_accuracy(params, x, y):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    return jnp.mean(jnp.argmax(logits, -1) == y)


# ------------------------------------------------------------------- harness
@dataclass
class FLSimConfig:
    """Defaults tuned (EXPERIMENTS.md §Repro) so that CR=0.01 Top-K visibly
    degrades accuracy — the regime where the paper's claims live."""
    n_clients: int = 10
    participation: float = 0.5        # C
    rounds: int = 40
    local_epochs: int = 1             # E
    batch_size: int = 64
    lr: float = 0.03                  # eta (local)
    beta: float = 0.1                 # Dirichlet heterogeneity
    n_train: int = 3000
    n_test: int = 1000
    n_classes: int = 20
    dim: int = 256
    hidden: int = 256
    noise: float = 3.0
    seed: int = 0
    eval_every: int = 5


@dataclass
class FLSimResult:
    accuracies: List[Tuple[int, float]] = field(default_factory=list)
    times: Optional[cost_model.TimeAccumulator] = None
    overlap_hist: Optional[np.ndarray] = None
    final_accuracy: float = 0.0
    wall_per_round: List[float] = field(default_factory=list)
    executed_rounds: List[int] = field(default_factory=list)

    def time_to_accuracy(self, target: float) -> Optional[float]:
        """Accumulated actual comm time up to AND INCLUDING the round whose
        evaluation first hits ``target`` (None if never reached).

        ``times.per_round[i]`` belongs to round ``executed_rounds[i]`` —
        rounds skipped by failure injection add no time entry, so the two
        lists are aligned by position, not by round number."""
        if self.times is None:
            return None
        per_round = self.times.per_round
        rounds_of = (self.executed_rounds
                     if len(self.executed_rounds) == len(per_round)
                     else list(range(len(per_round))))
        cum = 0.0
        i = 0
        for r, acc in self.accuracies:
            while i < len(per_round) and rounds_of[i] <= r:
                cum += per_round[i].actual
                i += 1
            if acc >= target:
                return cum
        return None


# ----------------------------------------------------------- fused batching
def _client_steps(ds, sim: FLSimConfig) -> int:
    return max(1, (len(ds) // sim.batch_size)) * sim.local_epochs


def _stack_client_batches(clients, selected, sim: FLSimConfig, s_max: int,
                          rng) -> Tuple[dict, jax.Array]:
    """Draw each selected client's batches (same rng stream as the legacy
    loop), zero-pad to ``s_max`` steps, stack to [C, S, ...] + mask [C, S].

    Padded steps carry zeros and are masked to exact no-ops inside the
    fused trainer, so ragged step counts cost one static shape, not one
    recompile per cohort."""
    xs_all, ys_all = [], []
    mask = np.zeros((len(selected), s_max), bool)
    for j, c in enumerate(selected):
        ds = clients[c]
        steps = _client_steps(ds, sim)
        xs, ys = ds.fixed_batches(sim.batch_size, steps, rng)
        if steps < s_max:
            xs = np.concatenate(
                [xs, np.zeros((s_max - steps,) + xs.shape[1:], xs.dtype)])
            ys = np.concatenate(
                [ys, np.zeros((s_max - steps,) + ys.shape[1:], ys.dtype)])
        xs_all.append(xs)
        ys_all.append(ys)
        mask[j, :steps] = True
    batches = {"x": jnp.asarray(np.stack(xs_all)),
               "y": jnp.asarray(np.stack(ys_all))}
    return batches, jnp.asarray(mask)


def run_fl(sim: FLSimConfig, acfg: agg_mod.AggregationConfig,
           failure: Optional[FailureInjector] = None,
           collect_overlap: bool = False, fused: bool = True) -> FLSimResult:
    rng = np.random.default_rng(sim.seed)
    key = jax.random.PRNGKey(sim.seed)

    # data
    x, y = synthetic_classification(sim.n_train + sim.n_test, sim.n_classes,
                                    sim.dim, rng, noise=sim.noise)
    x_train, y_train = x[: sim.n_train], y[: sim.n_train]
    x_test, y_test = x[sim.n_train:], y[sim.n_train:]
    parts = dirichlet_partition(y_train, sim.n_clients, sim.beta, rng,
                                min_size=sim.batch_size)
    clients = build_client_datasets(x_train, y_train, parts)
    fracs_all = data_fractions(parts)

    # model + server
    params = mlp_init(key, sim.dim, sim.n_classes, hidden=sim.hidden)
    links = cost_model.sample_links(sim.n_clients, rng)
    server = FLServer(params=params, acfg=acfg, eta=1.0, links=links)
    if fused:
        server.init_fused(mlp_loss, sim.lr, collect_overlap=collect_overlap)
        s_max = max(_client_steps(ds, sim) for ds in clients)
    else:
        local_train = jax.jit(make_local_trainer(mlp_loss, sim.lr))

    result = FLSimResult()
    overlap_hists = []
    n_sel = max(1, int(round(sim.n_clients * sim.participation)))

    for rnd in range(sim.rounds):
        t0 = time.perf_counter()
        selected = rng.choice(sim.n_clients, n_sel, replace=False)
        if failure is not None:
            alive = failure.survivors(rnd, sim.n_clients)
            selected = np.array([c for c in selected if alive[c]])
            if len(selected) == 0:
                continue
        fr = fracs_all[selected]
        fr = fr / fr.sum()
        is_overlap_round = collect_overlap and rnd == sim.rounds // 2

        if fused:
            batches, step_mask = _stack_client_batches(
                clients, selected, sim, s_max, rng)
            info = server.round_fused(batches, step_mask, fr, selected,
                                      want_overlap=is_overlap_round)
            if is_overlap_round:
                counts = np.asarray(info["overlap_counts"])
                overlap_hists.append(np.bincount(
                    counts[counts > 0], minlength=len(selected) + 1))
        else:
            deltas = []
            for c in selected:
                ds = clients[c]
                steps = _client_steps(ds, sim)
                xs, ys = ds.fixed_batches(sim.batch_size, steps, rng)
                delta, _ = local_train(
                    server.params,
                    {"x": jnp.asarray(xs), "y": jnp.asarray(ys)})
                deltas.append(delta)
            info = server.round(deltas, fr, selected)

            if is_overlap_round:
                # reproduce Fig. 4: histogram of retained-parameter overlap
                from repro.core.compression import flatten_tree, topk_compress
                flat = jnp.stack([flatten_tree(d)[0] for d in deltas])
                crs = info.get("crs", np.full(len(deltas), acfg.cr))
                masks = jnp.stack([
                    topk_compress(flat[i], float(crs[i])).mask
                    for i in range(flat.shape[0])])
                counts = np.asarray(overlap_counts(masks))
                hist = np.bincount(counts[counts > 0],
                                   minlength=len(deltas) + 1)
                overlap_hists.append(hist)

        server._flat.block_until_ready()
        result.wall_per_round.append(time.perf_counter() - t0)
        result.executed_rounds.append(rnd)

        if rnd % sim.eval_every == 0 or rnd == sim.rounds - 1:
            acc = float(mlp_accuracy(server.params, jnp.asarray(x_test),
                                     jnp.asarray(y_test)))
            result.accuracies.append((rnd, acc))

    result.times = server.times
    result.final_accuracy = result.accuracies[-1][1] if result.accuracies else 0.0
    if overlap_hists:
        result.overlap_hist = overlap_hists[0]
    return result
