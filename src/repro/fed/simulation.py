"""End-to-end FL simulation harness (paper §5 experiment loop).

Reproduces the paper's protocol on synthetic Dirichlet-partitioned data with
a small MLP classifier (offline stand-in for ResNet18/CIFAR — validation
targets the paper's *relative* claims; see docs/DESIGN.md §7):

  for each round: sample C·N clients -> E local epochs SGD -> compress ->
  aggregate (fedavg | topk | eftopk | bcrs | bcrs_opwa) -> time accounting.

Three round engines (``engine`` / legacy ``fused`` flag):

  * fused (default): each round is ONE jitted program
    (repro.fed.round_step) — clients vmapped, traced-k compression, server
    update with donated buffers, batch staging double-buffered via
    ``device_put``. O(1) XLA compiles per simulation.
  * scan: the ENTIRE simulation is ONE jitted ``lax.scan`` over rounds
    (repro.fed.engine.make_sim_scan) — server flat params + EF residuals
    threaded as carry, host-precomputed cohort/schedule/batch-index arrays
    as xs, batches gathered in-jit. One compile, zero per-round dispatch;
    bit-compatible with the fused engine on the shared seeded rng stream.
  * legacy: the original per-client Python loop, kept as the parity
    reference (same rng stream, same schedules -> accuracies match the
    fused path within float-accumulation noise).

All engines draw cohort selection, failure survival, straggler arrivals, and
batch indices from ONE host rng stream in identical order, so their
trajectories are comparable point by point. ``run_fl_traced`` additionally
offers a fully in-jit sampling path (PRNG-key-driven masks instead of host
numpy — its own stream, not bit-parity with the host engines).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg_mod
from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.core.opwa import overlap_counts
from repro.data import (build_client_datasets, data_fractions,
                        dirichlet_partition, synthetic_classification)
from repro.fed import engine as engine_mod
from repro.fed.client import make_local_trainer
from repro.fed.server import FLServer
from repro.ft import FailureInjector, StragglerPolicy, arrivals, over_select
from repro.ft.failures import survivors_traced
from repro.ft.straggler import (arrival_mask_traced,
                                renormalize_coefficients_traced)


# --------------------------------------------------------------- small model
def mlp_init(key, dim: int, n_classes: int, hidden: int = 128):
    k1, k2, k3 = jax.random.split(key, 3)
    s1, s2 = 1 / np.sqrt(dim), 1 / np.sqrt(hidden)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * s1,
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, hidden)) * s2,
        "b2": jnp.zeros((hidden,)),
        "w3": jax.random.normal(k3, (hidden, n_classes)) * s2,
        "b3": jnp.zeros((n_classes,)),
    }


def mlp_loss(params, batch):
    x, y = batch["x"], batch["y"]
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32),
                                         axis=1))
    return loss, logits


@jax.jit
def mlp_accuracy(params, x, y):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    h = jax.nn.relu(h @ params["w2"] + params["b2"])
    logits = h @ params["w3"] + params["b3"]
    return jnp.mean(jnp.argmax(logits, -1) == y)


# ------------------------------------------------------------------- harness
@dataclass
class FLSimConfig:
    """Defaults tuned (EXPERIMENTS.md §Repro) so that CR=0.01 Top-K visibly
    degrades accuracy — the regime where the paper's claims live."""
    n_clients: int = 10
    participation: float = 0.5        # C
    rounds: int = 40
    local_epochs: int = 1             # E
    batch_size: int = 64
    lr: float = 0.03                  # eta (local)
    beta: float = 0.1                 # Dirichlet heterogeneity
    n_train: int = 3000
    n_test: int = 1000
    n_classes: int = 20
    dim: int = 256
    hidden: int = 256
    noise: float = 3.0
    seed: int = 0
    eval_every: int = 5
    #: ragged-step mitigation: cap every client's local step count at this
    #: quantile of the per-client step distribution (1.0 = off). Under
    #: extreme Dirichlet skew the fused/scan engines pad every client to the
    #: cohort max (exact no-op steps, up to ~3x wasted compute at beta=0.1);
    #: trimming the tail trades a little local work of the largest clients
    #: for a much tighter static shape. Approximation knob — changes the
    #: trajectory, so parity suites leave it at 1.0.
    step_cap_quantile: float = 1.0
    # ------------------------- engine="async" (FedBuff buffered) knobs ----
    #: merge buffer size K (0 -> the synchronous cohort size C·N). In async
    #: mode ``rounds`` counts buffer FLUSHES, keeping trajectories and eval
    #: cadence comparable with the synchronous engines round-for-round
    async_buffer_k: int = 0
    #: in-flight upload concurrency M (0 -> min(2K, N - K), the FedBuff
    #: convention of over-provisioning dispatches vs the buffer)
    async_concurrency: int = 0
    #: staleness-discount exponent: w_i / (1 + s_i)^alpha (0 disables)
    async_alpha: float = 0.5
    #: partial-flush stall deadline (seconds of virtual time after the
    #: FIRST arrival into an empty buffer; inf = only flush when full)
    async_stall_s: float = float("inf")
    #: degenerate parity mode: replay the synchronous host round plans
    #: through the async train/merge programs (zero staleness by
    #: construction) — reproduces the scan engines' trajectories
    async_sync_arrivals: bool = False
    #: per-attempt mid-transfer upload failure probability; failed attempts
    #: resume from their byte offset after exponential backoff
    async_p_fail_upload: float = 0.0
    async_max_attempts: int = 3
    async_backoff_s: float = 0.5
    async_backoff_factor: float = 2.0
    #: hard wall-clock deadline per upload (seconds since dispatch)
    async_upload_timeout_s: float = float("inf")
    #: batched dispatch: record dispatches as pending and train them in
    #: padded vmapped WAVES at flush / ring-eviction / checkpoint time (one
    #: jit dispatch per wave shape bucket instead of one per upload) —
    #: bit-exact with per-client dispatch (False), which remains as the
    #: sequential baseline the dispatch benchmark compares against
    async_batch_dispatch: bool = True
    #: retained-parameter-version ring depth V for wave training. Must be
    #: >= the observable staleness bound (``async_engine.min_version_ring``:
    #: 1 when M <= K, else 2); deeper rings batch better under heavy
    #: staleness (shallow rings force-retire pending waves early, never
    #: affecting correctness)
    async_version_ring: int = 8
    #: opt back into the dense [P+1, n] EF residual reference store (the
    #: default is the sparse out-of-core ``population.ClientStateStore`` in
    #: the strategy's declared ``residual_layout``)
    async_dense_store: bool = False
    #: sparse-store chunking: clients per chunk
    async_store_chunk: int = 256
    #: sparse-store LRU bound: max resident chunks (0 = unbounded; bounding
    #: requires ``async_store_spill``)
    async_store_resident: int = 0
    #: directory evicted sparse-store chunks spill into ("" = none)
    async_store_spill: str = ""
    # ------------------------------------------- link population shape ----
    #: client uplink bandwidth distribution (normal, floored at 0.05 Mbps —
    #: ``cost_model.sample_link_arrays``). Defaults match the historical
    #: hard-coded draw, so seeded trajectories are unchanged; raising the sd
    #: produces the long-tailed heterogeneous-bandwidth mixes the async
    #: bench sweeps (benchmarks/bench_round.py --async)
    link_bw_mean_mbps: float = 1.0
    link_bw_sd_mbps: float = 0.2


@dataclass
class FLSimResult:
    accuracies: List[Tuple[int, float]] = field(default_factory=list)
    times: Optional[cost_model.TimeAccumulator] = None
    overlap_hist: Optional[np.ndarray] = None
    final_accuracy: float = 0.0
    wall_per_round: List[float] = field(default_factory=list)
    executed_rounds: List[int] = field(default_factory=list)
    #: final EF residuals [C, n] (eftopk only) — exposed so the scan engine's
    #: bit-parity with the fused engine is directly assertable
    final_residuals: Optional[np.ndarray] = None
    #: engine="async" only: the finished ``BufferedAsyncLoop`` (buffer /
    #: in-flight / counter state) — what the crash-restart bit-exactness
    #: tests compare against an uninterrupted run
    async_loop: Optional[object] = None

    def time_to_accuracy(self, target: float) -> Optional[float]:
        """Accumulated actual comm time up to AND INCLUDING the round whose
        evaluation first hits ``target`` (None if never reached).

        ``times.per_round[i]`` belongs to round ``executed_rounds[i]`` —
        rounds skipped by failure injection add no time entry, so the two
        lists are aligned by position, not by round number."""
        if self.times is None:
            return None
        per_round = self.times.per_round
        rounds_of = (self.executed_rounds
                     if len(self.executed_rounds) == len(per_round)
                     else list(range(len(per_round))))
        cum = 0.0
        i = 0
        for r, acc in self.accuracies:
            while i < len(per_round) and rounds_of[i] <= r:
                cum += per_round[i].actual
                i += 1
            if acc >= target:
                return cum
        return None


# ------------------------------------------------------------- shared setup
def _setup_sim(sim: FLSimConfig, acfg: agg_mod.AggregationConfig):
    """Seeded experiment setup shared by every entry point (run_fl and
    run_fl_traced MUST consume the host rng identically here, or 'same
    seed' stops meaning 'same dataset/links'). Returns
    (rng, clients, parts, fracs_all, splits, server)."""
    rng = np.random.default_rng(sim.seed)
    key = jax.random.PRNGKey(sim.seed)
    x, y = synthetic_classification(sim.n_train + sim.n_test, sim.n_classes,
                                    sim.dim, rng, noise=sim.noise)
    x_train, y_train = x[: sim.n_train], y[: sim.n_train]
    x_test, y_test = x[sim.n_train:], y[sim.n_train:]
    parts = dirichlet_partition(y_train, sim.n_clients, sim.beta, rng,
                                min_size=sim.batch_size)
    clients = build_client_datasets(x_train, y_train, parts)
    fracs_all = data_fractions(parts)
    params = mlp_init(key, sim.dim, sim.n_classes, hidden=sim.hidden)
    links = cost_model.sample_links(sim.n_clients, rng,
                                    bw_mean_mbps=sim.link_bw_mean_mbps,
                                    bw_sd_mbps=sim.link_bw_sd_mbps)
    server = FLServer(params=params, acfg=acfg, eta=1.0, links=links)
    return (rng, clients, parts, fracs_all,
            (x_train, y_train, x_test, y_test), server)


# ----------------------------------------------------------- host-side plan
def _client_steps(ds, sim: FLSimConfig) -> int:
    return max(1, (len(ds) // sim.batch_size)) * sim.local_epochs


def _steps_by_client(clients, sim: FLSimConfig) -> np.ndarray:
    """Per-client local step counts with the optional quantile cap applied
    (shared by every engine so the trajectories stay comparable)."""
    steps = np.array([_client_steps(ds, sim) for ds in clients], np.int64)
    if sim.step_cap_quantile < 1.0:
        cap = max(1, int(np.ceil(
            np.quantile(steps, sim.step_cap_quantile))))
        steps = np.minimum(steps, cap)
    return steps


def planned_client_steps(sim: FLSimConfig) -> np.ndarray:
    """Per-client local step counts (cap applied) for ``sim``'s seeded
    dataset — the exact partition every engine trains on, rebuilt through
    ``_setup_sim`` so reporting/benchmarks can't drift from the harness's
    rng draw order."""
    _, clients, *_ = _setup_sim(sim, agg_mod.AggregationConfig())
    return _steps_by_client(clients, sim)


def cohort_slots(n_clients: int, participation: float) -> int:
    """Target cohort size C·N — the ONE place the rounding rule lives.
    ``plan_cohort`` never emits a cohort larger than this, so it is also the
    static slot count every padded [rounds, C] plan array and EF residual
    buffer is sized with (fl_train, the scan engines)."""
    return max(1, int(round(n_clients * participation)))


def _link_columns(links, ids) -> Tuple[np.ndarray, np.ndarray]:
    """(bandwidth_bps, latency_s) float64 columns for the given client ids —
    an O(C) slice when ``links`` is a ``cost_model.LinkArrays`` (population
    scale), an O(C) comprehension over ``ClientLink`` objects otherwise.
    Either way the values are identical, so downstream vectorized math is
    bit-exact with the legacy per-object loops."""
    if isinstance(links, cost_model.LinkArrays):
        return links.bandwidth_bps[ids], links.latency_s[ids]
    return (np.array([links[c].bandwidth_bps for c in ids], np.float64),
            np.array([links[c].latency_s for c in ids], np.float64))


def plan_cohort(rnd: int, rng, *, n_clients: int, participation: float,
                fracs_all, links, v_bytes, acfg,
                failure: Optional[FailureInjector] = None,
                straggler: Optional[StragglerPolicy] = None,
                cohort: Optional[int] = None,
                sparse_failures: bool = False):
    """One round's cohort: selection -> failure survivors -> straggler
    arrivals -> renormalized weights. Shared by ALL engines — the three
    simulation engines AND the real-model mesh driver
    (``launch.fl_train``) — so failure/straggler planning has exactly one
    implementation; within the simulation harness the host rng stream is
    consumed in exactly this order everywhere, which is what makes
    legacy/fused/scan trajectories comparable. Returns (selected, fr) or
    None when the whole cohort died (the round is skipped).

    Population scale: pass ``cohort`` to fix the target size directly
    (instead of ``round(P * participation)`` — at P = 10^6 the cohort is an
    absolute budget, not a fraction) and ``sparse_failures=True`` to draw
    survivors per sampled id (``FailureInjector.survivors_at``, O(C)) rather
    than the dense ``[P]`` vector — its own seeded stream, and it revives a
    cohort member when all die, so the round is never skipped."""
    n_sel = cohort if cohort is not None \
        else cohort_slots(n_clients, participation)
    n_draw = over_select(n_sel, straggler) if straggler is not None else n_sel
    n_draw = min(n_draw, n_clients)
    selected = rng.choice(n_clients, n_draw, replace=False)
    if failure is not None:
        if sparse_failures:
            selected = selected[failure.survivors_at(rnd, selected)]
        else:
            alive = failure.survivors(rnd, n_clients)
            selected = selected[alive[selected]]
        if len(selected) == 0:
            return None
    if straggler is not None and len(selected) > n_sel:
        # completion times from the paper cost model at the configured CR,
        # priced through the strategy's declared wire format (dense -> 1.0,
        # the legacy fedavg convention; packed formats scale honestly).
        # Vectorized over the cohort (comm_time_batch is elementwise
        # bit-identical to the scalar loop) — O(C) numpy, no per-client
        # Python at any population size
        cr_eff = acfg.strat.wire.cr_eff(acfg.cr, int(v_bytes // 4))
        bw, lat = _link_columns(links, selected)
        t = bcrs_mod.comm_time_batch(v_bytes, bw, lat, cr_eff)
        chosen, _ = arrivals(t, n_sel, straggler)
        selected = selected[chosen]
    fr = fracs_all[selected]
    fr = fr / fr.sum()
    return selected, fr


def _plan_cohort(rnd: int, rng, sim: FLSimConfig, fracs_all, links, v_bytes,
                 acfg, failure: Optional[FailureInjector],
                 straggler: Optional[StragglerPolicy]):
    """FLSimConfig-flavored wrapper over ``plan_cohort`` for the simulation
    engines (same rng consumption, same return contract)."""
    return plan_cohort(rnd, rng, n_clients=sim.n_clients,
                       participation=sim.participation, fracs_all=fracs_all,
                       links=links, v_bytes=v_bytes, acfg=acfg,
                       failure=failure, straggler=straggler)


def _stack_client_batches(clients, selected, sim: FLSimConfig,
                          steps_by_client, s_max: int, rng
                          ) -> Tuple[dict, jax.Array]:
    """Draw each selected client's batches (same rng stream as the legacy
    loop), zero-pad to ``s_max`` steps, stack to [C, S, ...] + mask [C, S].

    Padded steps carry zeros and are masked to exact no-ops inside the
    fused trainer, so ragged step counts cost one static shape, not one
    recompile per cohort."""
    xs_all, ys_all = [], []
    mask = np.zeros((len(selected), s_max), bool)
    for j, c in enumerate(selected):
        ds = clients[c]
        steps = int(steps_by_client[c])
        xs, ys = ds.fixed_batches(sim.batch_size, steps, rng)
        if steps < s_max:
            xs = np.concatenate(
                [xs, np.zeros((s_max - steps,) + xs.shape[1:], xs.dtype)])
            ys = np.concatenate(
                [ys, np.zeros((s_max - steps,) + ys.shape[1:], ys.dtype)])
        xs_all.append(xs)
        ys_all.append(ys)
        mask[j, :steps] = True
    batches = {"x": np.stack(xs_all), "y": np.stack(ys_all)}
    return batches, mask


def _is_eval_round(sim: FLSimConfig, rnd: int) -> bool:
    """The ONE definition of the eval cadence — every engine's accuracy
    trajectory samples exactly these rounds."""
    return rnd % sim.eval_every == 0 or rnd == sim.rounds - 1


def _eval_plan(sim: FLSimConfig, rnds) -> Tuple[np.ndarray, np.ndarray]:
    """(eval_write bool [len(rnds)], eval_slot i32 [len(rnds)]) for the given
    executed round numbers — the scan engines' snapshot schedule."""
    write = np.array([_is_eval_round(sim, r) for r in rnds], bool)
    slot = np.zeros((len(write),), np.int32)
    slot[write] = np.arange(int(write.sum()), dtype=np.int32)
    return write, slot


def _overlap_hist(counts: np.ndarray, cohort_size: int) -> np.ndarray:
    """Fig. 4 binning shared by every engine: histogram of the nonzero
    degrees of overlap, padded to cohort_size+1 bins (degree 0 dropped)."""
    counts = np.asarray(counts)
    return np.bincount(counts[counts > 0], minlength=cohort_size + 1)


# ------------------------------------------------------------------ run_fl
def run_fl(sim: FLSimConfig, acfg: agg_mod.AggregationConfig,
           failure: Optional[FailureInjector] = None,
           collect_overlap: bool = False, fused: bool = True,
           engine: Optional[str] = None,
           straggler: Optional[StragglerPolicy] = None,
           checkpoint_dir: Optional[str] = None, checkpoint_every: int = 0,
           stop_after: Optional[int] = None) -> FLSimResult:
    """Run the simulation. ``engine`` selects the round engine
    ("legacy" | "fused" | "scan" | "pop_scan" | "population" | "async");
    when None it falls back to the legacy ``fused`` bool
    ("fused" / "legacy").

    ``engine="async"`` is the FedBuff-style buffered engine
    (``fed.async_engine``): ``sim.rounds`` counts buffer flushes, the
    ``sim.async_*`` knobs shape the buffer/arrival process, and
    ``checkpoint_dir`` / ``checkpoint_every`` (flushes) enable crash-safe
    state persistence — a rerun with the same config resumes bit-exactly
    from the newest intact checkpoint. ``stop_after`` aborts after that
    many flushes (test hook simulating a crash at a flush boundary).

    The two population engines treat ``sim.n_clients`` as the registered
    population P and carry EF residuals PER CLIENT (state survives cohort
    resizes — no reset-on-resize): "pop_scan" keeps them in a dense
    ``[P + 1, n]`` scan carry (the small-P reference), "population" streams
    each round's cohort through a sparse out-of-core
    ``population.ClientStateStore`` (round state O(C x n + P x (n - k_min)),
    bit-exact with pop_scan)."""
    if engine is None:
        engine = "fused" if fused else "legacy"
    if engine not in ("legacy", "fused", "scan", "pop_scan", "population",
                      "async"):
        raise ValueError(f"unknown engine {engine!r}")
    if engine != "async" and (checkpoint_dir is not None
                              or stop_after is not None):
        raise ValueError("checkpoint_dir / stop_after are engine='async' "
                         "features (the sync checkpointing entry point is "
                         "launch.fl_train)")
    (rng, clients, parts, fracs_all,
     (x_train, y_train, x_test, y_test), server) = _setup_sim(sim, acfg)
    links = server.links
    steps_by_client = _steps_by_client(clients, sim)
    s_max = int(steps_by_client.max())

    if engine in ("scan", "pop_scan"):
        return _run_scan(sim, acfg, rng, clients, parts, fracs_all, links,
                         server, steps_by_client, s_max, x_train, y_train,
                         x_test, y_test, failure, straggler, collect_overlap,
                         per_client_ef=(engine == "pop_scan"))
    if engine == "async":
        if collect_overlap:
            raise ValueError("the async engine does not carry the Fig. 4 "
                             "overlap instrumentation — use engine='scan'")
        from repro.fed.async_engine import run_async_sim
        return run_async_sim(sim, acfg, rng, clients, parts, fracs_all,
                             links, server, steps_by_client, s_max, x_train,
                             y_train, x_test, y_test, failure, straggler,
                             checkpoint_dir=checkpoint_dir,
                             checkpoint_every=checkpoint_every,
                             stop_after=stop_after)
    if engine == "population":
        if collect_overlap:
            raise ValueError("the population engine does not carry the "
                             "Fig. 4 overlap instrumentation — use "
                             "engine='scan' or 'pop_scan'")
        return _run_population(sim, acfg, rng, clients, parts, fracs_all,
                               links, server, steps_by_client, s_max,
                               x_train, y_train, x_test, y_test, failure,
                               straggler)

    if engine == "fused":
        server.init_fused(mlp_loss, sim.lr, collect_overlap=collect_overlap)
    else:
        local_train = jax.jit(make_local_trainer(mlp_loss, sim.lr))

    result = FLSimResult()
    overlap_hists = []

    def round_stream():
        """Per-round plans; for the fused engine the stacked client batches
        are staged to device here (async ``jnp.asarray`` transfer) so the
        consumer can pull round r+1 — staging its buffers — while round r's
        dispatched program is still running: double-buffered staging, two
        rounds' batch buffers alive at once, each consumed exactly once.
        The legacy engine draws its batches in the consumer, so it must not
        be prefetched (the shared rng stream would reorder)."""
        for rnd in range(sim.rounds):
            plan = _plan_cohort(rnd, rng, sim, fracs_all, links,
                                server.v_bytes, acfg, failure, straggler)
            if plan is None:
                continue
            selected, fr = plan
            staged = None
            if engine == "fused":
                batches, mask = _stack_client_batches(
                    clients, selected, sim, steps_by_client, s_max, rng)
                staged = ({k: jnp.asarray(v) for k, v in batches.items()},
                          jnp.asarray(mask))
            yield rnd, selected, fr, staged

    stream = round_stream()
    item = next(stream, None)
    while item is not None:
        rnd, selected, fr, staged = item
        t0 = time.perf_counter()
        is_overlap_round = collect_overlap and rnd == sim.rounds // 2

        if engine == "fused":
            batches, step_mask = staged
            info = server.round_fused(batches, step_mask, fr, selected,
                                      want_overlap=is_overlap_round)
            # prefetch: stage the NEXT round's buffers while this round's
            # dispatched program is still running on device
            item = next(stream, None)
            if is_overlap_round:
                overlap_hists.append(_overlap_hist(info["overlap_counts"],
                                                   len(selected)))
        else:
            deltas = []
            for c in selected:
                ds = clients[c]
                steps = int(steps_by_client[c])
                xs, ys = ds.fixed_batches(sim.batch_size, steps, rng)
                delta, _ = local_train(
                    server.params,
                    {"x": jnp.asarray(xs), "y": jnp.asarray(ys)})
                deltas.append(delta)
            info = server.round(deltas, fr, selected)

            if is_overlap_round:
                # reproduce Fig. 4: histogram of retained-parameter overlap
                from repro.core.compression import flatten_tree, topk_compress
                flat = jnp.stack([flatten_tree(d)[0] for d in deltas])
                crs = info.get("crs", np.full(len(deltas), acfg.cr))
                masks = jnp.stack([
                    topk_compress(flat[i], float(crs[i])).mask
                    for i in range(flat.shape[0])])
                overlap_hists.append(_overlap_hist(
                    np.asarray(overlap_counts(masks)), len(deltas)))
            item = next(stream, None)

        server._flat.block_until_ready()
        result.wall_per_round.append(time.perf_counter() - t0)
        result.executed_rounds.append(rnd)

        if _is_eval_round(sim, rnd):
            acc = float(mlp_accuracy(server.params, jnp.asarray(x_test),
                                     jnp.asarray(y_test)))
            result.accuracies.append((rnd, acc))

    result.times = server.times
    result.final_accuracy = result.accuracies[-1][1] if result.accuracies else 0.0
    if acfg.strat.needs_residuals and server._residuals is not None:
        result.final_residuals = np.asarray(server._residuals)
    if overlap_hists:
        result.overlap_hist = overlap_hists[0]
    return result


# ------------------------------------------------------- shared round plans
def _plan_rounds(sim, acfg, rng, clients, parts, fracs_all, links, server,
                 steps_by_client, s_max, failure, straggler,
                 collect_overlap) -> list:
    """Precompute every executed round's plan on the host (ONE rng stream,
    consumed in exactly the order the fused loop does): cohort -> BCRS
    schedule -> retained counts -> batch sample indices, with comm time
    accounted into ``server.times`` as it goes. Shared verbatim by the scan
    engine and both population engines, so their trajectories and comm
    accounting are identical by construction.

    Returns [(rnd, selected, weights, ks, ks_overlap, idx)]."""
    n_params, v_bytes = server.n_params, server.v_bytes
    bs = sim.batch_size
    plans = []
    for rnd in range(sim.rounds):
        plan = _plan_cohort(rnd, rng, sim, fracs_all, links, v_bytes, acfg,
                            failure, straggler)
        if plan is None:
            continue
        selected, fr = plan
        c_r = len(selected)
        links_sel = [links[i] for i in selected]
        crs, weights, info = agg_mod.round_schedule(acfg, c_r, fr, links_sel,
                                                    v_bytes)
        ks = agg_mod.ks_for_schedule(n_params, crs, acfg)
        ks_overlap = (agg_mod.overlap_ks(acfg, info, c_r, n_params)
                      if collect_overlap and rnd == sim.rounds // 2
                      else None)
        # batch sample indices, drawn per client in cohort order — the exact
        # rng calls the fused path's host staging makes
        idx = np.zeros((c_r, s_max * bs), np.int32)
        for j, c in enumerate(selected):
            steps = int(steps_by_client[c])
            local = clients[c].fixed_batch_indices(bs, steps, rng)
            idx[j, : steps * bs] = parts[c][local]
        server._account_time(dict(info), links_sel)
        plans.append((rnd, selected, weights, ks, ks_overlap, idx))
    return plans


# -------------------------------------------------------------- scan engine
def _run_scan(sim, acfg, rng, clients, parts, fracs_all, links, server,
              steps_by_client, s_max, x_train, y_train, x_test, y_test,
              failure, straggler, collect_overlap,
              per_client_ef: bool = False) -> FLSimResult:
    """Whole-simulation ``lax.scan`` engine: precompute every round's plan on
    host (same rng stream as the fused loop), stack the schedules + batch
    sample indices as scan xs, run ONE jitted program, then evaluate the
    returned per-round model trajectory.

    ``per_client_ef`` switches to the "pop_scan" carry contract: EF
    residuals live in a dense ``[P + 1, n]`` PER-CLIENT matrix (row P is the
    padded-slot sentinel) that every round slot-gathers/scatters by the
    cohort ids — the bit-exact dense reference for the sparse out-of-core
    client store, and the first engine whose EF state survives cohort
    resizes (no ``reset_ef``)."""
    n_sel = cohort_slots(sim.n_clients, sim.participation)
    n_params, v_bytes = server.n_params, server.v_bytes
    bs = sim.batch_size
    ef = acfg.strat.needs_residuals

    plans = _plan_rounds(sim, acfg, rng, clients, parts, fracs_all, links,
                         server, steps_by_client, s_max, failure, straggler,
                         collect_overlap)
    result = FLSimResult()
    if not plans:
        result.times = server.times
        return result

    # ------------------------------------------------- stack xs [R, C, ...]
    r_exec, c_max = len(plans), n_sel
    xs: Dict[str, np.ndarray] = {
        "sample_idx": np.zeros((r_exec, c_max, s_max, bs), np.int32),
        "step_mask": np.zeros((r_exec, c_max, s_max), bool),
        "active": np.zeros((r_exec, c_max), bool),
        "weights": np.zeros((r_exec, c_max), np.float32),
        "ks": np.ones((r_exec, c_max), np.int32),
    }
    if ef and not per_client_ef:
        xs["reset_ef"] = np.zeros((r_exec,), bool)
    if ef and per_client_ef:
        # slot -> client id; padded slots point at the sentinel row P
        xs["cohort"] = np.full((r_exec, c_max), sim.n_clients, np.int32)
    if collect_overlap:
        xs["ks_overlap"] = np.ones((r_exec, c_max), np.int32)
        xs["overlap_round"] = np.zeros((r_exec,), bool)
    # eval-round snapshots land in an O(E x n) carried buffer (the scanned
    # program no longer emits the model every round)
    xs["eval_write"], xs["eval_slot"] = _eval_plan(sim,
                                                   [p[0] for p in plans])
    n_evals = int(xs["eval_write"].sum())
    prev_c = None
    for i, (rnd, selected, weights, ks, ks_overlap, idx) in enumerate(plans):
        c_r = len(selected)
        xs["sample_idx"][i, :c_r] = idx.reshape(c_r, s_max, bs)
        for j, c in enumerate(selected):
            xs["step_mask"][i, j, : int(steps_by_client[c])] = True
        xs["active"][i, :c_r] = True
        xs["weights"][i, :c_r] = weights
        xs["ks"][i, :c_r] = ks
        if ef and per_client_ef:
            xs["cohort"][i, :c_r] = selected
        elif ef:
            # mirrors FLServer.round_fused: residuals reset whenever the
            # cohort size changes between consecutive EXECUTED rounds
            xs["reset_ef"][i] = prev_c is not None and c_r != prev_c
        if ks_overlap is not None:
            xs["ks_overlap"][i, :c_r] = ks_overlap
            xs["overlap_round"][i] = True
        prev_c = c_r

    # --------------------------------------------------- one compiled scan
    x_all, y_all = jnp.asarray(x_train), jnp.asarray(y_train)

    def gather_batches(p):
        idx = p["sample_idx"]
        return {"x": x_all[idx], "y": y_all[idx]}

    sim_fn = engine_mod.make_sim_scan(
        mlp_loss, server.params, lr=sim.lr, acfg=acfg, eta=server.eta,
        with_overlap=collect_overlap, make_batches=gather_batches,
        population=sim.n_clients if per_client_ef else None)
    res_rows = (sim.n_clients + 1) if per_client_ef else c_max
    residuals0 = (jnp.zeros((res_rows, n_params), jnp.float32) if ef
                  else jnp.zeros((0,), jnp.float32))
    evals0 = jnp.zeros((max(n_evals, 1), n_params), jnp.float32)
    xs_dev = {k: jnp.asarray(v) for k, v in xs.items()}
    # AOT-compile so wall_per_round reports the steady-state per-round cost
    # of the compiled trajectory (trace/compile is a one-off, just like the
    # fused engine's warmup rounds that benchmarks discard)
    compiled = sim_fn.compile(server._flat, residuals0, evals0, xs_dev)
    t_exec0 = time.perf_counter()
    out = compiled(server._flat, residuals0, evals0, xs_dev)
    out["flat"].block_until_ready()
    wall = time.perf_counter() - t_exec0

    # --------------------------------------------------------- host post
    server._flat = out["flat"]
    server.params = server._unravel(server._flat)
    evals_out = out["evals"]
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)
    for i, (rnd, selected, *_rest) in enumerate(plans):
        if xs["eval_write"][i]:
            snap = evals_out[int(xs["eval_slot"][i])]
            acc = float(mlp_accuracy(server._unravel(snap), xt, yt))
            result.accuracies.append((rnd, acc))
    result.executed_rounds = [p[0] for p in plans]
    result.wall_per_round = [wall / r_exec] * r_exec
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    if ef and per_client_ef:
        # PER-CLIENT matrix [P, n] (sentinel row dropped) — the dense
        # reference the sparse client store is parity-tested against
        result.final_residuals = np.asarray(
            out["residuals"][: sim.n_clients])
    elif ef:
        c_last = len(plans[-1][1])
        server._residuals = out["residuals"][:c_last]
        result.final_residuals = np.asarray(server._residuals)
    if collect_overlap:
        for i, (rnd, selected, *_rest) in enumerate(plans):
            if rnd == sim.rounds // 2:
                result.overlap_hist = _overlap_hist(
                    out["ys"]["overlap_counts"][i], len(selected))
    return result


# -------------------------------------------------------- population engine
def _run_population(sim, acfg, rng, clients, parts, fracs_all, links, server,
                    steps_by_client, s_max, x_train, y_train, x_test, y_test,
                    failure, straggler) -> FLSimResult:
    """Streaming-cohort engine over the sparse out-of-core client store:
    the same host plan as the scan engines (ONE rng stream), but each round
    is a single jitted program whose EF residuals arrive from / return to a
    ``population.ClientStateStore`` in the strategy's declared layout
    (densify-on-gather / sparsify-on-scatter inside the jit). Round state is
    O(C x n) device + O(P x width) host (chunked, spillable) — never
    ``[P, n]`` dense. Bit-exact with ``engine="pop_scan"`` (asserted in
    tests/test_population.py): same plans, same batch gathers, same
    aggregation arithmetic, lossless residual round-trips."""
    from repro.fed import population as pop_mod
    from repro.fed import round_step as rs_mod

    n_sel = cohort_slots(sim.n_clients, sim.participation)
    n_params, v_bytes = server.n_params, server.v_bytes
    bs = sim.batch_size
    strat = acfg.strat
    ef = strat.needs_residuals

    plans = _plan_rounds(sim, acfg, rng, clients, parts, fracs_all, links,
                         server, steps_by_client, s_max, failure, straggler,
                         False)
    result = FLSimResult()
    if not plans:
        result.times = server.times
        return result

    x_all, y_all = jnp.asarray(x_train), jnp.asarray(y_train)

    def gather_batches(x):
        idx = x["sample_idx"]
        return {"x": x_all[idx], "y": y_all[idx]}

    width = 0
    if ef and strat.residual_layout == "topk_complement":
        width = pop_mod.residual_width(
            n_params, min(int(np.min(p[3])) for p in plans))
    step = rs_mod.make_population_round_step(
        mlp_loss, server.params, lr=sim.lr, acfg=acfg, eta=server.eta,
        width=width, make_batches=gather_batches)
    store = None
    if ef:
        store = pop_mod.ClientStateStore(
            sim.n_clients, n_params, layout=strat.residual_layout,
            width=max(width, 1),
            chunk_clients=min(256, sim.n_clients))

    flat = server._flat
    res_dev = step.init_residuals(n_sel, n_params)
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)
    for rnd, selected, weights, ks, _ks_overlap, idx in plans:
        t0 = time.perf_counter()
        c_r = len(selected)
        x = {"sample_idx": np.zeros((n_sel, s_max, bs), np.int32),
             "step_mask": np.zeros((n_sel, s_max), bool),
             "active": np.zeros((n_sel,), bool),
             "weights": np.zeros((n_sel,), np.float32),
             "ks": np.ones((n_sel,), np.int32)}
        x["sample_idx"][:c_r] = idx.reshape(c_r, s_max, bs)
        for j, c in enumerate(selected):
            x["step_mask"][j, : int(steps_by_client[c])] = True
        x["active"][:c_r] = True
        x["weights"][:c_r] = weights
        x["ks"][:c_r] = ks
        x = {k: jnp.asarray(v) for k, v in x.items()}
        if ef:
            # pad the gathered cohort rows to the static slot count; the
            # jit's `active` mask round-trips the zero padding untouched
            bufs = []
            for g in store.gather(selected):
                buf = np.zeros((n_sel,) + g.shape[1:], g.dtype)
                buf[:c_r] = g
                bufs.append(jnp.asarray(buf))
            res_dev = (tuple(bufs) if step.layout == "topk_complement"
                       else bufs[0])
        out = step(flat, res_dev, x)
        flat = out["flat"]
        if ef:
            if bool(out["overflow"]):
                raise RuntimeError(
                    f"round {rnd}: EF residual outgrew sparse width "
                    f"{step.width}")
            res_dev = out["residuals"]
            new = (res_dev if isinstance(res_dev, tuple) else (res_dev,))
            store.scatter(selected,
                          tuple(np.asarray(a)[:c_r] for a in new))
        result.wall_per_round.append(time.perf_counter() - t0)
        result.executed_rounds.append(rnd)
        if _is_eval_round(sim, rnd):
            acc = float(mlp_accuracy(server._unravel(flat), xt, yt))
            result.accuracies.append((rnd, acc))

    server._flat = flat
    server.params = server._unravel(flat)
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    if ef:
        # PER-CLIENT [P, n] dense view (parity with pop_scan); small-P
        # engine — the large-P entry point is population.run_population_rounds
        result.final_residuals = store.dump_dense()
    return result


# ----------------------------------------------------- traced-sampling scan
def run_fl_traced(sim: FLSimConfig, acfg: agg_mod.AggregationConfig,
                  p_fail: float = 0.0,
                  straggler: Optional[StragglerPolicy] = None) -> FLSimResult:
    """Fully-traced sampling variant of the scan engine: cohort permutation,
    failure survival draws, straggler arrival deadlines, and batch index
    draws all happen INSIDE the one compiled program from a threaded PRNG
    key (``ft.failures.survivors_traced`` / ``ft.straggler.
    arrival_mask_traced`` masks). Self-consistent stream — the host-rng
    ``engine="scan"`` path remains the seeded parity reference.

    Host-side per-round work is exactly one PRNG key; the BCRS schedule is
    computed once over the full client set (links are round-invariant) and
    gathered per cohort in-jit, with coefficients renormalized over the
    surviving arrivals (``renormalize_coefficients_traced``). The sampled
    cohort is surfaced back to the host per round (ys), so comm-time
    accounting covers exactly the participating clients — the same
    accounting semantics as the host engines.
    """
    (rng, clients, parts, fracs_all,
     (x_train, y_train, x_test, y_test), server) = _setup_sim(sim, acfg)
    links = server.links
    key = jax.random.PRNGKey(sim.seed)
    fracs_all = np.asarray(fracs_all, np.float64)
    n_params, v_bytes = server.n_params, server.v_bytes
    n, bs = sim.n_clients, sim.batch_size

    steps_by_client = _steps_by_client(clients, sim)
    s_max = int(steps_by_client.max())
    n_sel = cohort_slots(n, sim.participation)
    n_draw = min(over_select(n_sel, straggler) if straggler else n_sel, n)

    # round-invariant per-client tables (links don't change, so the BCRS
    # schedule over the FULL client set is computable once on host)
    crs_all, coeffs_all, info = agg_mod.round_schedule(
        acfg, n, fracs_all / fracs_all.sum(), links, v_bytes)
    ks_all = agg_mod.ks_for_schedule(n_params, crs_all, acfg)
    cr_eff = acfg.strat.wire.cr_eff(acfg.cr, n_params)
    times_all = np.array([bcrs_mod.comm_time(v_bytes, l, cr_eff)
                          for l in links], np.float32)
    lens = np.array([len(ds) for ds in clients], np.int64)
    table = np.zeros((n, int(lens.max())), np.int32)
    for c, p in enumerate(parts):
        table[c, : len(p)] = p
    smask_all = (np.arange(s_max)[None, :]
                 < steps_by_client[:, None])          # [N, S]

    dev = dict(
        coeffs=jnp.asarray(coeffs_all, jnp.float32),
        ks=jnp.asarray(ks_all, jnp.int32),
        times=jnp.asarray(times_all),
        lens=jnp.asarray(lens, jnp.int32),
        table=jnp.asarray(table),
        smask=jnp.asarray(smask_all),
        x=jnp.asarray(x_train), y=jnp.asarray(y_train))
    weighted_by_coeffs = acfg.strat.weighting == "bcrs"

    def plan_fn(xrow):
        k_perm, k_fail, k_batch = jax.random.split(xrow["key"], 3)
        cohort = jax.random.permutation(k_perm, n)[:n_draw]
        active = survivors_traced(k_fail, n, p_fail)[cohort]
        if straggler is not None:
            t = jnp.where(active, dev["times"][cohort], jnp.inf)
            active = arrival_mask_traced(t, n_sel, straggler)
        coeffs = dev["coeffs"][cohort]
        if weighted_by_coeffs:
            w = renormalize_coefficients_traced(coeffs, active)
        else:
            w = jnp.where(active, coeffs, 0.0)
            w = w / jnp.maximum(jnp.sum(w), 1e-12)
        local = jax.random.randint(
            k_batch, (n_draw, s_max * bs), 0,
            dev["lens"][cohort][:, None])
        idx = jnp.take_along_axis(dev["table"][cohort], local, axis=1)
        return {"sample_idx": idx.reshape(n_draw, s_max, bs),
                "step_mask": dev["smask"][cohort],
                "active": active, "weights": w, "ks": dev["ks"][cohort],
                # surfaced to the host so comm time is accounted over the
                # clients that actually participated, like the host engines
                "ys_extra": {"cohort": cohort, "arrived": active}}

    def gather_batches(p):
        idx = p["sample_idx"]
        return {"x": dev["x"][idx], "y": dev["y"][idx]}

    sim_fn = engine_mod.make_sim_scan(
        mlp_loss, server.params, lr=sim.lr, acfg=acfg, eta=server.eta,
        make_batches=gather_batches, plan_fn=plan_fn)
    ef = acfg.strat.needs_residuals
    residuals0 = (jnp.zeros((n_draw, n_params), jnp.float32) if ef
                  else jnp.zeros((0,), jnp.float32))
    # eval bookkeeping is host-known even under traced sampling: the scanned
    # program snapshots eval rounds into the O(E x n) carried buffer
    eval_write, eval_slot = _eval_plan(sim, range(sim.rounds))
    evals0 = jnp.zeros((max(int(eval_write.sum()), 1), n_params),
                       jnp.float32)
    t0 = time.perf_counter()
    out = sim_fn(server._flat, residuals0, evals0,
                 {"key": jax.random.split(jax.random.fold_in(key, 1),
                                          sim.rounds),
                  "eval_write": jnp.asarray(eval_write),
                  "eval_slot": jnp.asarray(eval_slot)})
    out["flat"].block_until_ready()
    wall = time.perf_counter() - t0

    result = FLSimResult()
    server._flat = out["flat"]
    server.params = server._unravel(server._flat)
    evals_out = out["evals"]
    cohorts = np.asarray(out["ys"]["cohort"])
    arrived = np.asarray(out["ys"]["arrived"])
    xt, yt = jnp.asarray(x_test), jnp.asarray(y_test)
    for rnd in range(sim.rounds):
        # comm time over the clients that actually participated this round
        # (same accounting the host engines do for their cohorts). A round
        # whose whole sampled cohort died contributes nothing — the revived
        # survivor need not be in the cohort — exactly like the host
        # engines' skipped rounds (the in-jit model update is a no-op too).
        sel = cohorts[rnd][arrived[rnd]]
        if sel.size:
            info_r = {"strategy": acfg.strategy}
            if "crs" in info:
                info_r["crs"] = np.asarray(crs_all)[sel]
            server._account_time(info_r, [links[c] for c in sel])
            result.executed_rounds.append(rnd)
        if eval_write[rnd]:
            snap = evals_out[int(eval_slot[rnd])]
            acc = float(mlp_accuracy(server._unravel(snap), xt, yt))
            result.accuracies.append((rnd, acc))
    result.wall_per_round = ([wall / len(result.executed_rounds)]
                             * len(result.executed_rounds)
                             if result.executed_rounds else [])
    result.times = server.times
    result.final_accuracy = (result.accuracies[-1][1]
                             if result.accuracies else 0.0)
    if ef:
        result.final_residuals = np.asarray(out["residuals"])
    return result
