"""FL server: round orchestration around core.aggregation.

Holds the global model (flat vector + unravel), per-client EF residuals,
the time accumulator, and applies  w <- w - eta * agg  per round.

Two execution paths share the same state and host-side BCRS schedule:

  * ``round``        — the legacy eager loop (parity reference): flattens
                       host-side client deltas, compresses/aggregates op by
                       op, updates the flat model on host;
  * ``round_fused``  — ONE jitted program (repro.fed.round_step): local
                       training, compression, EF, OPWA, and the server
                       update run inside a single XLA executable with the
                       flat model / residual buffers donated.

A third engine bypasses the per-round server entirely:
``repro.fed.engine.make_sim_scan`` lowers the whole multi-round simulation
into a single ``lax.scan`` (the simulation harness still threads this
server's flat/residual state and time accumulator through it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg_mod
from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.core.compression import flatten_tree
from repro.core import strategies as strat_mod


@dataclass
class FLServer:
    params: object                      # global model pytree
    acfg: agg_mod.AggregationConfig
    eta: float = 1.0                    # server learning rate on the update
    links: Optional[List[bcrs_mod.ClientLink]] = None
    times: cost_model.TimeAccumulator = field(
        default_factory=cost_model.TimeAccumulator)
    _residuals: Optional[jax.Array] = None

    def __post_init__(self):
        flat, self._unravel = flatten_tree(self.params)
        self._flat = flat.astype(jnp.float32)
        self.n_params = int(flat.shape[0])
        self.v_bytes = float(self.n_params * 4)   # fp32 update bytes
        self._fused_step = None
        self._fused_step_overlap = None

    # ------------------------------------------------------------------
    def _selected_links(self, selected):
        return ([self.links[i] for i in selected]
                if self.links is not None else None)

    def _account_time(self, info: dict, links) -> None:
        """Paper §5.2 metrics, shared by both round paths. The strategy's
        declared wire format prices the uploads: dense formats take the
        no-index-overhead ``uncompressed_round``; sparse formats map their
        schedule CRs through ``wire.cr_eff`` (identity for the reference
        idx32+f32 pair, honestly smaller for packed formats like qtopk)."""
        if links is None:
            return
        wire = strat_mod.get(self.acfg.strategy).wire
        if wire.dense:
            rt = cost_model.uncompressed_round(links, self.v_bytes)
        else:
            crs = info.get("crs", np.ones(len(links)))
            rt = cost_model.round_times(links, self.v_bytes,
                                        wire.cr_eff(crs, self.n_params))
        self.times.add(rt)
        info["round_time"] = rt

    # ------------------------------------------------------------------
    def round(self, client_deltas: List, data_fracs: np.ndarray,
              selected: np.ndarray) -> dict:
        """Aggregate one round (legacy eager engine: per-client static-CR
        compression loop — the seed behavior, kept as the fused round's
        parity/benchmark reference). client_deltas: list of pytrees
        (w_t - w_i); ``selected``: client indices (for link lookup)."""
        flat_updates = jnp.stack([flatten_tree(d)[0].astype(jnp.float32)
                                  for d in client_deltas])
        links = self._selected_links(selected)
        if self.acfg.strat.needs_residuals:
            if (self._residuals is None
                    or self._residuals.shape[0] != flat_updates.shape[0]):
                self._residuals = jnp.zeros_like(flat_updates)
            agg, info, new_res = agg_mod.aggregate(
                flat_updates, data_fracs, self.acfg, links=links,
                v_bytes=self.v_bytes, residuals=self._residuals,
                use_loop=True)
            self._residuals = new_res
        else:
            agg, info, _ = agg_mod.aggregate(
                flat_updates, data_fracs, self.acfg, links=links,
                v_bytes=self.v_bytes, use_loop=True)
        self._flat = self._flat - self.eta * agg
        self.params = self._unravel(self._flat)
        self._account_time(info, links)
        return info

    # ------------------------------------------------------------------
    def init_fused(self, loss_fn: Callable, lr: float,
                   collect_overlap: bool = False) -> None:
        """Compile-once setup for ``round_fused``: builds the fused round
        program (plus the Fig. 4 overlap-instrumented variant on demand)."""
        from repro.fed import round_step as rs
        self._fused_step = rs.make_round_step(
            loss_fn, self.params, lr=lr, acfg=self.acfg, eta=self.eta)
        if collect_overlap:
            self._fused_step_overlap = rs.make_round_step(
                loss_fn, self.params, lr=lr, acfg=self.acfg, eta=self.eta,
                with_overlap=True)

    def round_fused(self, batches, step_mask, data_fracs: np.ndarray,
                    selected: np.ndarray, want_overlap: bool = False) -> dict:
        """One fused round: batches is a pytree of [C, S, ...] stacked client
        batches, step_mask [C, S] marks real (non-padded) local steps."""
        if self._fused_step is None:
            raise RuntimeError("call init_fused(loss_fn, lr) first")
        k = int(jax.tree.leaves(batches)[0].shape[0])
        links = self._selected_links(selected)
        crs, weights, info = agg_mod.round_schedule(
            self.acfg, k, data_fracs, links, self.v_bytes)
        ks = jnp.asarray(agg_mod.ks_for_schedule(self.n_params, crs,
                                                 self.acfg))
        if want_overlap:
            if self._fused_step_overlap is None:
                raise RuntimeError(
                    "round_fused(want_overlap=True) needs "
                    "init_fused(..., collect_overlap=True)")
            ks_overlap = jnp.asarray(
                agg_mod.overlap_ks(self.acfg, info, k, self.n_params))
        else:
            ks_overlap = ks    # ignored by the non-instrumented step

        residuals = None
        if self.acfg.strat.needs_residuals:
            if (self._residuals is None
                    or self._residuals.shape[0] != k):
                self._residuals = jnp.zeros((k, self.n_params), jnp.float32)
            residuals = self._residuals

        step = self._fused_step_overlap if want_overlap else self._fused_step
        out = step(self._flat, residuals, batches, step_mask,
                   jnp.asarray(weights, jnp.float32), ks, ks_overlap)
        self._flat = out["flat"]
        if self.acfg.strat.needs_residuals:
            self._residuals = out["residuals"]
        self.params = self._unravel(self._flat)
        info["loss"] = out["loss"]
        if "overlap_counts" in out:
            info["overlap_counts"] = out["overlap_counts"]
        self._account_time(info, links)
        return info
