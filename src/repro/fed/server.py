"""FL server: round orchestration around core.aggregation.

Holds the global model (flat vector + unravel), per-client EF residuals,
the time accumulator, and applies  w <- w - eta * agg  per round.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg_mod
from repro.core import bcrs as bcrs_mod
from repro.core import cost_model
from repro.core.compression import flatten_tree


@dataclass
class FLServer:
    params: object                      # global model pytree
    acfg: agg_mod.AggregationConfig
    eta: float = 1.0                    # server learning rate on the update
    links: Optional[List[bcrs_mod.ClientLink]] = None
    times: cost_model.TimeAccumulator = field(
        default_factory=cost_model.TimeAccumulator)
    _residuals: Optional[jax.Array] = None

    def __post_init__(self):
        flat, self._unravel = flatten_tree(self.params)
        self._flat = flat.astype(jnp.float32)
        self.n_params = int(flat.shape[0])
        self.v_bytes = float(self.n_params * 4)   # fp32 update bytes

    # ------------------------------------------------------------------
    def round(self, client_deltas: List, data_fracs: np.ndarray,
              selected: np.ndarray) -> dict:
        """Aggregate one round. client_deltas: list of pytrees (w_t - w_i).
        ``selected``: client indices (for link lookup). Returns info dict."""
        flat_updates = jnp.stack([flatten_tree(d)[0].astype(jnp.float32)
                                  for d in client_deltas])
        links = ([self.links[i] for i in selected]
                 if self.links is not None else None)
        if self.acfg.strategy == "eftopk":
            if (self._residuals is None
                    or self._residuals.shape[0] != flat_updates.shape[0]):
                self._residuals = jnp.zeros_like(flat_updates)
            agg, info, new_res = agg_mod.aggregate(
                flat_updates, data_fracs, self.acfg, links=links,
                v_bytes=self.v_bytes, residuals=self._residuals)
            self._residuals = new_res
        else:
            agg, info, _ = agg_mod.aggregate(
                flat_updates, data_fracs, self.acfg, links=links,
                v_bytes=self.v_bytes)
        self._flat = self._flat - self.eta * agg
        self.params = self._unravel(self._flat)

        # --- time accounting (paper §5.2 metrics)
        if links is not None:
            if "crs" in info:
                crs = info["crs"]
            else:
                crs = np.ones(len(links))
            if self.acfg.strategy == "fedavg":
                rt = cost_model.uncompressed_round(links, self.v_bytes)
            else:
                rt = cost_model.round_times(links, self.v_bytes, crs)
            self.times.add(rt)
            info["round_time"] = rt
        return info
