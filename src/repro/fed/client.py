"""FL client: E local epochs of SGD, update = w_t - w_local (paper Alg. 1
LocalTraining). Model-agnostic: works with any (init, loss_fn) pair.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.models import flags


def make_local_trainer(loss_fn: Callable, lr: float):
    """Returns jittable ``local_train(params, batches) -> (delta, last_loss)``
    where batches is a pytree with leading [n_steps, ...] axes consumed by
    ``lax.scan`` (E epochs pre-flattened into n_steps)."""

    grad_fn = jax.grad(lambda p, b: loss_fn(p, b)[0])

    def sgd_step(params, batch):
        grads = grad_fn(params, batch)
        new = jax.tree.map(lambda p, g: (p - lr * g.astype(p.dtype)).astype(p.dtype),
                           params, grads)
        loss = loss_fn(new, batch)[0]
        return new, loss

    def local_train(params, batches) -> Tuple[Any, jax.Array]:
        n_steps = jax.tree.leaves(batches)[0].shape[0]
        final, losses = jax.lax.scan(sgd_step, params, batches,
                                     unroll=flags.scan_unroll(n_steps))
        delta = jax.tree.map(lambda a, b: (a - b).astype(a.dtype), params, final)
        return delta, losses[-1]

    return local_train
