"""Failure injection + elastic cohort management for FL / multi-pod training.

Node (client) failures during a round surface as missing updates; the server
aggregates the survivors with renormalized coefficients (see straggler.py).
Whole-job failures recover from the atomic checkpoint (checkpoint/) — the
training drivers resume from ``latest_step`` automatically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


def survivors_traced(key, n_clients: int, p_fail: float):
    """Traced twin of ``FailureInjector.survivors`` for the fully in-jit
    sampling path of the scanned simulation (``engine="scan"`` keeps the
    host injector as the seeded parity reference; this one draws from a
    threaded PRNG key instead). iid per-round survival draws; if the whole
    cohort would die, one uniformly-chosen client is revived — the same
    never-lose-everyone guarantee the host injector makes."""
    import jax
    import jax.numpy as jnp
    k_draw, k_revive = jax.random.split(key)
    alive = jax.random.uniform(k_draw, (n_clients,)) >= p_fail
    revived = jnp.zeros((n_clients,), bool).at[
        jax.random.randint(k_revive, (), 0, n_clients)].set(True)
    return alive | (~alive.any() & revived)


_U64 = (1 << 64) - 1


def counter_uniform(seed: int, round_idx: int, ids: np.ndarray) -> np.ndarray:
    """Vectorized counter-based uniform draw on [0, 1) keyed on
    ``(seed, round, id)`` — the population-scale survivor stream.

    PINNED CONVENTION (v1 — changing any constant below changes every
    sparse-failure trajectory): the key is
    ``id * PHI ^ rot(round * M1) ^ rot(seed * M2)`` in u64, run through the
    splitmix64 finalizer, top 53 bits scaled by 2^-53. Pure u64 numpy
    arithmetic — O(C) with no per-client Python, unlike one
    ``np.random.default_rng((seed, round, id))`` per id."""
    phi = np.uint64(0x9E3779B97F4A7C15)
    m1, m2 = np.uint64(0xBF58476D1CE4E5B9), np.uint64(0x94D049BB133111EB)
    x = np.asarray(ids, dtype=np.uint64) * phi
    x ^= np.uint64((round_idx * 0xBF58476D1CE4E5B9) & _U64)
    x ^= np.uint64((seed * 0x94D049BB133111EB) & _U64)
    # splitmix64 finalizer (Steele et al.) — full-avalanche mix
    x ^= x >> np.uint64(30)
    x *= m1
    x ^= x >> np.uint64(27)
    x *= m2
    x ^= x >> np.uint64(31)
    return (x >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/sims: client i fails in round
    r with probability p (per-round, iid), or at explicit (round, client)."""
    p_fail: float = 0.0
    scheduled: Optional[Sequence] = None   # [(round, client), ...]
    seed: int = 0

    def survivors(self, round_idx: int, n_clients: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100_003 + round_idx)
        alive = rng.random(n_clients) >= self.p_fail
        if self.scheduled:
            for r, c in self.scheduled:
                if r == round_idx and c < n_clients:
                    alive[c] = False
        if not alive.any():      # never lose the whole cohort
            alive[int(rng.integers(n_clients))] = True
        return alive

    def survivors_at(self, round_idx: int, ids: np.ndarray) -> np.ndarray:
        """Population-scale survivor draw: per-client Bernoulli keyed on
        (seed, round, client id), computed ONLY for the sampled cohort —
        O(C) regardless of the registered population (``survivors`` draws
        the full ``[P]`` vector, a per-round O(P) bill that defeats
        streaming cohorts at P = 10^6). Its OWN deterministic stream, not
        bit-parity with ``survivors`` — drivers pick one convention and
        keep it (the simulation engines keep the dense draw so their seeded
        trajectories stay comparable). The stream is the pinned
        counter-based hash (:func:`counter_uniform`, splitmix64 v1) —
        vectorized u64 numpy, no per-client ``default_rng`` construction.
        The never-lose-everyone revive is applied over the cohort: if every
        sampled client dies, the first one is revived."""
        ids = np.asarray(ids)
        alive = counter_uniform(self.seed, round_idx, ids) >= self.p_fail
        if self.scheduled:
            for r, c in self.scheduled:
                if r == round_idx:
                    alive[ids == c] = False
        if not alive.any():
            alive[0] = True
        return alive


@dataclass
class ElasticPool:
    """Client pool that can grow/shrink between rounds (elastic scaling).
    Selection always samples from the currently-registered set."""
    n_registered: int

    def scale(self, delta: int) -> None:
        self.n_registered = max(1, self.n_registered + delta)

    def sample(self, frac: float, rng: np.random.Generator) -> np.ndarray:
        n_sel = max(1, int(round(self.n_registered * frac)))
        return rng.choice(self.n_registered, size=n_sel, replace=False)
