"""Failure injection + elastic cohort management for FL / multi-pod training.

Node (client) failures during a round surface as missing updates; the server
aggregates the survivors with renormalized coefficients (see straggler.py).
Whole-job failures recover from the atomic checkpoint (checkpoint/) — the
training drivers resume from ``latest_step`` automatically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


def survivors_traced(key, n_clients: int, p_fail: float):
    """Traced twin of ``FailureInjector.survivors`` for the fully in-jit
    sampling path of the scanned simulation (``engine="scan"`` keeps the
    host injector as the seeded parity reference; this one draws from a
    threaded PRNG key instead). iid per-round survival draws; if the whole
    cohort would die, one uniformly-chosen client is revived — the same
    never-lose-everyone guarantee the host injector makes."""
    import jax
    import jax.numpy as jnp
    k_draw, k_revive = jax.random.split(key)
    alive = jax.random.uniform(k_draw, (n_clients,)) >= p_fail
    revived = jnp.zeros((n_clients,), bool).at[
        jax.random.randint(k_revive, (), 0, n_clients)].set(True)
    return alive | (~alive.any() & revived)


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/sims: client i fails in round
    r with probability p (per-round, iid), or at explicit (round, client)."""
    p_fail: float = 0.0
    scheduled: Optional[Sequence] = None   # [(round, client), ...]
    seed: int = 0

    def survivors(self, round_idx: int, n_clients: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100_003 + round_idx)
        alive = rng.random(n_clients) >= self.p_fail
        if self.scheduled:
            for r, c in self.scheduled:
                if r == round_idx and c < n_clients:
                    alive[c] = False
        if not alive.any():      # never lose the whole cohort
            alive[int(rng.integers(n_clients))] = True
        return alive

    def survivors_at(self, round_idx: int, ids: np.ndarray) -> np.ndarray:
        """Population-scale survivor draw: per-client Bernoulli keyed on
        (seed, round, client id), computed ONLY for the sampled cohort —
        O(C) regardless of the registered population (``survivors`` draws
        the full ``[P]`` vector, a per-round O(P) bill that defeats
        streaming cohorts at P = 10^6). Its OWN deterministic stream, not
        bit-parity with ``survivors`` — drivers pick one convention and
        keep it (the simulation engines keep the dense draw so their seeded
        trajectories stay comparable). The never-lose-everyone revive is
        applied over the cohort: if every sampled client dies, the first
        one is revived."""
        ids = np.asarray(ids)
        u = np.array([np.random.default_rng(
            (self.seed, round_idx, int(c))).random() for c in ids])
        alive = u >= self.p_fail
        if self.scheduled:
            for r, c in self.scheduled:
                if r == round_idx:
                    alive[ids == c] = False
        if not alive.any():
            alive[0] = True
        return alive


@dataclass
class ElasticPool:
    """Client pool that can grow/shrink between rounds (elastic scaling).
    Selection always samples from the currently-registered set."""
    n_registered: int

    def scale(self, delta: int) -> None:
        self.n_registered = max(1, self.n_registered + delta)

    def sample(self, frac: float, rng: np.random.Generator) -> np.ndarray:
        n_sel = max(1, int(round(self.n_registered * frac)))
        return rng.choice(self.n_registered, size=n_sel, replace=False)
