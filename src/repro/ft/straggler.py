"""Straggler mitigation for FL rounds.

BCRS already equalizes *communication* time; compute stragglers are handled
by over-selection + deadline: select (1+rho)·C·N clients, aggregate the first
C·N arrivals, renormalize coefficients over the arrived set. Late updates are
dropped (FedAvg-compatible, no staleness correction needed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class StragglerPolicy:
    over_selection: float = 0.25     # rho
    deadline_factor: float = 1.5     # x median round time -> hard deadline


def over_select(n_target: int, policy: StragglerPolicy) -> int:
    return int(np.ceil(n_target * (1.0 + policy.over_selection)))


def arrivals(times: Sequence[float], n_target: int,
             policy: StragglerPolicy) -> Tuple[np.ndarray, float]:
    """Given per-client round completion times, pick the aggregation set:
    first ``n_target`` arrivals, capped by the deadline
    (``deadline_factor`` x the median completion time). A client past the
    deadline is excluded even when fewer than ``n_target`` have arrived —
    except the very fastest one, which is always taken so the round can
    never go empty. Returns (bool mask over clients, effective round
    duration)."""
    t = np.asarray(times)
    order = np.argsort(t, kind="stable")
    deadline = policy.deadline_factor * float(np.median(t))
    chosen = np.zeros(len(t), bool)
    took = 0
    for i in order:
        if took >= n_target:
            break
        if took > 0 and t[i] > deadline:
            break          # deadline cut; the took>0 guard keeps >= 1 client
        chosen[i] = True
        took += 1
    dur = float(t[chosen].max()) if chosen.any() else 0.0
    return chosen, dur


def arrival_mask_traced(times, n_target: int,
                        policy: StragglerPolicy | None = None):
    """Traced twin of ``arrivals`` (in-jit straggler deadline for the
    scanned simulation): pick the ``n_target`` fastest finishers, capped —
    when a ``policy`` is given — by the same deadline as the host path
    (``deadline_factor`` x median over the *finite* completion times, with
    the same never-empty guard on the fastest finisher). Clients whose
    completion time is +inf (already failed) never arrive. Returns a bool
    mask over the cohort axis."""
    import jax.numpy as jnp
    t = jnp.asarray(times, jnp.float32)
    order = jnp.argsort(t, stable=True)
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(t.shape[0]))
    mask = (rank < n_target) & jnp.isfinite(t)
    if policy is not None:
        deadline = policy.deadline_factor * jnp.nanmedian(
            jnp.where(jnp.isfinite(t), t, jnp.nan))
        mask &= (t <= deadline) | (rank == 0)
    return mask


def renormalize_coefficients_traced(coeffs, arrived):
    """Traced twin of ``renormalize_coefficients`` (jit-safe: jnp.where in
    place of the host branch)."""
    import jax.numpy as jnp
    out = jnp.where(arrived, coeffs.astype(jnp.float32), 0.0)
    s_all, s_in = jnp.sum(coeffs.astype(jnp.float32)), jnp.sum(out)
    return out * jnp.where(s_in > 0, s_all / jnp.maximum(s_in, 1e-12), 1.0)


def renormalize_coefficients(coeffs: np.ndarray, arrived: np.ndarray
                             ) -> np.ndarray:
    """Keep arrived clients' relative weights; zero the rest; rescale so the
    total server step magnitude is preserved (elastic cohort resize)."""
    out = np.where(arrived, coeffs, 0.0)
    s_all, s_in = coeffs.sum(), out.sum()
    if s_in > 0:
        out *= s_all / s_in
    return out
