"""Straggler mitigation for FL rounds.

BCRS already equalizes *communication* time; compute stragglers are handled
by over-selection + deadline: select (1+rho)·C·N clients, aggregate the first
C·N arrivals, renormalize coefficients over the arrived set. Late updates are
dropped (FedAvg-compatible, no staleness correction needed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class StragglerPolicy:
    over_selection: float = 0.25     # rho
    deadline_factor: float = 1.5     # x median round time -> hard deadline


def over_select(n_target: int, policy: StragglerPolicy) -> int:
    return int(np.ceil(n_target * (1.0 + policy.over_selection)))


def arrivals(times: Sequence[float], n_target: int,
             policy: StragglerPolicy) -> Tuple[np.ndarray, float]:
    """Given per-client round completion times, pick the aggregation set:
    first ``n_target`` arrivals, capped by the deadline. Returns
    (bool mask over clients, effective round duration)."""
    t = np.asarray(times)
    order = np.argsort(t)
    deadline = policy.deadline_factor * float(np.median(t))
    chosen = np.zeros(len(t), bool)
    took = 0
    for i in order:
        if took >= n_target and t[i] > deadline:
            break
        chosen[i] = True
        took += 1
        if took >= n_target:
            break
    dur = float(t[chosen].max()) if chosen.any() else 0.0
    return chosen, dur


def renormalize_coefficients(coeffs: np.ndarray, arrived: np.ndarray
                             ) -> np.ndarray:
    """Keep arrived clients' relative weights; zero the rest; rescale so the
    total server step magnitude is preserved (elastic cohort resize)."""
    out = np.where(arrived, coeffs, 0.0)
    s_all, s_in = coeffs.sum(), out.sum()
    if s_in > 0:
        out *= s_all / s_in
    return out
