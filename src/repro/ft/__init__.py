from repro.ft.failures import ElasticPool, FailureInjector
from repro.ft.straggler import (StragglerPolicy, arrivals, over_select,
                                renormalize_coefficients)

__all__ = ["FailureInjector", "ElasticPool", "StragglerPolicy", "arrivals",
           "over_select", "renormalize_coefficients"]
