from repro.ft.arrivals import ArrivalProcess, UploadEvent, failure_fracs
from repro.ft.failures import ElasticPool, FailureInjector
from repro.ft.straggler import (StragglerPolicy, arrivals, over_select,
                                renormalize_coefficients)

__all__ = ["FailureInjector", "ElasticPool", "StragglerPolicy", "arrivals",
           "over_select", "renormalize_coefficients", "ArrivalProcess",
           "UploadEvent", "failure_fracs"]
