"""Event-driven arrival process for the async buffered-aggregation engine.

Each dispatched upload resolves through the retry-aware cost model
(``core.cost_model.upload_time_with_retries``): it can fail mid-transfer
(resume-from-offset retry after exponential backoff), run out of attempts,
or hit its wall-clock deadline — all decided by a counter-based failure
draw keyed on ``(seed, tag, dispatch_counter)``, so the entire event stream
is a pure function of the seed and the dispatch order. That makes it
checkpointable: persisting the in-flight records plus the dispatch counter
reproduces the exact same future, which is what the crash-safe async engine
relies on for bit-exact restarts.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bcrs import ClientLink
from repro.core.cost_model import (RetryPolicy, UploadOutcome,
                                   upload_time_with_retries)

# rng-stream tags for counter-based draws; pinned — changing them changes
# every seeded async trajectory
FAILURE_TAG = 7_919     # per-dispatch failure/fraction draws
BATCH_TAG = 15_73       # per-dispatch local-batch index draws (engine side)


@dataclass(frozen=True)
class UploadEvent:
    """One in-flight upload, fully resolved at dispatch time. ``uid`` is the
    dispatch counter value — the key for both rng streams and the engine's
    in-flight update store."""
    uid: int
    client: int
    version: int              # server version the client trained against
    t_dispatch: float
    t_resolve: float          # absolute time the upload lands or dies
    arrived: bool
    attempts: int
    progress: float
    timed_out: bool


def failure_fracs(seed: int, uid: int, p_fail: float,
                  max_attempts: int) -> List[float]:
    """Counter-based failure draw for one dispatch: per attempt, one uniform
    decides failure (``u < p_fail``) and a second gives the fraction of the
    remaining payload delivered before the cut. Stops at the first clean
    attempt. Deterministic in ``(seed, uid)`` alone."""
    rng = np.random.default_rng((seed, FAILURE_TAG, uid))
    fracs: List[float] = []
    for _ in range(max_attempts):
        u, frac = rng.random(), rng.random()
        if u >= p_fail:
            break
        fracs.append(frac)
    return fracs


@dataclass
class ArrivalProcess:
    """Priority queue of in-flight uploads with deterministic resolution.

    ``dispatch`` draws the upload's whole timeline immediately (failures,
    retries, timeout) and pushes it on the heap; ``pop`` returns events in
    virtual-time order. State is (pending events, dispatch counter) — both
    round-trip through ``state()`` / ``load_state()`` as plain arrays for
    the checkpointer."""
    seed: int
    p_fail: float = 0.0
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    _heap: List[Tuple[float, int, UploadEvent]] = field(default_factory=list)
    counter: int = 0

    def dispatch(self, client: int, version: int, now: float,
                 link: ClientLink, v_bytes: float, cr: float) -> UploadEvent:
        uid = self.counter
        self.counter += 1
        fracs = failure_fracs(self.seed, uid, self.p_fail,
                              self.retry.max_attempts)
        out: UploadOutcome = upload_time_with_retries(link, v_bytes, cr,
                                                      fracs, self.retry)
        ev = UploadEvent(uid=uid, client=client, version=version,
                         t_dispatch=now, t_resolve=now + out.t_resolve,
                         arrived=out.arrived, attempts=out.attempts,
                         progress=out.progress, timed_out=out.timed_out)
        heapq.heappush(self._heap, (ev.t_resolve, uid, ev))
        return ev

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop(self) -> UploadEvent:
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:
        return len(self._heap)

    def in_flight(self) -> List[UploadEvent]:
        """Pending events in heap order (deterministic: keyed by (t, uid))."""
        return [ev for _, _, ev in sorted(self._heap)]

    def busy_clients(self) -> set:
        """Clients with an upload in flight — the engine's busy-set rebuild
        on restore (a client is busy from dispatch until abort or flush)."""
        return {ev.client for _, _, ev in self._heap}

    # ---------------------------------------------------------- checkpointing
    _STATE_COLS = ("uid", "client", "version", "t_dispatch", "t_resolve",
                   "arrived", "attempts", "progress", "timed_out")

    def state(self) -> Dict[str, np.ndarray]:
        """Arrays of the pending events (sorted by (t_resolve, uid)) plus the
        dispatch counter — everything needed to reproduce the future."""
        evs = self.in_flight()
        s: Dict[str, np.ndarray] = {
            "uid": np.array([e.uid for e in evs], np.int64),
            "client": np.array([e.client for e in evs], np.int64),
            "version": np.array([e.version for e in evs], np.int64),
            "t_dispatch": np.array([e.t_dispatch for e in evs], np.float64),
            "t_resolve": np.array([e.t_resolve for e in evs], np.float64),
            "arrived": np.array([e.arrived for e in evs], bool),
            "attempts": np.array([e.attempts for e in evs], np.int64),
            "progress": np.array([e.progress for e in evs], np.float64),
            "timed_out": np.array([e.timed_out for e in evs], bool),
            "counter": np.array([self.counter], np.int64),
        }
        return s

    def load_state(self, s: Dict[str, np.ndarray]) -> None:
        self.counter = int(np.asarray(s["counter"])[0])
        self._heap = []
        n = len(np.asarray(s["uid"]))
        for i in range(n):
            ev = UploadEvent(
                uid=int(s["uid"][i]), client=int(s["client"][i]),
                version=int(s["version"][i]),
                t_dispatch=float(s["t_dispatch"][i]),
                t_resolve=float(s["t_resolve"][i]),
                arrived=bool(s["arrived"][i]),
                attempts=int(s["attempts"][i]),
                progress=float(s["progress"][i]),
                timed_out=bool(s["timed_out"][i]))
            heapq.heappush(self._heap, (ev.t_resolve, ev.uid, ev))
