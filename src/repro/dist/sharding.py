"""Logical-axis sharding rules for the production meshes.

Model code names axes *logically* (``"batch"``, ``"seq"``, ``"embed"``,
``"vocab"``, ``"heads"``, ``"experts"``, ``"act_d"``) and calls
``constrain(x, spec)``; a process-global :class:`Rules` object (installed by
``launch/specs.py`` via ``set_rules``) lowers those names to mesh axes and
``lax.with_sharding_constraint``. When no rules are installed — every smoke
test, every single-device run — ``constrain`` is an identity no-op, so the
same model code runs unsharded without a mesh in scope.

Layout policy (matching docs/DESIGN.md / the dry-run evidence):
  - ``batch``   -> all batch mesh axes present (``("pod", "data")`` on the
                   multi-pod mesh, ``("data",)`` on one pod)
  - ``vocab`` / ``heads`` / ``experts`` -> the ``model`` axis (TP/EP)
  - ``act_d``   -> ``model`` only for FSDP archs (sequence-parallel-style
                   activation sharding of the layer-scan carry)
  - ``seq`` / ``embed`` -> replicated (activations are batch-sharded)

``param_specs`` derives a ZeRO/FSDP+TP PartitionSpec tree generically: the
largest mesh-divisible dim of each weight goes to ``model``; FSDP archs
(``cfg.n_params() >= cfg.fsdp_threshold``) additionally shard one remaining
dim over the batch axes. Stacked-layer leading dims (n_layers is rarely
divisible by 16) and small glue params (norms, gates) stay replicated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# A logical spec entry: a logical axis name, or None for "replicated dim".
LogicalSpec = Sequence[Optional[str]]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Resolved logical-axis -> mesh-axis mapping for one (cfg, shape, mesh)."""
    mesh: Any
    axes: Dict[str, Any]            # logical name -> mesh axis | tuple | None
    batch_axes: Tuple[str, ...]     # mesh axes the batch dim shards over
    shard_batch: bool = True
    fsdp: bool = False

    def logical(self, spec: LogicalSpec) -> P:
        """Lower a tuple of logical names (None = replicated) to a
        PartitionSpec. Unknown names resolve to replicated, so model code may
        annotate axes the current mesh does not distribute."""
        return P(*(self.axes.get(name) if name is not None else None
                   for name in spec))

    def batch_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= dict(self.mesh.shape)[a]
        return n


# ------------------------------------------------------------- global registry
_RULES: Optional[Rules] = None


def get_rules() -> Optional[Rules]:
    return _RULES


def set_rules(rules: Optional[Rules]) -> Optional[Rules]:
    """Install (or clear, with None) the process-global rules."""
    global _RULES
    _RULES = rules
    return rules


class use_rules:
    """Context manager form of set_rules for tests: restores on exit."""

    def __init__(self, rules: Optional[Rules]):
        self.rules = rules
        self._saved: Optional[Rules] = None

    def __enter__(self) -> Optional[Rules]:
        self._saved = get_rules()
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *exc):
        set_rules(self._saved)
        return False


# ------------------------------------------------------------------ resolution
def make_rules(cfg, shape, mesh) -> Rules:
    """Map logical axes to mesh axes for one arch family × input shape.

    The batch mapping drops mesh axes (pod first) until the global batch is
    divisible by the product of the remaining ones, so odd shapes degrade to
    fewer-way data parallelism instead of failing to lower.
    """
    names = set(mesh.axis_names)
    msh = dict(mesh.shape)
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    gb = getattr(shape, "global_batch", None)
    while batch_axes and gb is not None and gb % int(
            np.prod([msh[a] for a in batch_axes])):
        batch_axes = batch_axes[1:]
    model = "model" if "model" in names else None
    fsdp = cfg.n_params() >= cfg.fsdp_threshold
    axes = {
        "batch": batch_axes if batch_axes else None,
        "seq": None,
        "embed": None,
        "vocab": model,
        "heads": model,
        "experts": model,
        "ff": model,
        "act_d": model if fsdp else None,
    }
    return Rules(mesh=mesh, axes=axes, batch_axes=batch_axes,
                 shard_batch=bool(batch_axes), fsdp=fsdp)


# ------------------------------------------------------------------- constrain
def constrain(x: jax.Array, spec: LogicalSpec) -> jax.Array:
    """``lax.with_sharding_constraint`` under the installed rules; identity
    when rules are unset (single-device tests) or the rank mismatches the
    annotation (callers annotate the common layout; variant ranks pass
    through)."""
    rules = get_rules()
    if rules is None:
        return x
    if len(spec) != np.ndim(x):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.logical(spec)))


# ----------------------------------------------------------------- spec trees
def _axis_sizes(mesh, axis) -> int:
    msh = dict(mesh.shape)
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([msh[a] for a in axis]))
    return msh[axis]


def _leaf_param_spec(shape: Tuple[int, ...], size: int, mesh, model_axis,
                     dp_axes, fsdp: bool, min_size: int) -> P:
    """TP the largest model-divisible dim; FSDP one remaining dim."""
    if not shape or size < min_size:
        return P()
    spec: list = [None] * len(shape)
    if model_axis is not None:
        m = _axis_sizes(mesh, model_axis)
        best = -1
        for i in range(len(shape) - 1, -1, -1):  # prefer trailing dims on ties
            if shape[i] % m == 0 and shape[i] >= m and (
                    best < 0 or shape[i] > shape[best]):
                best = i
        if best >= 0:
            spec[best] = model_axis
    if fsdp and dp_axes:
        d = _axis_sizes(mesh, dp_axes)
        for i, s in enumerate(shape):
            if spec[i] is None and s % d == 0 and s >= d:
                spec[i] = dp_axes
                break
    return P(*spec)


def param_specs(cfg, params_abs):
    """PartitionSpec pytree for a parameter tree (abstract or concrete).

    Requires installed rules (the mesh decides divisibility); without rules
    every leaf is replicated — callers running single-device get a
    trivially-correct layout.
    """
    rules = get_rules()
    if rules is None:
        return jax.tree.map(lambda _: P(), params_abs)
    model_axis = rules.axes.get("vocab")  # the TP axis (None if mesh lacks it)
    dp_axes = rules.batch_axes if rules.batch_axes else None
    return jax.tree.map(
        lambda l: _leaf_param_spec(tuple(l.shape), int(np.prod(l.shape)),
                                   rules.mesh, model_axis, dp_axes,
                                   rules.fsdp, min_size=2 ** 16),
        params_abs)


def batch_specs(cfg, batch_abs):
    """Batch dict -> specs: dim 0 over the batch axes, rest replicated."""
    rules = get_rules()
    if rules is None:
        return jax.tree.map(lambda _: P(), batch_abs)
    return jax.tree.map(
        lambda l: rules.logical(("batch",) + (None,) * (len(l.shape) - 1)),
        batch_abs)


def cache_specs(cfg, cache_abs):
    """Decode-cache specs: batch dim over the batch axes; KV-heads over
    ``model`` when divisible, else the sequence dim (sequence-sharded cache —
    ``decode_attend`` reduces over S with small per-(B,H) collectives).

    Cache leaves carry a leading layer-stack dim ([L, B, S, Hkv, D]; VLM
    groups add one more: [G, per, B, ...]), which stays replicated.
    """
    rules = get_rules()
    if rules is None:
        return jax.tree.map(lambda _: P(), cache_abs)
    model_axis = rules.axes.get("heads")
    m = _axis_sizes(rules.mesh, model_axis) if model_axis is not None else 1
    b_axes = rules.batch_axes if rules.shard_batch else None
    nb = _axis_sizes(rules.mesh, b_axes) if b_axes else 1

    def spec(l) -> P:
        shape = tuple(l.shape)
        nd = len(shape)
        if nd < 2:
            return P()
        bi = 2 if cfg.family == "vlm" and nd >= 5 else 1
        if bi >= nd:
            return P()
        out: list = [None] * nd
        if b_axes and shape[bi] % nb == 0 and shape[bi] >= nb:
            out[bi] = b_axes
        if model_axis is not None and m > 1:
            # prefer the KV-heads dim; fall back to sequence sharding
            hi = next((i for i in range(nd - 1, bi, -1)
                       if shape[i] == cfg.n_kv_heads and shape[i] % m == 0),
                      None)
            if hi is not None:
                out[hi] = model_axis
            elif bi + 1 < nd and shape[bi + 1] % m == 0 and shape[bi + 1] >= m:
                out[bi + 1] = model_axis
        return P(*out)

    return jax.tree.map(spec, cache_abs)
