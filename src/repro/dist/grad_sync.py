"""Train-step builders: dense DP sync and hierarchical BCRS/OPWA compressed
pod sync (the paper's technique applied to multi-pod data parallelism).

``make_train_step`` is the plain jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` step with optional gradient-accumulation
microbatching and explicit grad shardings (FSDP: grads land on the param
layout instead of whatever the partitioner guesses).

``make_compressed_train_step`` splits the global batch over ``n_pods``
virtual pods, gives every pod its own gradient, and replaces the dense
all-reduce with the paper's compressed exchange: per-pod error-feedback
Top-K at the BCRS-scheduled traced ratios (``pod_crs``, clipped to the
``wire_cr`` budget; ``repro.core.bcrs.pod_link_schedule`` produces them from
heterogeneous DCN links), merged with overlap-weighted averaging
(``repro.core.opwa`` — coords kept by <= ``overlap_d`` pods are amplified by
``gamma``). Compression + EF + merge run through the shared substrate
(``repro.fed.engine.compress_merge_leaf`` -> the one
``topk_compress_dynamic`` bisection), the same pipeline the FL round
engines use. At ``wire_cr=1.0`` every pod keeps everything, overlap saturates,
and the step reproduces ``make_train_step`` exactly (strict generalization —
see tests/test_dist.py).

Error-feedback residuals live in the optimizer-state pytree: init with
``init_compressed_state(opt, params, n_pods=N)`` and the step threads
``{"opt": <inner>, "ef": <[n_pods, ...] residuals>}``. A bare ``opt.init``
state is also accepted (residuals start at zero and are dropped on return,
keeping the in/out structure identical for ahead-of-time lowering in
``launch/specs.py``); only the wrapped form carries EF across steps.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import strategies as strat_mod
from repro.core.compression import k_for_ratio_traced, resolve_use_kernel
from repro.fed.engine import compress_merge_leaf

Metrics = Dict[str, jax.Array]


def _grad_fn(model) -> Callable:
    return jax.value_and_grad(model.loss_fn, has_aux=True)


# ------------------------------------------------------------------ dense step
def make_train_step(model, opt, *, n_micro: int = 1,
                    grad_shardings: Any = None) -> Callable:
    """Dense DP train step. ``n_micro`` > 1 scans fwd+bwd over microbatches
    (bounded activation memory; grads/metrics averaged in f32).
    ``grad_shardings``: optional sharding pytree (matching params) pinned on
    the accumulated grads before the optimizer update."""
    grad_fn = _grad_fn(model)

    def step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            (l_abs, m_abs), _ = jax.eval_shape(grad_fn, params, mb0)

            def body(carry, mb):
                g_acc, l_acc, m_acc = carry
                (l, m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / n_micro,
                    g_acc, g)
                m_acc = jax.tree.map(lambda a, v: a + v / n_micro, m_acc, m)
                return (g_acc, l_acc + l / n_micro, m_acc), None

            init = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params),
                    jnp.zeros(l_abs.shape, jnp.float32),
                    jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                 m_abs))
            (grads, loss, metrics), _ = jax.lax.scan(body, init, micro)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        new_params, new_state = opt.update(grads, opt_state, params)
        out = dict(metrics)
        out["loss"] = loss
        return new_params, new_state, out

    return step


# ------------------------------------------------------ compressed-state init
def _zero_ef(params, n_pods: int):
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + tuple(p.shape), jnp.float32), params)


def init_compressed_state(opt, params, *, n_pods: int):
    """Optimizer state + per-pod f32 error-feedback residuals."""
    return {"opt": opt.init(params), "ef": _zero_ef(params, n_pods)}


def _is_wrapped(opt_state) -> bool:
    return (isinstance(opt_state, dict) and len(opt_state) == 2
            and "opt" in opt_state and "ef" in opt_state)


# ------------------------------------------------------------- compressed step
def make_compressed_train_step(model, opt, *, n_pods: int,
                               wire_cr: float = 0.05, gamma: float = 1.0,
                               min_leaf_size: int = 4096, overlap_d: int = 1,
                               use_kernel="auto",
                               strategy: str = "bcrs_opwa") -> Callable:
    """Returns jittable
    ``step(params, opt_state, batch, pod_crs, pod_coeffs)``.

    pod_crs: f32 [n_pods] traced BCRS compression ratios (one compiled step
    serves any per-round schedule); pod_coeffs: f32 [n_pods] averaging
    coefficients p'_i (1/n_pods reproduces the dense mean). Leaves smaller
    than ``min_leaf_size`` are exchanged dense (their index overhead would
    exceed the savings — same cutoff the byte model uses).

    ``strategy`` names a registered compressing strategy; its capabilities
    pick the merge (``overlap_weighted`` -> OPWA vs plain coefficient sum)
    and the optional ``value_codec`` (e.g. ``qtopk``'s int8 quantizer —
    EF absorbs its quantization error, same contract as the FL engines).
    Pod sync always runs error feedback: residuals are structural in the
    wrapped optimizer state, so ``carry`` here only affects the codec's EF
    interplay, not whether residuals exist.
    """
    if n_pods < 2:
        # with a single pod every kept coordinate has overlap 1 <= overlap_d,
        # so OPWA would silently scale all gradients by gamma (an LR change,
        # not a sync strategy) — use make_train_step instead
        raise ValueError(f"n_pods must be >= 2, got {n_pods}")
    strat = strat_mod.get(strategy)
    if not strat.compresses:
        raise ValueError(
            f"strategy {strategy!r} does not compress; use make_train_step "
            f"for dense sync")
    opwa = strat.overlap_weighted
    value_codec = strat.value_codec
    kernel_codec = strat.kernel_codec
    # codec strategies take the kernel route iff they registered a kernel
    # lowering for their codec (fused_merge's quantize/dequantize stage)
    use_kernel = (resolve_use_kernel(use_kernel)
                  and (value_codec is None or kernel_codec is not None))
    grad_fn = _grad_fn(model)

    def step(params, opt_state, batch, pod_crs, pod_coeffs):
        b = jax.tree.leaves(batch)[0].shape[0]
        if b % n_pods:
            raise ValueError(
                f"global batch {b} not divisible by n_pods={n_pods}")
        wrapped = _is_wrapped(opt_state)
        if wrapped:
            lead = jax.tree.leaves(opt_state["ef"])[0].shape[0]
            if lead != n_pods:
                raise ValueError(
                    f"opt_state carries EF residuals for {lead} pods but the "
                    f"step was built with n_pods={n_pods} (checkpoint / "
                    f"--compressed-pods mismatch)")
        inner = opt_state["opt"] if wrapped else opt_state
        ef = opt_state["ef"] if wrapped else _zero_ef(params, n_pods)

        pod_batch = jax.tree.map(
            lambda x: x.reshape((n_pods, x.shape[0] // n_pods) + x.shape[1:]),
            batch)
        (losses, metrics), grads = jax.vmap(
            grad_fn, in_axes=(None, 0))(params, pod_batch)

        crs = jnp.clip(pod_crs.astype(jnp.float32), 0.0, wire_cr)
        coeffs = pod_coeffs.astype(jnp.float32)

        def sync_leaf(g, e):
            """g: [n_pods, *shape] pod grads; e: matching EF residuals."""
            n = int(np.prod(g.shape[1:]))
            gf = g.reshape(n_pods, n).astype(jnp.float32)
            if n < min_leaf_size:  # dense exchange, no EF
                return (jnp.tensordot(coeffs, gf, axes=(0, 0))
                        .reshape(g.shape[1:]), e)
            ks = k_for_ratio_traced(n, crs)
            agg, new_e = compress_merge_leaf(
                gf, coeffs, ks, gamma=gamma, overlap_d=overlap_d, opwa=opwa,
                use_kernel=use_kernel, residuals=e.reshape(n_pods, n),
                value_codec=value_codec, kernel_codec=kernel_codec)
            return agg.reshape(g.shape[1:]), new_e.reshape(e.shape)

        pairs = jax.tree.map(sync_leaf, grads, ef)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2
        agg_grads = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
        new_ef = jax.tree.map(lambda t: t[1], pairs, is_leaf=is_pair)

        new_params, new_inner = opt.update(agg_grads, inner, params)
        out = jax.tree.map(jnp.mean, dict(metrics))
        out["loss"] = jnp.mean(losses)
        out["wire_cr"] = jnp.mean(crs)
        new_state = ({"opt": new_inner, "ef": new_ef} if wrapped
                     else new_inner)
        return new_params, new_state, out

    return step
