from repro.dist import grad_sync, sharding
from repro.dist.sharding import (Rules, constrain, get_rules, make_rules,
                                 set_rules)

__all__ = ["Rules", "constrain", "get_rules", "grad_sync", "make_rules",
           "set_rules", "sharding"]
