"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_topk import block_topk_pallas
from repro.kernels.ef_update import ef_update_pallas
from repro.kernels.overlap_combine import overlap_combine_pallas

SHAPES_2D = [(8, 128), (8, 1024), (16, 8192), (32, 512), (8, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


class TestBlockTopK:
    @pytest.mark.parametrize("shape", SHAPES_2D)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_ref(self, shape, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
        k = max(1, shape[1] // 10)
        kv, km = block_topk_pallas(x, k, interpret=True)
        rv, rm = ref.block_topk_ref(x, k)
        np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))
        np.testing.assert_allclose(np.asarray(kv, np.float32),
                                   np.asarray(rv, np.float32), rtol=1e-6)

    @pytest.mark.parametrize("k", [1, 7, 128, 1024])
    def test_k_sweep(self, k):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 1024))
        kv, km = block_topk_pallas(x, k, interpret=True)
        assert (np.asarray(km).sum(axis=1) == k).all()

    def test_flat_wrapper_matches_core(self):
        u = jax.random.normal(jax.random.PRNGKey(2), (100_000,))
        from repro.core.compression import block_topk_compress
        a = block_topk_compress(u, 0.1, block=8192, use_kernel=False)
        b = ops.block_topk(u, 0.1, block=8192)
        np.testing.assert_allclose(np.asarray(a.values), np.asarray(b.values),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


class TestOverlapCombine:
    @pytest.mark.parametrize("k_clients", [2, 5, 10, 16])
    @pytest.mark.parametrize("n", [1024, 4096, 10240])
    def test_vs_ref(self, k_clients, n):
        key = jax.random.PRNGKey(k_clients * 1000 + n)
        vals = jax.random.normal(key, (k_clients, n))
        vals = vals * (jax.random.uniform(jax.random.PRNGKey(1), (k_clients, n)) < 0.1)
        masks = (vals != 0)
        coeffs = jax.random.uniform(jax.random.PRNGKey(2), (k_clients,))
        out = overlap_combine_pallas(vals, masks, coeffs, 5.0, 1,
                                     interpret=True)
        r = ref.overlap_combine_ref(vals, masks, coeffs, 5.0, 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-5,
                                   atol=1e-6)

    @pytest.mark.parametrize("gamma,d", [(1.0, 1), (3.0, 2), (10.0, 1)])
    def test_gamma_d_sweep(self, gamma, d):
        vals = jax.random.normal(jax.random.PRNGKey(3), (6, 2048))
        vals = vals * (jnp.abs(vals) > 1.0)
        masks = vals != 0
        coeffs = jnp.full((6,), 1 / 6)
        out = ops.overlap_combine(vals, masks, coeffs, gamma, d)
        r = ref.overlap_combine_ref(vals, masks, coeffs, gamma, d)[0]
        np.testing.assert_allclose(np.asarray(out), np.asarray(r), rtol=1e-5,
                                   atol=1e-6)


class TestEFUpdate:
    @pytest.mark.parametrize("shape", SHAPES_2D)
    def test_vs_ref(self, shape):
        g = jax.random.normal(jax.random.PRNGKey(4), shape)
        e = jax.random.normal(jax.random.PRNGKey(5), shape)
        k = max(1, shape[1] // 20)
        ks, ke = ef_update_pallas(g, e, k, interpret=True)
        rs, re = ref.ef_update_ref(g, e, k)
        np.testing.assert_allclose(np.asarray(ks), np.asarray(rs), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ke), np.asarray(re), rtol=1e-6)

    def test_conservation(self):
        g = jax.random.normal(jax.random.PRNGKey(6), (30_000,))
        e = jax.random.normal(jax.random.PRNGKey(7), (30_000,))
        s, ne = ops.ef_topk_update(g, e, 0.05, block=4096)
        np.testing.assert_allclose(np.asarray(s + ne), np.asarray(g + e),
                                   rtol=1e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("shape", [(2, 3, 128, 128, 64),
                                       (1, 2, 256, 256, 32),
                                       (1, 2, 100, 100, 64),
                                       (1, 1, 128, 384, 64)])
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_vs_ref(self, shape, dtype):
        b, h, sq, sk, d = shape
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, sq, h, d)).astype(dtype)
        k = jax.random.normal(ks[1], (b, sk, h, d)).astype(dtype)
        v = jax.random.normal(ks[2], (b, sk, h, d)).astype(dtype)
        out = ops.flash_attention(q, k, v, causal=True)
        qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
        kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
        vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
        r = ref.flash_attention_ref(qt, kt, vt, True).reshape(
            b, h, sq, d).transpose(0, 2, 1, 3)
        tol = 2e-6 if dtype == jnp.float32 else 2e-3
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(r, np.float32), atol=tol,
                                   rtol=tol)

    def test_matches_model_attend(self):
        """Flash kernel == the model's chunked jnp attention path."""
        from repro.models.attention import attend
        b, s, h, d = 1, 128, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, h, d))
        v = jax.random.normal(ks[2], (b, s, h, d))
        a = attend(q, k, v, causal=True, chunk=64)
        f = ops.flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(f), atol=1e-5,
                                   rtol=1e-5)

    @pytest.mark.parametrize("blocks", [(64, 64), (128, 64), (64, 128)])
    def test_block_shape_invariance(self, blocks):
        bq, bk = blocks
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        a = ops.flash_attention(q, k, v, blk_q=bq, blk_k=bk)
        b_ = ops.flash_attention(q, k, v, blk_q=128, blk_k=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5,
                                   rtol=1e-5)
