"""OPWA tests (paper §4.3, Alg. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core import compression as C
from repro.core import opwa


def _sparse_updates(k_clients, n, cr, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), k_clients)
    vals, masks = [], []
    for kk in keys:
        u = jax.random.normal(kk, (n,))
        c = C.topk_compress(u, cr)
        vals.append(c.values)
        masks.append(c.mask)
    return jnp.stack(vals), jnp.stack(masks)


class TestOverlap:
    def test_counts_range(self):
        vals, masks = _sparse_updates(5, 2000, 0.1)
        counts = opwa.overlap_counts(masks)
        assert counts.min() >= 0 and counts.max() <= 5

    def test_mask_values(self):
        counts = jnp.array([0, 1, 2, 3, 5])
        m = opwa.opwa_mask(counts, gamma=4.0, d=2)
        np.testing.assert_array_equal(np.asarray(m), [1.0, 4.0, 4.0, 1.0, 1.0])

    def test_fig4_pattern_majority_singletons_at_high_compression(self):
        """Paper Fig. 4: at CR=0.01 most retained indices appear in only one
        client's update (random-ish top-k supports barely overlap)."""
        vals, masks = _sparse_updates(5, 50_000, 0.01, seed=2)
        counts = np.asarray(opwa.overlap_counts(masks))
        retained = counts[counts > 0]
        frac_singleton = (retained == 1).mean()
        assert frac_singleton > 0.5

    def test_overlap_grows_with_cr(self):
        """Among RETAINED indices, the singleton fraction falls as CR rises
        (paper Fig. 4: high compression -> mostly overlap-1)."""
        _, m_low = _sparse_updates(5, 20_000, 0.01, seed=3)
        _, m_high = _sparse_updates(5, 20_000, 0.3, seed=3)
        c_low = np.asarray(opwa.overlap_counts(m_low))
        c_high = np.asarray(opwa.overlap_counts(m_high))
        f1 = (c_low[c_low > 0] == 1).mean()
        f2 = (c_high[c_high > 0] == 1).mean()
        assert f2 < f1


class TestHistogram:
    def test_matches_manual_loop(self):
        _, masks = _sparse_updates(5, 3000, 0.1, seed=11)
        hist = np.asarray(opwa.overlap_histogram(masks))
        counts = np.asarray(opwa.overlap_counts(masks))
        manual = np.array([np.sum(counts == c) for c in range(6)])
        np.testing.assert_array_equal(hist, manual)

    def test_sums_to_n(self):
        _, masks = _sparse_updates(4, 2048, 0.2, seed=12)
        hist = np.asarray(opwa.overlap_histogram(masks))
        assert hist.sum() == 2048

    def test_kmax_truncates(self):
        """Degrees above k_max are dropped, not clipped into the last bin."""
        masks = jnp.ones((5, 7), bool)   # every index has overlap 5
        hist = np.asarray(opwa.overlap_histogram(masks, k_max=3))
        np.testing.assert_array_equal(hist, [0, 0, 0, 0])


class TestAggregate:
    def test_equals_manual(self):
        vals, masks = _sparse_updates(4, 1000, 0.1)
        coeffs = jnp.array([0.1, 0.2, 0.3, 0.4])
        agg = opwa.opwa_aggregate(vals, masks, coeffs, gamma=3.0, d=1)
        counts = np.asarray(masks.astype(np.int32)).sum(0)
        man = np.einsum("k,kn->n", np.asarray(coeffs), np.asarray(vals, np.float32))
        man = np.where((counts > 0) & (counts <= 1), 3.0 * man, man)
        np.testing.assert_allclose(np.asarray(agg), man, rtol=1e-5)

    def test_gamma_one_is_bcrs(self):
        vals, masks = _sparse_updates(4, 1000, 0.1, seed=5)
        coeffs = jnp.array([0.25] * 4)
        a = opwa.opwa_aggregate(vals, masks, coeffs, gamma=1.0, d=1)
        b = opwa.bcrs_aggregate(vals, coeffs)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    @given(st.integers(2, 8), st.floats(1.0, 10.0), st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_property_amplifies_only_low_overlap(self, k, gamma, seed):
        vals, masks = _sparse_updates(k, 3000, 0.05, seed=seed)
        coeffs = jnp.ones((k,)) / k
        with_g = np.asarray(opwa.opwa_aggregate(vals, masks, coeffs, gamma, 1))
        no_g = np.asarray(opwa.bcrs_aggregate(vals, coeffs))
        counts = np.asarray(opwa.overlap_counts(masks))
        hi = counts > 1
        np.testing.assert_allclose(with_g[hi], no_g[hi], rtol=1e-5)
        lo = counts == 1
        np.testing.assert_allclose(with_g[lo], gamma * no_g[lo], rtol=1e-4)

    def test_kernel_path_matches(self):
        vals, masks = _sparse_updates(6, 4096, 0.1, seed=7)
        coeffs = jnp.linspace(0.1, 0.2, 6)
        a = opwa.opwa_aggregate(vals, masks, coeffs, 5.0, 1, use_kernel=False)
        b = opwa.opwa_aggregate(vals, masks, coeffs, 5.0, 1, use_kernel=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)
