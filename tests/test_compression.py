"""Unit + property tests for the compression substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core import compression as C

SEED = st.integers(0, 2**31 - 1)


def _vec(key, n):
    return jax.random.normal(jax.random.PRNGKey(key), (n,))


class TestTopK:
    def test_exact_k(self):
        u = _vec(0, 1000)
        c = C.topk_compress(u, 0.1)
        assert int(c.mask.sum()) == 100

    def test_keeps_largest(self):
        u = jnp.asarray(np.random.default_rng(0).permutation(1000.0 + np.arange(1000)))
        c = C.topk_compress(u, 0.05)
        kept = np.sort(np.asarray(u)[np.asarray(c.mask)])
        assert kept.min() >= np.sort(np.asarray(u))[-50]

    def test_values_masked(self):
        u = _vec(1, 512)
        c = C.topk_compress(u, 0.25)
        np.testing.assert_array_equal(np.asarray(c.values == 0),
                                      ~np.asarray(c.mask))

    @given(st.integers(10, 5000), st.floats(0.01, 1.0), SEED)
    @settings(max_examples=25, deadline=None)
    def test_property_retained_count(self, n, cr, seed):
        u = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        c = C.topk_compress(u, cr)
        k = C.k_for_ratio(n, cr)
        assert int(c.mask.sum()) == k  # distinct gaussian values: no ties

    @given(st.integers(100, 3000), st.floats(0.05, 0.9), SEED)
    @settings(max_examples=25, deadline=None)
    def test_property_mass_dominance(self, n, cr, seed):
        """Top-K retains at least cr fraction of the L2 mass (it is the
        best k-sparse approximation)."""
        u = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        c = C.topk_compress(u, cr)
        kept = float(jnp.sum(c.values ** 2))
        total = float(jnp.sum(u ** 2))
        assert kept >= cr * total - 1e-5


class TestDynamicTopK:
    @given(st.integers(16, 4000), st.integers(1, 200), SEED)
    @settings(max_examples=25, deadline=None)
    def test_matches_static(self, n, k, seed):
        k = min(k, n)
        u = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        dyn = C.topk_compress_dynamic(u, jnp.int32(k))
        mag = jnp.abs(u)
        thresh = jax.lax.top_k(mag, k)[0][-1]
        ref_mask = mag >= thresh
        np.testing.assert_array_equal(np.asarray(dyn.mask), np.asarray(ref_mask))


class TestBlockTopK:
    def test_ratio_preserved_per_block(self):
        u = _vec(3, 8192 * 3)
        c = C.block_topk_compress(u, 0.1, block=8192)
        m = np.asarray(c.mask).reshape(3, 8192)
        assert (m.sum(1) == 819).all()

    def test_padding_tail(self):
        u = _vec(4, 10000)
        c = C.block_topk_compress(u, 0.1, block=8192)
        assert c.values.shape == (10000,)
        assert int(c.mask.sum()) >= C.k_for_ratio(10000, 0.1)

    def test_close_to_global_mass(self):
        """Block top-k retains nearly the mass of exact global top-k."""
        u = _vec(5, 65536)
        g = C.topk_compress(u, 0.1)
        b = C.block_topk_compress(u, 0.1, block=4096)
        mass = lambda c: float(jnp.sum(c.values.astype(jnp.float32) ** 2))
        assert mass(b) >= 0.95 * mass(g)


class TestErrorFeedback:
    def test_conservation(self):
        """send + residual' == residual + g (nothing is lost)."""
        g, e = _vec(6, 4096), _vec(7, 4096)
        comp, new_e = C.ef_compress(e, g, 0.1)
        np.testing.assert_allclose(np.asarray(comp.values + new_e),
                                   np.asarray(e + g), rtol=1e-6)

    def test_residual_decays_for_stationary_grad(self):
        """With a repeated gradient, EF eventually transmits everything:
        total sent over T rounds -> T*g."""
        g = _vec(8, 2048)
        e = jnp.zeros_like(g)
        sent = jnp.zeros_like(g)
        for _ in range(50):
            comp, e = C.ef_compress(e, g, 0.05)
            sent = sent + comp.values
        # the residual is bounded, so sent/T -> g
        np.testing.assert_allclose(np.asarray(sent + e), np.asarray(g * 50),
                                   rtol=1e-4)


class TestSparseFormat:
    @given(st.integers(64, 2000), st.floats(0.02, 0.5), SEED)
    @settings(max_examples=20, deadline=None)
    def test_roundtrip(self, n, cr, seed):
        u = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        c = C.topk_compress(u, cr)
        k = C.k_for_ratio(n, cr)
        idx, vals = C.to_sparse(c, k)
        dense = C.from_sparse(idx, vals, n)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(c.values),
                                   rtol=1e-6)

    @given(st.integers(64, 2000), st.integers(1, 500), SEED)
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_dynamic(self, n, k, seed):
        """Wire-format round-trip over the traced-k compressor (the fused
        round's selection path)."""
        k = min(k, n)
        u = jax.random.normal(jax.random.PRNGKey(seed), (n,))
        c = C.topk_compress_dynamic(u, jnp.int32(k))
        idx, vals = C.to_sparse(c, k)
        dense = C.from_sparse(idx, vals, n)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(c.values),
                                   rtol=1e-6)

    @given(st.integers(128, 1500), st.integers(1, 4), SEED)
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_block(self, n, kc, seed):
        """Round-trip through the blockwise compressor (uneven tail block)."""
        u = jax.random.normal(jax.random.PRNGKey(seed), (1, n))
        ks = jnp.asarray([kc * 8], jnp.int32)
        c = C.block_topk_compress_batch(u, ks, block=256)
        kept = int(c.mask[0].sum())
        idx, vals = C.to_sparse(C.Compressed(c.values[0], c.mask[0]), kept)
        dense = C.from_sparse(idx, vals, n)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(c.values[0]),
                                   rtol=1e-6)

    def test_overallocated_k(self):
        u = _vec(9, 256)
        c = C.topk_compress(u, 0.05)
        idx, vals = C.to_sparse(c, 64)  # k larger than retained count
        assert int((idx >= 0).sum()) == int(c.mask.sum())
        dense = C.from_sparse(idx, vals, 256)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(c.values),
                                   rtol=1e-6)


class TestQuantize:
    def test_unbiased(self):
        u = _vec(10, 10000)
        keys = jax.random.split(jax.random.PRNGKey(11), 64)
        qs = jnp.stack([C.quantize_stochastic(u, 4, k) for k in keys])
        err = np.asarray(qs.mean(0)) - np.asarray(u)
        # unbiasedness: mean error ~ 0; pointwise error within ~5 sigma of
        # the Bernoulli rounding noise (scale/2/sqrt(64))
        assert abs(err.mean()) < 0.01
        scale = float(jnp.max(jnp.abs(u))) / 7
        assert np.abs(err).max() < 5 * scale * 0.5 / 8

    def test_reconstruction_error_bounded(self):
        u = _vec(14, 4096)
        q = C.quantize_stochastic(u, 8, jax.random.PRNGKey(15))
        scale = float(jnp.max(jnp.abs(u))) / 127
        assert float(jnp.max(jnp.abs(q - u))) <= scale * (1 + 1e-6)


class TestRandK:
    def test_unbiased_scaling(self):
        u = _vec(12, 5000)
        keys = jax.random.split(jax.random.PRNGKey(13), 200)
        est = jnp.stack([C.randk_compress(u, 0.2, k).values for k in keys])
        err = np.asarray(est.mean(0)) - np.asarray(u)
        assert abs(err.mean()) < 0.02          # unbiased on average
        assert np.abs(err).mean() < 0.2        # bounded estimator noise
