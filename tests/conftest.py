import os
import sys

import pytest

# `python -m pytest` from the repo root works without PYTHONPATH=src (the
# documented tier-1 command keeps working too — an existing entry wins).
_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
