"""BCRS scheduling tests (paper Alg. 2 + Eq. 6)."""
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.core import bcrs
from repro.core.cost_model import round_times, sample_links, uncompressed_round


def _links(n=8, seed=0):
    return sample_links(n, np.random.default_rng(seed))


class TestSchedule:
    def test_equalizes_times(self):
        """The whole point: post-schedule comm times are ~equal across
        clients (up to the cr_max clip)."""
        links = _links()
        v = 4 * 10_000_000  # 10M params fp32
        crs = bcrs.schedule_crs(links, v, cr_star=0.01)
        times = [bcrs.comm_time(v, l, c) for l, c in zip(links, crs)]
        unclipped = [t for t, c in zip(times, crs) if c < 1.0]
        assert max(unclipped) - min(unclipped) < 1e-9 * max(unclipped) + 1e-6

    def test_slowest_keeps_cr_star(self):
        links = _links()
        v = 4 * 10_000_000
        cr_star = 0.02
        crs = bcrs.schedule_crs(links, v, cr_star)
        t0 = [bcrs.comm_time(v, l, cr_star) for l in links]
        slowest = int(np.argmax(t0))
        assert crs[slowest] == pytest.approx(cr_star, rel=1e-6)

    def test_faster_clients_get_higher_cr(self):
        links = [bcrs.ClientLink(2e6, 0.1), bcrs.ClientLink(1e6, 0.1),
                 bcrs.ClientLink(0.5e6, 0.1)]
        crs = bcrs.schedule_crs(links, 4e6, 0.05)
        assert crs[0] > crs[1] > crs[2]

    @given(st.integers(2, 30), st.floats(0.001, 0.2), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_property_no_slower_than_uniform(self, n, cr_star, seed):
        """BCRS never makes any client slower than the uniform-CR* straggler
        (Fig. 1: it reuses idle time, never adds to it)."""
        links = _links(n, seed)
        v = 4e6
        crs = bcrs.schedule_crs(links, v, cr_star)
        t_bench = max(bcrs.comm_time(v, l, cr_star) for l in links)
        times = [bcrs.comm_time(v, l, c) for l, c in zip(links, crs)]
        assert max(times) <= t_bench * (1 + 1e-9)

    @given(st.integers(2, 30), st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_property_crs_at_least_cr_star(self, n, seed):
        links = _links(n, seed)
        crs = bcrs.schedule_crs(links, 4e6, 0.01)
        assert (crs >= 0.01 - 1e-12).all()


class TestCoefficients:
    def test_cap_at_alpha(self):
        f = np.array([0.5, 0.3, 0.2])
        crs = np.array([0.1, 0.1, 0.1])
        p = bcrs.client_coefficients(f, crs, alpha=0.3)
        assert (p <= 0.3 + 1e-12).all()

    def test_small_data_fraction_downweighted(self):
        """Clients whose data fraction is below their normalized CR get
        p' < alpha (Eq. 6 denominator switches to Norm(CR))."""
        f = np.array([0.05, 0.95])
        crs = np.array([0.5, 0.5])   # Norm -> [0.5, 0.5]
        p = bcrs.client_coefficients(f, crs, alpha=1.0)
        assert p[0] == pytest.approx(0.1)
        assert p[1] == pytest.approx(1.0)


class TestTimeAccounting:
    def test_bcrs_round_no_slower_than_topk(self):
        links = _links(12, seed=3)
        v = 4e6
        cr = 0.05
        topk_rt = round_times(links, v, [cr] * 12)
        crs = bcrs.schedule_crs(links, v, cr)
        bcrs_rt = round_times(links, v, crs)
        assert bcrs_rt.actual <= topk_rt.actual * (1 + 1e-9)

    def test_uncompressed_much_slower(self):
        links = _links(12, seed=4)
        v = 4e6
        dense = uncompressed_round(links, v)
        crs = bcrs.schedule_crs(links, v, 0.01)
        compressed = round_times(links, v, crs)
        assert dense.actual > 10 * compressed.actual

    def test_pod_schedule(self):
        crs = bcrs.pod_link_schedule([100.0, 50.0, 25.0], v_bytes=1e9,
                                     cr_star=0.01)
        assert crs[0] > crs[1] > crs[2]
        assert crs[2] == pytest.approx(0.01, rel=1e-6)
