"""End-to-end FL behaviour tests: convergence, paper-claim directionality,
fault tolerance, mesh-parallel round equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aggregation import AggregationConfig
from repro.fed.mesh_round import make_fl_round_step
from repro.fed.simulation import FLSimConfig, run_fl
from repro.ft import FailureInjector
from repro.models import Model

FAST = dict(rounds=12, n_train=2000, n_test=600, eval_every=2, seed=3)


class TestSimulation:
    def test_fedavg_learns(self):
        res = run_fl(FLSimConfig(**FAST),
                     AggregationConfig(strategy="fedavg"))
        assert res.final_accuracy > 0.5

    def test_topk_learns_slower_at_high_compression(self):
        dense = run_fl(FLSimConfig(**FAST),
                       AggregationConfig(strategy="fedavg"))
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        assert topk.final_accuracy <= dense.final_accuracy + 0.02

    def test_bcrs_not_worse_than_topk(self):
        """Paper claim: BCRS >= TopK at the same CR* (more info, same time)."""
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        bcrs = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="bcrs", cr=0.01, alpha=1.0))
        assert bcrs.final_accuracy >= topk.final_accuracy - 0.03

    def test_bcrs_comm_time_equals_topk_benchmark(self):
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        bcrs = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="bcrs", cr=0.01))
        assert bcrs.times.actual == pytest.approx(topk.times.actual, rel=1e-6)

    def test_fedavg_much_slower_comm(self):
        dense = run_fl(FLSimConfig(**FAST),
                       AggregationConfig(strategy="fedavg"))
        comp = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        assert dense.times.actual > 5 * comp.times.actual

    def test_survives_client_failures(self):
        inj = FailureInjector(p_fail=0.3, seed=1)
        res = run_fl(FLSimConfig(**FAST),
                     AggregationConfig(strategy="bcrs", cr=0.05),
                     failure=inj)
        assert res.final_accuracy > 0.35  # still learns under 30% dropout


class TestMeshRound:
    def _setup(self):
        cfg = get_config("stablelm-1.6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        c, steps, bs, s = 4, 2, 2, 32
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (c, steps, bs, s + 1))
        batches = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                   "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
        return cfg, model, params, batches, c

    def test_round_changes_params_and_loss_finite(self):
        cfg, model, params, batches, c = self._setup()
        fn = jax.jit(make_fl_round_step(model, lr_local=1e-2))
        coeffs = jnp.full((c,), 1.0 / c)
        crs = jnp.full((c,), 0.1)
        new_params, loss = fn(params, batches, coeffs, crs)
        assert np.isfinite(float(loss))
        diffs = jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            params, new_params))
        assert max(diffs) > 0

    def test_cr_one_uncompressed_matches_dense_round(self):
        """CR=1 keeps every parameter -> compressed round == dense round."""
        cfg, model, params, batches, c = self._setup()
        comp_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                             compress=True, gamma=1.0))
        dense_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                              compress=False))
        coeffs = jnp.full((c,), 1.0 / c)
        p1, _ = comp_fn(params, batches, coeffs, jnp.ones((c,)))
        p2, _ = dense_fn(params, batches, coeffs, jnp.ones((c,)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-4, atol=5e-5)

    def test_higher_cr_closer_to_dense(self):
        cfg, model, params, batches, c = self._setup()
        fn = jax.jit(make_fl_round_step(model, lr_local=1e-2, gamma=1.0))
        dense_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                              compress=False))
        coeffs = jnp.full((c,), 1.0 / c)
        pd, _ = dense_fn(params, batches, coeffs, jnp.ones((c,)))
        flat = lambda t: jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(t)])
        errs = []
        for cr in [0.01, 0.3]:
            pc, _ = fn(params, batches, coeffs, jnp.full((c,), cr))
            errs.append(float(jnp.linalg.norm(flat(pc) - flat(pd))))
        assert errs[1] < errs[0]
