"""End-to-end FL behaviour tests: convergence, paper-claim directionality,
fault tolerance, mesh-parallel round equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aggregation import AggregationConfig
from repro.fed.mesh_round import make_fl_round_step
from repro.fed.simulation import FLSimConfig, run_fl
from repro.ft import FailureInjector
from repro.models import Model

FAST = dict(rounds=12, n_train=2000, n_test=600, eval_every=2, seed=3)


class TestSimulation:
    def test_fedavg_learns(self):
        res = run_fl(FLSimConfig(**FAST),
                     AggregationConfig(strategy="fedavg"))
        assert res.final_accuracy > 0.5

    def test_topk_learns_slower_at_high_compression(self):
        dense = run_fl(FLSimConfig(**FAST),
                       AggregationConfig(strategy="fedavg"))
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        assert topk.final_accuracy <= dense.final_accuracy + 0.02

    def test_bcrs_not_worse_than_topk(self):
        """Paper claim: BCRS >= TopK at the same CR* (more info, same time)."""
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        bcrs = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="bcrs", cr=0.01, alpha=1.0))
        assert bcrs.final_accuracy >= topk.final_accuracy - 0.03

    def test_bcrs_comm_time_equals_topk_benchmark(self):
        topk = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        bcrs = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="bcrs", cr=0.01))
        assert bcrs.times.actual == pytest.approx(topk.times.actual, rel=1e-6)

    def test_fedavg_much_slower_comm(self):
        dense = run_fl(FLSimConfig(**FAST),
                       AggregationConfig(strategy="fedavg"))
        comp = run_fl(FLSimConfig(**FAST),
                      AggregationConfig(strategy="topk", cr=0.01))
        assert dense.times.actual > 5 * comp.times.actual

    def test_survives_client_failures(self):
        inj = FailureInjector(p_fail=0.3, seed=1)
        res = run_fl(FLSimConfig(**FAST),
                     AggregationConfig(strategy="bcrs", cr=0.05),
                     failure=inj)
        assert res.final_accuracy > 0.35  # still learns under 30% dropout


class TestMeshRound:
    def _setup(self):
        cfg = get_config("stablelm-1.6b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        c, steps, bs, s = 4, 2, 2, 32
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, (c, steps, bs, s + 1))
        batches = {"tokens": jnp.asarray(toks[..., :-1], jnp.int32),
                   "labels": jnp.asarray(toks[..., 1:], jnp.int32)}
        return cfg, model, params, batches, c

    def test_round_changes_params_and_loss_finite(self):
        cfg, model, params, batches, c = self._setup()
        fn = jax.jit(make_fl_round_step(model, lr_local=1e-2))
        coeffs = jnp.full((c,), 1.0 / c)
        crs = jnp.full((c,), 0.1)
        new_params, loss = fn(params, batches, coeffs, crs)
        assert np.isfinite(float(loss))
        diffs = jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            params, new_params))
        assert max(diffs) > 0

    def test_cr_one_uncompressed_matches_dense_round(self):
        """CR=1 keeps every parameter -> compressed round == dense round."""
        cfg, model, params, batches, c = self._setup()
        comp_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                             compress=True, gamma=1.0))
        dense_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                              compress=False))
        coeffs = jnp.full((c,), 1.0 / c)
        p1, _ = comp_fn(params, batches, coeffs, jnp.ones((c,)))
        p2, _ = dense_fn(params, batches, coeffs, jnp.ones((c,)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-4, atol=5e-5)

    def test_higher_cr_closer_to_dense(self):
        cfg, model, params, batches, c = self._setup()
        fn = jax.jit(make_fl_round_step(model, lr_local=1e-2, gamma=1.0))
        dense_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                              compress=False))
        coeffs = jnp.full((c,), 1.0 / c)
        pd, _ = dense_fn(params, batches, coeffs, jnp.ones((c,)))
        flat = lambda t: jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in jax.tree.leaves(t)])
        errs = []
        for cr in [0.01, 0.3]:
            pc, _ = fn(params, batches, coeffs, jnp.full((c,), cr))
            errs.append(float(jnp.linalg.norm(flat(pc) - flat(pd))))
        assert errs[1] < errs[0]


class TestMeshRoundStepParity:
    """mesh_round and round_step now share ONE compression substrate
    (repro.fed.engine backed by core.compression.topk_compress_dynamic).
    On a tiny 2-leaf model the two engines must agree."""

    C, B, S, DIM, OUT = 3, 8, 2, 16, 4

    class _TwoLeafModel:
        """Linear model with two leaves (w [dim,out], b [out])."""

        @staticmethod
        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"] + params["b"]
            err = pred - batch["t"]
            return jnp.mean(err * err), pred

    def _setup(self, seed=0):
        rng = np.random.default_rng(seed)
        params = {"w": jnp.asarray(rng.normal(size=(self.DIM, self.OUT)),
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(self.OUT,)),
                                   jnp.float32)}
        batches = {"x": jnp.asarray(rng.normal(
                       size=(self.C, self.S, self.B, self.DIM)), jnp.float32),
                   "t": jnp.asarray(rng.normal(
                       size=(self.C, self.S, self.B, self.OUT)), jnp.float32)}
        coeffs = jnp.asarray(rng.dirichlet(np.ones(self.C)), jnp.float32)
        return params, batches, coeffs

    def test_cr_one_matches_fused_round_step(self):
        """At CR=1 both engines keep every parameter, so the per-leaf mesh
        selection and the whole-model-flatten fused selection coincide and
        the server updates must match."""
        from repro.core.aggregation import AggregationConfig
        from repro.core.compression import flatten_tree
        from repro.fed.mesh_round import make_fl_round_step
        from repro.fed.round_step import make_round_step

        model = self._TwoLeafModel()
        params, batches, coeffs = self._setup()
        mesh_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                             gamma=1.0))
        p_mesh, _ = mesh_fn(params, batches, coeffs,
                            jnp.ones((self.C,)))

        acfg = AggregationConfig(strategy="bcrs", cr=1.0)
        step = make_round_step(model.loss_fn, params, lr=1e-2, acfg=acfg)
        flat, unravel = flatten_tree(params)
        n = flat.shape[0]
        mask = jnp.ones((self.C, self.S), bool)
        ks = jnp.full((self.C,), n, jnp.int32)
        out = step(flat.astype(jnp.float32), None, batches, mask, coeffs,
                   ks, ks)
        p_fused = unravel(out["flat"])
        for a, b in zip(jax.tree.leaves(p_mesh), jax.tree.leaves(p_fused)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)

    def test_compressed_leaf_matches_substrate_reference(self):
        """The mesh round's per-leaf path must equal the shared substrate
        computed directly: vmapped topk_compress_dynamic + OPWA merge."""
        from repro.core.compression import topk_compress_dynamic
        from repro.core.opwa import opwa_aggregate
        from repro.fed.client import make_local_trainer
        from repro.fed.mesh_round import make_fl_round_step

        model = self._TwoLeafModel()
        params, batches, coeffs = self._setup(seed=5)
        gamma, cr = 3.0, 0.25
        mesh_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                             gamma=gamma))
        p_mesh, _ = mesh_fn(params, batches, coeffs,
                            jnp.full((self.C,), cr))

        local_train = make_local_trainer(model.loss_fn, 1e-2)
        deltas, _ = jax.vmap(local_train, in_axes=(None, 0))(params, batches)
        for name in ("w", "b"):
            dl = deltas[name].astype(jnp.float32)
            leaf_n = dl[0].size
            ks = jnp.clip(jnp.round(jnp.full((self.C,), cr) * leaf_n)
                          .astype(jnp.int32), 1, leaf_n)
            comp = jax.vmap(topk_compress_dynamic)(dl, ks)
            agg = opwa_aggregate(comp.values, comp.mask, coeffs, gamma,
                                 d=1, use_kernel=False)
            expect = params[name].astype(jnp.float32) - agg
            np.testing.assert_allclose(np.asarray(p_mesh[name]),
                                       np.asarray(expect),
                                       rtol=1e-6, atol=1e-7)

    def test_cr_one_exactness_per_leaf(self):
        """The deleted float-space bisection lost coordinates at CR=1; the
        shared integer-bit bisection must keep EVERY parameter (compressed
        round == dense round bitwise)."""
        from repro.fed.mesh_round import make_fl_round_step

        model = self._TwoLeafModel()
        params, batches, coeffs = self._setup(seed=9)
        comp_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                             gamma=1.0))
        dense_fn = jax.jit(make_fl_round_step(model, lr_local=1e-2,
                                              compress=False))
        p1, _ = comp_fn(params, batches, coeffs, jnp.ones((self.C,)))
        p2, _ = dense_fn(params, batches, coeffs, jnp.ones((self.C,)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
