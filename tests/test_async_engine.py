"""The async buffered-aggregation engine (``engine="async"``), asserted:

  * staleness discount is the identity at s=0 and non-increasing in s;
  * partial-flush coefficients preserve the planned full-buffer step
    magnitude (renormalization folds the missing slots' mass onto the
    arrived ones);
  * the retry-aware arrival process is a pure function of (seed, dispatch
    order) and round-trips through its checkpoint state;
  * the degenerate sync-arrivals configuration reproduces the scan
    engine's trajectory exactly (pop_scan's for per-client-EF strategies,
    residual matrix included);
  * the buffer merge compiles exactly ONCE per run;
  * every carry="ef" strategy survives p_fail > 0 end to end;
  * a crash-restarted run (checkpoint -> stop -> resume) is bit-identical
    to an uninterrupted one: params, residuals, times, accuracies.
"""
import numpy as np
import pytest

from repro.core import cost_model
from repro.core.aggregation import AggregationConfig
from repro.core.bcrs import ClientLink, comm_time, staleness_discount
from repro.fed import async_engine
from repro.fed.async_engine import flush_weights
from repro.fed.simulation import FLSimConfig, run_fl
from repro.ft.arrivals import ArrivalProcess, failure_fracs

FAST = dict(rounds=6, n_train=1600, n_test=500, eval_every=2, seed=3)
ASYNC = dict(async_buffer_k=4, async_p_fail_upload=0.3,
             async_upload_timeout_s=60.0)


def _accs(res):
    return np.array([a for _, a in res.accuracies])


def _times(res):
    return np.array([[t.actual, t.max, t.min] for t in res.times.per_round])


# ------------------------------------------------------ staleness weighting
class TestStalenessDiscount:
    def test_identity_at_zero_staleness(self):
        w = np.array([0.4, 0.3, 0.2, 0.1])
        np.testing.assert_array_equal(
            staleness_discount(w, np.zeros(4), alpha=0.7), w)

    def test_alpha_zero_disables(self):
        w = np.array([0.5, 0.5])
        np.testing.assert_array_equal(
            staleness_discount(w, np.array([3.0, 9.0]), alpha=0.0), w)

    def test_monotone_nonincreasing_in_staleness(self):
        w = np.ones(6)
        for alpha in (0.25, 0.5, 1.0, 2.0):
            d = staleness_discount(w, np.arange(6, dtype=float), alpha)
            assert (np.diff(d) < 0).all()
            assert (d > 0).all() and (d <= 1.0).all()


class TestFlushWeights:
    COEFFS = np.array([0.05, 0.10, 0.15, 0.20, 0.25, 0.25])

    def test_full_flush_is_discounted_passthrough(self):
        ids, stal = [2, 0, 5], [0.0, 1.0, 2.0]
        w = flush_weights(ids, stal, [], [], buffer_k=3, alpha=0.5,
                          coeff_table=self.COEFFS)
        expect = staleness_discount(self.COEFFS[ids], stal, 0.5)
        np.testing.assert_allclose(w, expect, rtol=1e-12)

    def test_partial_flush_preserves_planned_magnitude(self):
        """A stall flush with m < K arrived takes the same total step the
        full buffer would have: the pending uploads' discounted mass is
        folded onto the arrived slots."""
        ids, stal = [1, 4], [0.0, 1.0]
        pend_ids, pend_stal = [3, 0], [2.0, 0.0]
        w = flush_weights(ids, stal, pend_ids, pend_stal, buffer_k=4,
                          alpha=0.5, coeff_table=self.COEFFS)
        assert w.shape == (2,)
        planned = staleness_discount(
            self.COEFFS[ids + pend_ids],
            np.array(stal + pend_stal), 0.5).sum()
        assert w.sum() == pytest.approx(planned, rel=1e-12)
        # arrived slots keep their relative discounted proportions
        d = staleness_discount(self.COEFFS[ids], np.array(stal), 0.5)
        np.testing.assert_allclose(w / w.sum(), d / d.sum(), rtol=1e-12)

    def test_data_weighting_normalizes_over_occupants(self):
        fracs = np.array([0.1, 0.2, 0.3, 0.4])
        w = flush_weights([0, 3], [0.0, 0.0], [], [], buffer_k=2,
                          alpha=0.5, fracs_all=fracs)
        np.testing.assert_allclose(w, [0.2, 0.8], rtol=1e-12)


# ------------------------------------------------------- arrival process
def _link(rng):
    return ClientLink(bandwidth_bps=float(rng.uniform(2e6, 3e7)),
                      latency_s=float(rng.uniform(0.001, 0.04)))


class TestRetries:
    LINK = ClientLink(bandwidth_bps=1e7, latency_s=0.01)

    def test_clean_upload_matches_comm_time(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [], cost_model.RetryPolicy())
        assert out.arrived and out.attempts == 1 and not out.timed_out
        assert out.t_resolve == pytest.approx(
            comm_time(1e6, self.LINK, 0.1))

    def test_resume_from_offset_crosses_wire_once(self):
        """Payload bytes cross the wire exactly once across retries: the
        retried run costs only extra latency + backoff over the clean one,
        never a re-send of delivered bytes."""
        pol = cost_model.RetryPolicy(backoff_s=0.5, backoff_factor=2.0)
        clean = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [], pol)
        two_cuts = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [0.5, 0.5], pol)
        assert two_cuts.arrived and two_cuts.attempts == 3
        assert two_cuts.t_resolve == pytest.approx(
            clean.t_resolve + 2 * self.LINK.latency_s + 0.5 + 1.0)

    def test_retries_exhausted_reports_progress(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [0.5, 0.75],
            cost_model.RetryPolicy(max_attempts=2))
        assert not out.arrived and not out.timed_out
        assert out.progress == pytest.approx(0.875)

    def test_timeout_clips(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e8, 1.0, [], cost_model.RetryPolicy(timeout_s=0.3))
        assert not out.arrived and out.timed_out
        assert out.t_resolve == pytest.approx(0.3)


class TestArrivalProcess:
    def _run_stream(self, proc, rng, n=12):
        evs = []
        for i in range(n):
            proc.dispatch(int(rng.integers(8)), i, float(i) * 0.1,
                          _link(rng), 4e5, 0.05)
        while len(proc):
            evs.append(proc.pop())
        return evs

    def test_deterministic_in_seed(self):
        a = self._run_stream(ArrivalProcess(seed=5, p_fail=0.4),
                             np.random.default_rng(0))
        b = self._run_stream(ArrivalProcess(seed=5, p_fail=0.4),
                             np.random.default_rng(0))
        assert a == b
        c = self._run_stream(ArrivalProcess(seed=6, p_fail=0.4),
                             np.random.default_rng(0))
        assert [e.t_resolve for e in a] != [e.t_resolve for e in c]

    def test_failure_fracs_counter_based(self):
        for uid in range(40):
            f1 = failure_fracs(9, uid, 0.6, 4)
            f2 = failure_fracs(9, uid, 0.6, 4)
            assert f1 == f2 and len(f1) <= 4
        # some dispatch must actually draw a failure at p_fail=0.6
        assert any(failure_fracs(9, u, 0.6, 4) for u in range(40))

    def test_state_roundtrip_reproduces_future(self):
        rng = np.random.default_rng(2)
        proc = ArrivalProcess(seed=7, p_fail=0.5)
        for i in range(6):
            proc.dispatch(i, 0, 0.0, _link(rng), 4e5, 0.05)
        proc.pop(), proc.pop()
        clone = ArrivalProcess(seed=7, p_fail=0.5)
        clone.load_state(proc.state())
        assert clone.counter == proc.counter
        # identical remaining events AND identical post-restore dispatches
        rng2 = np.random.default_rng(3)
        link = _link(rng2)
        proc.dispatch(7, 1, 1.0, link, 4e5, 0.05)
        clone.dispatch(7, 1, 1.0, link, 4e5, 0.05)
        while len(proc):
            assert proc.pop() == clone.pop()
        assert not len(clone)


# ----------------------------------------------------- sync parity anchor
class TestSyncParityAnchor:
    def test_matches_scan_bcrs_opwa(self):
        sim = FLSimConfig(**FAST, async_sync_arrivals=True)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        ref = run_fl(FLSimConfig(**FAST), acfg, engine="scan")
        res = run_fl(sim, acfg, engine="async")
        np.testing.assert_array_equal(_accs(res), _accs(ref))

    def test_matches_pop_scan_eftopk_residuals_exact(self):
        sim = FLSimConfig(**FAST, async_sync_arrivals=True)
        acfg = AggregationConfig(strategy="eftopk", cr=0.05)
        ref = run_fl(FLSimConfig(**FAST), acfg, engine="pop_scan")
        res = run_fl(sim, acfg, engine="async")
        np.testing.assert_array_equal(_accs(res), _accs(ref))
        np.testing.assert_array_equal(res.final_residuals,
                                      ref.final_residuals)


# ------------------------------------------------------ general async mode
class TestAsyncEngine:
    @pytest.mark.parametrize("strategy", ["eftopk", "qtopk"])
    def test_ef_strategies_survive_failures(self, strategy):
        """carry="ef" strategies run end to end with mid-transfer upload
        failures, and the buffer merge compiles exactly once per run."""
        sim = FLSimConfig(**FAST, **ASYNC)
        before = dict(async_engine.TRACE_COUNTS)
        res = run_fl(sim, AggregationConfig(strategy=strategy, cr=0.05),
                     engine="async")
        delta = {k: v - before.get(k, 0)
                 for k, v in async_engine.TRACE_COUNTS.items()
                 if v != before.get(k, 0)}
        assert delta.get(("async_merge", strategy)) == 1
        assert delta.get(("async_train", strategy)) == 1
        assert len(res.executed_rounds) == sim.rounds
        assert res.final_accuracy > 0.2
        assert res.final_residuals is not None
        assert np.abs(res.final_residuals).sum() > 0

    def test_deterministic(self):
        sim = FLSimConfig(**FAST, **ASYNC)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        a, b = (run_fl(sim, acfg, engine="async") for _ in range(2))
        np.testing.assert_array_equal(_accs(a), _accs(b))
        np.testing.assert_array_equal(_times(a), _times(b))

    def test_staleness_and_partial_flush(self):
        """A tight stall deadline under heavy failures forces partial
        flushes; the run still completes every flush, and virtual time
        advances monotonically."""
        sim = FLSimConfig(**{**FAST, **ASYNC, "async_stall_s": 0.05,
                             "async_p_fail_upload": 0.5})
        res = run_fl(sim, AggregationConfig(strategy="eftopk", cr=0.05),
                     engine="async")
        assert len(res.executed_rounds) == sim.rounds
        assert (_times(res)[:, 0] >= 0).all()

    def test_buffer_larger_than_population_rejected(self):
        sim = FLSimConfig(**FAST, async_buffer_k=11)
        with pytest.raises(ValueError, match="exceeds"):
            run_fl(sim, AggregationConfig(strategy="fedavg"),
                   engine="async")

    def test_overlap_collection_rejected(self):
        with pytest.raises(ValueError):
            run_fl(FLSimConfig(**FAST), AggregationConfig(strategy="fedavg"),
                   engine="async", collect_overlap=True)

    def test_checkpoint_knobs_require_async(self):
        with pytest.raises(ValueError):
            run_fl(FLSimConfig(**FAST), AggregationConfig(strategy="fedavg"),
                   engine="scan", checkpoint_dir="/tmp/x")


# --------------------------------------------------------- crash restart
class TestCrashRestart:
    @pytest.mark.parametrize("strategy", ["bcrs_opwa", "eftopk"])
    def test_restart_is_bit_exact(self, strategy, tmp_path):
        """Checkpoint at flush 2, crash at flush 3, resume: the restarted
        run's params, residuals, times, accuracies, buffer occupancy and
        dispatch counter all match the uninterrupted run exactly."""
        sim = FLSimConfig(**FAST, **ASYNC)
        acfg = AggregationConfig(strategy=strategy, cr=0.05)
        full = run_fl(sim, acfg, engine="async")
        ckpt = str(tmp_path / strategy)
        run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
               checkpoint_every=2, stop_after=3)
        res = run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
                     checkpoint_every=2)
        np.testing.assert_array_equal(_accs(res), _accs(full))
        np.testing.assert_array_equal(_times(res), _times(full))
        np.testing.assert_array_equal(
            np.asarray(res.async_loop.flat), np.asarray(full.async_loop.flat))
        assert res.async_loop.proc.counter == full.async_loop.proc.counter
        assert ([(b["client"], b["uid"]) for b in res.async_loop.buffer]
                == [(b["client"], b["uid"]) for b in full.async_loop.buffer])
        if full.final_residuals is not None:
            np.testing.assert_array_equal(res.final_residuals,
                                          full.final_residuals)
