"""The async buffered-aggregation engine (``engine="async"``), asserted:

  * staleness discount is the identity at s=0 and non-increasing in s;
  * partial-flush coefficients preserve the planned full-buffer step
    magnitude (renormalization folds the missing slots' mass onto the
    arrived ones);
  * the retry-aware arrival process is a pure function of (seed, dispatch
    order) and round-trips through its checkpoint state;
  * the degenerate sync-arrivals configuration reproduces the scan
    engine's trajectory exactly (pop_scan's for per-client-EF strategies,
    residual matrix included);
  * the buffer merge compiles exactly ONCE per run, the wave trainer once
    per wave SHAPE BUCKET (a bounded pow2 set);
  * batched wave dispatch is bit-exact with eager per-upload dispatch
    while issuing strictly fewer jit calls;
  * every carry="ef" strategy survives p_fail > 0 end to end;
  * the sparse out-of-core residual store reproduces the dense [P + 1, n]
    reference bit-exactly at P = 4096 under failures + partial flushes,
    and its train/merge programs never materialize a P-sized array;
  * a crash-restarted run (checkpoint -> stop -> resume) is bit-identical
    to an uninterrupted one — params, residuals, times, accuracies —
    including with the sparse store spilled to disk;
  * the async_* config knobs are validated BEFORE any loop state exists.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.aggregation import AggregationConfig
from repro.core.bcrs import ClientLink, comm_time, staleness_discount
from repro.core.compression import flatten_tree, k_for_ratio
from repro.fed import async_engine
from repro.fed import population as pop_mod
from repro.fed.async_engine import (BufferedAsyncLoop, flush_weights,
                                    make_async_merge_step,
                                    make_wave_train_step, min_version_ring,
                                    wave_bucket)
from repro.fed.simulation import FLSimConfig, mlp_init, mlp_loss, run_fl
from repro.ft.arrivals import ArrivalProcess, failure_fracs

FAST = dict(rounds=6, n_train=1600, n_test=500, eval_every=2, seed=3)
ASYNC = dict(async_buffer_k=4, async_p_fail_upload=0.3,
             async_upload_timeout_s=60.0)


def _accs(res):
    return np.array([a for _, a in res.accuracies])


def _times(res):
    return np.array([[t.actual, t.max, t.min] for t in res.times.per_round])


# ------------------------------------------------------ staleness weighting
class TestStalenessDiscount:
    def test_identity_at_zero_staleness(self):
        w = np.array([0.4, 0.3, 0.2, 0.1])
        np.testing.assert_array_equal(
            staleness_discount(w, np.zeros(4), alpha=0.7), w)

    def test_alpha_zero_disables(self):
        w = np.array([0.5, 0.5])
        np.testing.assert_array_equal(
            staleness_discount(w, np.array([3.0, 9.0]), alpha=0.0), w)

    def test_monotone_nonincreasing_in_staleness(self):
        w = np.ones(6)
        for alpha in (0.25, 0.5, 1.0, 2.0):
            d = staleness_discount(w, np.arange(6, dtype=float), alpha)
            assert (np.diff(d) < 0).all()
            assert (d > 0).all() and (d <= 1.0).all()


class TestFlushWeights:
    COEFFS = np.array([0.05, 0.10, 0.15, 0.20, 0.25, 0.25])

    def test_full_flush_is_discounted_passthrough(self):
        ids, stal = [2, 0, 5], [0.0, 1.0, 2.0]
        w = flush_weights(ids, stal, [], [], buffer_k=3, alpha=0.5,
                          coeff_table=self.COEFFS)
        expect = staleness_discount(self.COEFFS[ids], stal, 0.5)
        np.testing.assert_allclose(w, expect, rtol=1e-12)

    def test_partial_flush_preserves_planned_magnitude(self):
        """A stall flush with m < K arrived takes the same total step the
        full buffer would have: the pending uploads' discounted mass is
        folded onto the arrived slots."""
        ids, stal = [1, 4], [0.0, 1.0]
        pend_ids, pend_stal = [3, 0], [2.0, 0.0]
        w = flush_weights(ids, stal, pend_ids, pend_stal, buffer_k=4,
                          alpha=0.5, coeff_table=self.COEFFS)
        assert w.shape == (2,)
        planned = staleness_discount(
            self.COEFFS[ids + pend_ids],
            np.array(stal + pend_stal), 0.5).sum()
        assert w.sum() == pytest.approx(planned, rel=1e-12)
        # arrived slots keep their relative discounted proportions
        d = staleness_discount(self.COEFFS[ids], np.array(stal), 0.5)
        np.testing.assert_allclose(w / w.sum(), d / d.sum(), rtol=1e-12)

    def test_data_weighting_normalizes_over_occupants(self):
        fracs = np.array([0.1, 0.2, 0.3, 0.4])
        w = flush_weights([0, 3], [0.0, 0.0], [], [], buffer_k=2,
                          alpha=0.5, fracs_all=fracs)
        np.testing.assert_allclose(w, [0.2, 0.8], rtol=1e-12)


# ------------------------------------------------------- arrival process
def _link(rng):
    return ClientLink(bandwidth_bps=float(rng.uniform(2e6, 3e7)),
                      latency_s=float(rng.uniform(0.001, 0.04)))


class TestRetries:
    LINK = ClientLink(bandwidth_bps=1e7, latency_s=0.01)

    def test_clean_upload_matches_comm_time(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [], cost_model.RetryPolicy())
        assert out.arrived and out.attempts == 1 and not out.timed_out
        assert out.t_resolve == pytest.approx(
            comm_time(1e6, self.LINK, 0.1))

    def test_resume_from_offset_crosses_wire_once(self):
        """Payload bytes cross the wire exactly once across retries: the
        retried run costs only extra latency + backoff over the clean one,
        never a re-send of delivered bytes."""
        pol = cost_model.RetryPolicy(backoff_s=0.5, backoff_factor=2.0)
        clean = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [], pol)
        two_cuts = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [0.5, 0.5], pol)
        assert two_cuts.arrived and two_cuts.attempts == 3
        assert two_cuts.t_resolve == pytest.approx(
            clean.t_resolve + 2 * self.LINK.latency_s + 0.5 + 1.0)

    def test_retries_exhausted_reports_progress(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e6, 0.1, [0.5, 0.75],
            cost_model.RetryPolicy(max_attempts=2))
        assert not out.arrived and not out.timed_out
        assert out.progress == pytest.approx(0.875)

    def test_timeout_clips(self):
        out = cost_model.upload_time_with_retries(
            self.LINK, 1e8, 1.0, [], cost_model.RetryPolicy(timeout_s=0.3))
        assert not out.arrived and out.timed_out
        assert out.t_resolve == pytest.approx(0.3)


class TestArrivalProcess:
    def _run_stream(self, proc, rng, n=12):
        evs = []
        for i in range(n):
            proc.dispatch(int(rng.integers(8)), i, float(i) * 0.1,
                          _link(rng), 4e5, 0.05)
        while len(proc):
            evs.append(proc.pop())
        return evs

    def test_deterministic_in_seed(self):
        a = self._run_stream(ArrivalProcess(seed=5, p_fail=0.4),
                             np.random.default_rng(0))
        b = self._run_stream(ArrivalProcess(seed=5, p_fail=0.4),
                             np.random.default_rng(0))
        assert a == b
        c = self._run_stream(ArrivalProcess(seed=6, p_fail=0.4),
                             np.random.default_rng(0))
        assert [e.t_resolve for e in a] != [e.t_resolve for e in c]

    def test_failure_fracs_counter_based(self):
        for uid in range(40):
            f1 = failure_fracs(9, uid, 0.6, 4)
            f2 = failure_fracs(9, uid, 0.6, 4)
            assert f1 == f2 and len(f1) <= 4
        # some dispatch must actually draw a failure at p_fail=0.6
        assert any(failure_fracs(9, u, 0.6, 4) for u in range(40))

    def test_state_roundtrip_reproduces_future(self):
        rng = np.random.default_rng(2)
        proc = ArrivalProcess(seed=7, p_fail=0.5)
        for i in range(6):
            proc.dispatch(i, 0, 0.0, _link(rng), 4e5, 0.05)
        proc.pop(), proc.pop()
        clone = ArrivalProcess(seed=7, p_fail=0.5)
        clone.load_state(proc.state())
        assert clone.counter == proc.counter
        # identical remaining events AND identical post-restore dispatches
        rng2 = np.random.default_rng(3)
        link = _link(rng2)
        proc.dispatch(7, 1, 1.0, link, 4e5, 0.05)
        clone.dispatch(7, 1, 1.0, link, 4e5, 0.05)
        while len(proc):
            assert proc.pop() == clone.pop()
        assert not len(clone)


# ----------------------------------------------------- sync parity anchor
class TestSyncParityAnchor:
    def test_matches_scan_bcrs_opwa(self):
        sim = FLSimConfig(**FAST, async_sync_arrivals=True)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        ref = run_fl(FLSimConfig(**FAST), acfg, engine="scan")
        res = run_fl(sim, acfg, engine="async")
        np.testing.assert_array_equal(_accs(res), _accs(ref))

    def test_matches_pop_scan_eftopk_residuals_exact(self):
        sim = FLSimConfig(**FAST, async_sync_arrivals=True)
        acfg = AggregationConfig(strategy="eftopk", cr=0.05)
        ref = run_fl(FLSimConfig(**FAST), acfg, engine="pop_scan")
        res = run_fl(sim, acfg, engine="async")
        np.testing.assert_array_equal(_accs(res), _accs(ref))
        np.testing.assert_array_equal(res.final_residuals,
                                      ref.final_residuals)


# ------------------------------------------------------ general async mode
class TestAsyncEngine:
    @pytest.mark.parametrize("strategy", ["eftopk", "qtopk"])
    def test_ef_strategies_survive_failures(self, strategy):
        """carry="ef" strategies run end to end with mid-transfer upload
        failures, and the buffer merge compiles exactly once per run."""
        sim = FLSimConfig(**FAST, **ASYNC)
        before = dict(async_engine.TRACE_COUNTS)
        res = run_fl(sim, AggregationConfig(strategy=strategy, cr=0.05),
                     engine="async")
        delta = {k: v - before.get(k, 0)
                 for k, v in async_engine.TRACE_COUNTS.items()
                 if v != before.get(k, 0)}
        assert delta.get(("async_merge", strategy)) == 1
        # the wave trainer compiles once per wave SHAPE BUCKET, never more
        assert delta.get(("async_train", strategy)) \
            == len(res.async_loop.wave_buckets_used)
        assert len(res.executed_rounds) == sim.rounds
        assert res.final_accuracy > 0.2
        assert res.final_residuals is not None
        assert np.abs(res.final_residuals).sum() > 0

    def test_deterministic(self):
        sim = FLSimConfig(**FAST, **ASYNC)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        a, b = (run_fl(sim, acfg, engine="async") for _ in range(2))
        np.testing.assert_array_equal(_accs(a), _accs(b))
        np.testing.assert_array_equal(_times(a), _times(b))

    def test_staleness_and_partial_flush(self):
        """A tight stall deadline under heavy failures forces partial
        flushes; the run still completes every flush, and virtual time
        advances monotonically."""
        sim = FLSimConfig(**{**FAST, **ASYNC, "async_stall_s": 0.05,
                             "async_p_fail_upload": 0.5})
        res = run_fl(sim, AggregationConfig(strategy="eftopk", cr=0.05),
                     engine="async")
        assert len(res.executed_rounds) == sim.rounds
        assert (_times(res)[:, 0] >= 0).all()

    def test_buffer_larger_than_population_rejected(self):
        sim = FLSimConfig(**FAST, async_buffer_k=11)
        with pytest.raises(ValueError, match="exceeds"):
            run_fl(sim, AggregationConfig(strategy="fedavg"),
                   engine="async")

    def test_overlap_collection_rejected(self):
        with pytest.raises(ValueError):
            run_fl(FLSimConfig(**FAST), AggregationConfig(strategy="fedavg"),
                   engine="async", collect_overlap=True)

    def test_checkpoint_knobs_require_async(self):
        with pytest.raises(ValueError):
            run_fl(FLSimConfig(**FAST), AggregationConfig(strategy="fedavg"),
                   engine="scan", checkpoint_dir="/tmp/x")


# ------------------------------------------------------- batched dispatch
class TestBatchedDispatch:
    def test_wave_bucket_is_next_pow2(self):
        assert [wave_bucket(w) for w in (1, 2, 3, 5, 8, 9, 16)] \
            == [1, 2, 4, 8, 8, 16, 16]

    def test_min_version_ring_bound(self):
        # M <= K: every in-flight upload is current-version (depth 1);
        # M > K: one flush can land mid-pipeline (pigeonhole -> depth 2)
        assert min_version_ring(4, 8) == 1
        assert min_version_ring(8, 8) == 1
        assert min_version_ring(9, 8) == 2
        assert min_version_ring(64, 8) == 2

    @pytest.mark.parametrize("strategy", ["bcrs_opwa", "eftopk", "qtopk"])
    def test_batched_bit_exact_with_sequential(self, strategy):
        """Wave-batched dispatch is pure scheduling: params, residuals,
        accuracies and flush times all match the eager per-upload baseline
        bit for bit, with strictly fewer jit dispatches."""
        acfg = AggregationConfig(strategy=strategy, cr=0.05)
        b = run_fl(FLSimConfig(**FAST, **ASYNC), acfg, engine="async")
        s = run_fl(FLSimConfig(**FAST, **ASYNC, async_batch_dispatch=False),
                   acfg, engine="async")
        np.testing.assert_array_equal(_accs(b), _accs(s))
        np.testing.assert_array_equal(_times(b), _times(s))
        np.testing.assert_array_equal(np.asarray(b.async_loop.flat),
                                      np.asarray(s.async_loop.flat))
        if s.final_residuals is not None:
            np.testing.assert_array_equal(b.final_residuals,
                                          s.final_residuals)
        lb, ls = b.async_loop, s.async_loop
        assert lb.train_calls < ls.train_calls
        # eager mode trains each dispatch as a wave of one
        assert ls.train_calls == ls.train_rows
        assert ls.wave_buckets_used == {1}
        assert all(w == wave_bucket(w) for w in lb.wave_buckets_used)

    def test_version_ring_below_bound_rejected_at_config_time(self):
        sim = FLSimConfig(**FAST, async_buffer_k=4, async_concurrency=6,
                          async_version_ring=1)
        with pytest.raises(ValueError, match="staleness bound"):
            run_fl(sim, AggregationConfig(strategy="fedavg"),
                   engine="async")

    def test_store_resident_requires_spill_dir(self):
        sim = FLSimConfig(**FAST, async_store_resident=2)
        with pytest.raises(ValueError, match="spill"):
            run_fl(sim, AggregationConfig(strategy="eftopk", cr=0.05),
                   engine="async")


# -------------------------------------------- sparse population-scale store
def _drive_loop(p, k_buf, m_conc, flushes, *, sparse, stall_s,
                spill=None, chunk=256, resident=None):
    """Drive ``BufferedAsyncLoop`` directly (run_fl's dataset partition is
    O(P) host setup — irrelevant to the loop under test) with a tiny MLP;
    returns (loop, flush RoundTimes, buffer occupancy at each flush)."""
    acfg = AggregationConfig(strategy="eftopk", cr=0.1)
    pop = pop_mod.make_population(p, seed=11)
    params = mlp_init(jax.random.PRNGKey(11), 16, 5, hidden=16)
    flat0, _ = flatten_tree(params)
    n = int(flat0.shape[0])
    data_rng = np.random.default_rng(4)
    x_all = jnp.asarray(data_rng.normal(size=(256, 16)).astype(np.float32))
    y_all = jnp.asarray(data_rng.integers(0, 5, 256).astype(np.int32))
    k = k_for_ratio(n, acfg.cr)
    width = pop_mod.residual_width(n, k)
    if sparse:
        store = pop_mod.ClientStateStore(
            p, n, layout="topk_complement", width=width,
            chunk_clients=chunk, max_resident_chunks=resident,
            spill_dir=spill)
        merge = make_async_merge_step(
            acfg, residual_layout="topk_complement", width=width)
    else:
        store, merge = None, make_async_merge_step(acfg)
    wave_train = make_wave_train_step(
        mlp_loss, params, lr=0.1,
        make_batches=lambda x: {"x": x_all[x["sample_idx"]],
                                "y": y_all[x["sample_idx"]]},
        strategy="eftopk")

    def batch_plan(client, uid):
        r = np.random.default_rng((11, async_engine.BATCH_TAG, uid))
        return {"sample_idx": r.integers(256, size=(2, 4)).astype(np.int32),
                "step_mask": np.ones((2,), bool)}

    rts = []
    loop = BufferedAsyncLoop(
        n_clients=p, n_params=n, buffer_k=k_buf, concurrency=m_conc,
        # p_fail=0.5 with a 0.3 s deadline: clean first attempts land
        # (latency 0.05-0.2 + a ~ms transfer) but a single failure pushes
        # the retry past the deadline mid-backoff, so failed uploads abort
        # while still PENDING — lazy mode never trains them (the
        # aborted_untrained assertion below)
        target_flushes=flushes, seed=11, alpha=0.5, stall_s=stall_s,
        p_fail=0.5,
        retry=cost_model.RetryPolicy(max_attempts=2, timeout_s=0.3),
        links=pop.links, v_bytes=4.0 * n,
        cr_eff_all=np.full(p, acfg.cr), ks_all=np.full(p, k, np.int32),
        coeff_table=None, fracs_all=pop.weights, merge=merge,
        wave_train=wave_train, batch_plan=batch_plan, residual_store=store,
        on_flush=lambda i, f, rt: rts.append((rt.actual, rt.max, rt.min)))
    flush_sizes = []
    inner_flush = loop._flush

    def spy_flush(t):
        flush_sizes.append(len(loop.buffer))
        inner_flush(t)

    loop._flush = spy_flush
    loop.run(jnp.array(flat0))
    return loop, np.array(rts), flush_sizes


class TestSparseStore:
    def test_matches_dense_reference_p4096(self):
        """P=4096 clients over a C=16 buffer with upload failures AND
        stall-forced partial flushes: the sparse out-of-core store's run is
        bit-identical to the dense [P + 1, n] reference — params, the full
        residual matrix, and every flush's RoundTime."""
        P, K = 4096, 16
        dl, drts, dsizes = _drive_loop(P, K, 32, 8, sparse=False,
                                       stall_s=0.02)
        sl, srts, ssizes = _drive_loop(P, K, 32, 8, sparse=True,
                                       stall_s=0.02, chunk=64)
        assert dsizes == ssizes
        np.testing.assert_array_equal(drts, srts)
        np.testing.assert_array_equal(np.asarray(dl.flat),
                                      np.asarray(sl.flat))
        np.testing.assert_array_equal(sl.store.dump_dense(), dl.store[:P])
        # the failure regime was actually exercised
        assert min(dsizes) < K            # >=1 partial (stall) flush
        assert dl.aborted_untrained > 0   # lazy mode skipped aborted waves


class TestAsyncMemoryGate:
    def _all_avals(self, jaxpr, out):
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    out.append(aval)
            for param in eqn.params.values():
                inner = getattr(param, "jaxpr", param)
                if hasattr(inner, "eqns"):
                    self._all_avals(inner, out)
        return out

    def test_wave_and_merge_programs_have_no_population_sized_aval(self):
        """The async memory gate: the compiled wave-train and buffer-merge
        programs are sized by the wave bucket / buffer K and the version
        ring — for a nominal P = 10^6 population, NOTHING in either jaxpr
        is within two orders of magnitude of a [P]-sized buffer."""
        huge_p = 1_000_000
        k_buf, ring_depth, bs, s = 8, 8, 4, 2
        acfg = AggregationConfig(strategy="eftopk", cr=0.1)
        params = mlp_init(jax.random.PRNGKey(0), 16, 5, hidden=16)
        flat0, _ = flatten_tree(params)
        n = int(flat0.shape[0])
        k = k_for_ratio(n, acfg.cr)
        width = pop_mod.residual_width(n, k)
        x_all = jnp.zeros((256, 16), jnp.float32)
        y_all = jnp.zeros((256,), jnp.int32)
        wave_train = make_wave_train_step(
            mlp_loss, params, lr=0.1,
            make_batches=lambda x: {"x": x_all[x["sample_idx"]],
                                    "y": y_all[x["sample_idx"]]},
            strategy="eftopk")
        merge = make_async_merge_step(
            acfg, residual_layout="topk_complement", width=width)
        ring = jnp.zeros((ring_depth, n), jnp.float32)
        xw = {"sample_idx": jnp.zeros((k_buf, s, bs), jnp.int32),
              "step_mask": jnp.ones((k_buf, s), bool),
              "ver_idx": jnp.zeros((k_buf,), jnp.int32)}
        xm = {"updates": jnp.zeros((k_buf, n), jnp.float32),
              "weights": jnp.zeros((k_buf,), jnp.float32),
              "ks": jnp.full((k_buf,), k, jnp.int32),
              "active": jnp.ones((k_buf,), bool)}
        res = (jnp.zeros((k_buf, width), jnp.int32),
               jnp.zeros((k_buf, width), jnp.float32))
        for closed in (jax.make_jaxpr(wave_train._fn)(ring, xw),
                       jax.make_jaxpr(merge._fn)(
                           jnp.zeros((n,), jnp.float32), res, xm)):
            avals = self._all_avals(closed.jaxpr, [])
            assert avals
            biggest = max(int(np.prod(a.shape)) for a in avals)
            assert biggest < huge_p // 100, (
                f"async program allocates {biggest} elements")
            assert all(huge_p not in a.shape for a in avals)


# --------------------------------------------------------- crash restart
class TestCrashRestart:
    @pytest.mark.parametrize("strategy", ["bcrs_opwa", "eftopk"])
    def test_restart_is_bit_exact(self, strategy, tmp_path):
        """Checkpoint at flush 2, crash at flush 3, resume: the restarted
        run's params, residuals, times, accuracies, buffer occupancy and
        dispatch counter all match the uninterrupted run exactly."""
        sim = FLSimConfig(**FAST, **ASYNC)
        acfg = AggregationConfig(strategy=strategy, cr=0.05)
        full = run_fl(sim, acfg, engine="async")
        ckpt = str(tmp_path / strategy)
        run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
               checkpoint_every=2, stop_after=3)
        res = run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
                     checkpoint_every=2)
        np.testing.assert_array_equal(_accs(res), _accs(full))
        np.testing.assert_array_equal(_times(res), _times(full))
        np.testing.assert_array_equal(
            np.asarray(res.async_loop.flat), np.asarray(full.async_loop.flat))
        assert res.async_loop.proc.counter == full.async_loop.proc.counter
        assert ([(b["client"], b["uid"]) for b in res.async_loop.buffer]
                == [(b["client"], b["uid"]) for b in full.async_loop.buffer])
        if full.final_residuals is not None:
            np.testing.assert_array_equal(res.final_residuals,
                                          full.final_residuals)

    def test_restart_bit_exact_with_sparse_store_spilled(self, tmp_path):
        """Crash-restart with the sparse residual store under a 2-chunk
        residency bound spilling to disk: the resumed run restores the
        store from the checkpoint's chunk snapshots and finishes
        bit-identical to the uninterrupted run, while the bounded LRU
        actually evicted through the spill directory."""
        sim = FLSimConfig(**FAST, **ASYNC, async_store_chunk=2,
                          async_store_resident=2,
                          async_store_spill=str(tmp_path / "spill"))
        acfg = AggregationConfig(strategy="eftopk", cr=0.05)
        full = run_fl(sim, acfg, engine="async")
        assert full.async_loop.store.chunk_spills > 0
        ckpt = str(tmp_path / "ckpt")
        run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
               checkpoint_every=2, stop_after=3)
        res = run_fl(sim, acfg, engine="async", checkpoint_dir=ckpt,
                     checkpoint_every=2)
        np.testing.assert_array_equal(_accs(res), _accs(full))
        np.testing.assert_array_equal(_times(res), _times(full))
        np.testing.assert_array_equal(np.asarray(res.async_loop.flat),
                                      np.asarray(full.async_loop.flat))
        np.testing.assert_array_equal(res.final_residuals,
                                      full.final_residuals)
