"""Population-scale FL: the streaming-cohort engine, the sparse out-of-core
client store, and the O(C) host planning primitives.

The load-bearing claims, each asserted here:

  * sparse <-> dense residual round-trip is LOSSLESS for every carry="ef"
    strategy's declared layout (hypothesis seed sweep over ties, signed
    zeros, and overflow widths);
  * the streaming "population" engine is bit-exact with the dense-carry
    "pop_scan" reference at small P — accuracies, comm times, and the full
    final residual matrix;
  * round state is O(C x n + P x k_max): the compiled round program's jaxpr
    contains no [P, ...] allocation, and the store's peak residency does not
    grow with P (the memory gate);
  * the chunked store spills through the checkpointer and restores
    bit-exactly, including after a save/restore with a read-only base;
  * host planning stays O(C): sparse survivor draws, LinkArrays slices, and
    the vectorized comm-time math all agree with their dense/scalar twins.
"""
import os
import shutil
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(__file__))
from hyputil import given, settings, st  # noqa: E402

from repro.core import bcrs as bcrs_mod  # noqa: E402
from repro.core import cost_model  # noqa: E402
from repro.core import strategies as strat_mod  # noqa: E402
from repro.core.aggregation import AggregationConfig  # noqa: E402
from repro.fed import engine as engine_mod  # noqa: E402
from repro.fed import mesh_round as mesh_mod  # noqa: E402
from repro.fed import population as pop_mod  # noqa: E402
from repro.fed import round_step as rs_mod  # noqa: E402
from repro.fed.simulation import FLSimConfig, plan_cohort, run_fl  # noqa: E402
from repro.ft.failures import FailureInjector  # noqa: E402

EF_STRATEGIES = tuple(n for n in strat_mod.names()
                      if strat_mod.get(n).carry == "ef")


# ------------------------------------------------ sparse layout round-trip
class TestSparseRoundTrip:
    def test_every_ef_strategy_declares_a_layout(self):
        assert EF_STRATEGIES, "registry lost its carry='ef' strategies"
        for name in EF_STRATEGIES:
            assert strat_mod.get(name).residual_layout in (
                "topk_complement", "dense")

    @staticmethod
    def _random_sparse_rows(rng, c, n, width):
        """Rows with nnz <= width, including exact ties and signed zeros."""
        rows = np.zeros((c, n), np.float32)
        for i in range(c):
            nnz = int(rng.integers(0, width + 1))
            cols = rng.choice(n, size=nnz, replace=False)
            vals = rng.normal(size=nnz).astype(np.float32)
            if nnz > 2:          # exact ties survive the stable argsort
                vals[1] = vals[0]
            rows[i, cols] = vals
        return rows

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_sparsify_densify_lossless(self, seed):
        rng = np.random.default_rng(seed)
        c = int(rng.integers(1, 6))
        n = int(rng.integers(4, 64))
        width = int(rng.integers(1, n + 1))
        rows = self._random_sparse_rows(rng, c, n, width)
        idx, val, overflow = engine_mod.sparsify_rows(jnp.asarray(rows),
                                                      width)
        assert not bool(overflow)
        back = np.asarray(engine_mod.densify_rows(idx, val, n))
        assert np.array_equal(back, rows)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_overflow_flagged_not_silent(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(8, 48))
        width = int(rng.integers(1, n - 1))
        rows = np.zeros((2, n), np.float32)
        cols = rng.choice(n, size=width + 1, replace=False)
        rows[0, cols] = rng.normal(size=width + 1).astype(np.float32)
        _, _, overflow = engine_mod.sparsify_rows(jnp.asarray(rows), width)
        assert bool(overflow)

    @pytest.mark.parametrize("strategy", EF_STRATEGIES)
    def test_store_round_trip_per_strategy(self, strategy):
        """Whatever layout a carry='ef' strategy declares, scattering a
        cohort's rows into a ClientStateStore and gathering them back is
        the identity."""
        layout = strat_mod.get(strategy).residual_layout
        rng = np.random.default_rng(3)
        n, width, p = 32, 12, 40
        store = pop_mod.ClientStateStore(p, n, layout=layout, width=width,
                                         chunk_clients=7)
        ids = np.array([0, 6, 7, 13, 39])
        if layout == "topk_complement":
            rows = self._random_sparse_rows(rng, len(ids), n, width)
            idx, val, ov = engine_mod.sparsify_rows(jnp.asarray(rows), width)
            assert not bool(ov)
            wire = (np.asarray(idx), np.asarray(val))
        else:
            rows = rng.normal(size=(len(ids), n)).astype(np.float32)
            wire = (rows,)
        store.scatter(ids, wire)
        back = store.gather(ids)
        for a, b in zip(wire, back):
            assert np.array_equal(a, b)
        dense = store.dump_dense()
        assert np.array_equal(dense[ids], rows)
        untouched = np.setdiff1d(np.arange(p), ids)
        assert not dense[untouched].any()


# ------------------------------------------------ store spill + restart
class TestStoreSpillRestart:
    def _fill(self, store, rng, p, n):
        mirror = np.zeros((p, n), np.float32)
        for lo in range(0, p, 10):
            ids = np.arange(lo, min(lo + 10, p))
            rows = rng.normal(size=(len(ids), n)).astype(np.float32)
            store.scatter(ids, (rows,))
            mirror[ids] = rows
        return mirror

    def test_spill_window_is_bounded_and_lossless(self, tmp_path):
        p, n = 64, 16
        rng = np.random.default_rng(0)
        store = pop_mod.ClientStateStore(
            p, n, layout="dense", chunk_clients=8, max_resident_chunks=2,
            spill_dir=str(tmp_path / "spill"))
        mirror = self._fill(store, rng, p, n)
        assert store.chunk_spills > 0
        # the LRU window, not the population, bounds residency
        assert store.resident_bytes() <= 2 * 8 * n * 4
        assert np.array_equal(store.dump_dense(), mirror)

    def test_save_restore_bit_exact_then_divergeable(self, tmp_path):
        p, n, width = 50, 24, 9
        rng = np.random.default_rng(1)
        store = pop_mod.ClientStateStore(p, n, layout="topk_complement",
                                         width=width, chunk_clients=6)
        ids = np.array([0, 5, 6, 17, 49])
        rows = TestSparseRoundTrip._random_sparse_rows(rng, len(ids), n,
                                                       width)
        idx, val, _ = engine_mod.sparsify_rows(jnp.asarray(rows), width)
        store.scatter(ids, (np.asarray(idx), np.asarray(val)))
        manifest = store.save(str(tmp_path), 4)
        before = store.dump_dense()

        restored = pop_mod.ClientStateStore.restore(
            str(tmp_path), 4, manifest,
            spill_dir=str(tmp_path / "spill"))
        assert np.array_equal(restored.dump_dense(), before)
        # a restored store is writable without touching the snapshot
        new_rows = TestSparseRoundTrip._random_sparse_rows(rng, 2, n, width)
        i2, v2, _ = engine_mod.sparsify_rows(jnp.asarray(new_rows), width)
        restored.scatter(np.array([5, 6]), (np.asarray(i2), np.asarray(v2)))
        again = pop_mod.ClientStateStore.restore(
            str(tmp_path), 4, manifest,
            spill_dir=str(tmp_path / "spill2"))
        assert np.array_equal(again.dump_dense(), before)

    def test_restore_refuses_rechunk(self, tmp_path):
        store = pop_mod.ClientStateStore(20, 8, layout="dense",
                                         chunk_clients=4)
        store.scatter(np.array([3]), (np.ones((1, 8), np.float32),))
        man = store.save(str(tmp_path), 0)
        with pytest.raises(ValueError, match="chunked"):
            pop_mod.ClientStateStore.restore(str(tmp_path), 0, man,
                                             chunk_clients=8)

    def test_snapshot_pruning_follows_retention(self, tmp_path):
        store = pop_mod.ClientStateStore(12, 8, layout="dense",
                                         chunk_clients=4)
        store.scatter(np.array([1]), (np.ones((1, 8), np.float32),))
        for step in (2, 4, 6):
            store.save(str(tmp_path), step)
        pop_mod.prune_client_snapshots(str(tmp_path), keep_steps=[4, 6])
        kept = sorted(d for d in os.listdir(str(tmp_path))
                      if d.startswith("clients_step_"))
        assert kept == ["clients_step_4", "clients_step_6"]


# ------------------------------------------- engine parity at small P
def _parity_sim(p=256, cohort=16, rounds=5):
    # feasibility: dirichlet_partition rejects until every client holds
    # >= batch_size samples, so n_train/P must comfortably exceed it at
    # the chosen beta (beta=1.0 keeps skew without starving any client)
    return FLSimConfig(n_clients=p, participation=cohort / p, rounds=rounds,
                       n_train=p * 24, n_test=200, batch_size=4, beta=1.0,
                       dim=16, hidden=16, n_classes=5, eval_every=2, seed=11)


class TestEngineParity:
    @pytest.mark.slow
    @pytest.mark.parametrize("strategy", EF_STRATEGIES)
    def test_population_matches_pop_scan_bit_exact(self, strategy):
        """P=256, C=16: the streaming store engine reproduces the dense
        [P+1, n]-carry scan reference exactly — accuracies, per-round comm
        times, and every client's final residual row."""
        sim = _parity_sim()
        acfg = AggregationConfig(strategy=strategy, cr=0.25)
        ref = run_fl(sim, acfg, engine="pop_scan")
        res = run_fl(sim, acfg, engine="population")
        assert [a for _, a in ref.accuracies] == \
            [a for _, a in res.accuracies]
        for t_ref, t_pop in zip(ref.times.per_round, res.times.per_round):
            assert (t_ref.actual, t_ref.max, t_ref.min) == \
                (t_pop.actual, t_pop.max, t_pop.min)
        assert ref.final_residuals is not None
        assert ref.final_residuals.shape[0] == sim.n_clients
        assert np.array_equal(ref.final_residuals, res.final_residuals)
        assert ref.final_residuals.any()   # EF state actually accumulated

    def test_population_engine_refuses_overlap_collection(self):
        sim = _parity_sim(p=32, cohort=4, rounds=2)
        with pytest.raises(ValueError, match="overlap"):
            run_fl(sim, AggregationConfig(strategy="eftopk", cr=0.25),
                   engine="population", collect_overlap=True)


# ----------------------------------------------------------- memory gate
def _all_avals(jaxpr, out):
    for eqn in jaxpr.eqns:
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                out.append(aval)
        for sub in jax.core.subjaxprs(eqn.jaxpr) if hasattr(
                eqn, "jaxpr") else ():
            _all_avals(sub, out)
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", param)
            if hasattr(inner, "eqns"):
                _all_avals(inner, out)
    return out


class TestMemoryGate:
    def test_round_program_has_no_population_sized_aval(self):
        """The tier-1 O(C x n + P x k_max) gate: trace the population round
        program for a HUGE P and assert the jaxpr never materializes an
        array with a P-sized dimension — state entering the jit is the
        cohort slots plus the sparse wire rows, nothing scaled by P."""
        huge_p = 1_000_000
        c, dim, hidden, classes, s, b = 8, 16, 16, 5, 2, 4
        from repro.fed.simulation import mlp_init, mlp_loss
        params = mlp_init(jax.random.PRNGKey(0), dim, classes, hidden=hidden)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        acfg = AggregationConfig(strategy="eftopk", cr=0.25)
        from repro.core.compression import k_for_ratio
        width = pop_mod.residual_width(n, k_for_ratio(n, acfg.cr))
        step = rs_mod.make_population_round_step(
            mlp_loss, params, lr=0.05, acfg=acfg, width=width)
        flat = jnp.zeros((n,), jnp.float32)
        res = step.init_residuals(c, n)
        x = {"step_mask": jnp.ones((c, s), bool),
             "active": jnp.ones((c,), bool),
             "weights": jnp.full((c,), 1.0 / c, jnp.float32),
             "ks": jnp.full((c,), k_for_ratio(n, acfg.cr), jnp.int32),
             "batches": {"x": jnp.zeros((c, s, b, dim), jnp.float32),
                         "y": jnp.zeros((c, s, b), jnp.int32)}}
        closed = jax.make_jaxpr(step._fn)(flat, res, x)
        avals = _all_avals(closed.jaxpr, [])
        assert avals
        biggest = max(int(np.prod(a.shape)) for a in avals)
        # nothing in the program is within two orders of magnitude of a
        # [P]-sized buffer, let alone [P, n]
        assert biggest < huge_p // 100, (
            f"population round program allocates {biggest} elements")
        assert all(huge_p not in a.shape for a in avals)

    def test_store_residency_flat_in_population(self, tmp_path):
        """Same rounds, same cohort, 8x the population: identical compiled
        program (TRACE_COUNTS grows by exactly 1 across both runs) and
        identical peak host state bytes — the store's window, not P, is
        the bound."""
        acfg = AggregationConfig(strategy="eftopk", cr=0.2)
        cfg = pop_mod.PopulationRunConfig(cohort=6, rounds=4, dim=16,
                                          hidden=16, n_classes=5, seed=5)
        traces0 = rs_mod.TRACE_COUNTS[("population", "eftopk")]
        peaks = {}
        step = None
        for p in (512, 4096):
            pop = pop_mod.make_population(p, seed=5)
            res, step, store = pop_mod.run_population_rounds(
                pop, cfg, acfg=acfg, step=step, chunk_clients=1,
                max_resident_chunks=8,
                spill_dir=str(tmp_path / f"spill_{p}"))
            peaks[p] = res.peak_state_bytes
            assert store.chunk_spills > 0   # the window actually evicted
        assert rs_mod.TRACE_COUNTS[("population", "eftopk")] - traces0 == 1
        assert peaks[4096] == peaks[512]


# ------------------------------------------------------ O(C) host planning
class TestHostPlanning:
    def test_survivors_at_deterministic_and_per_id(self):
        inj = FailureInjector(p_fail=0.4, seed=9)
        ids = np.array([3, 999_999, 17, 400_000])
        a = inj.survivors_at(2, ids)
        b = inj.survivors_at(2, ids)
        assert np.array_equal(a, b)
        # per-id keying: a client's fate depends only on (seed, round, id),
        # never on who else was sampled alongside it (cohort revive aside).
        # Golden re-pinned once to the vectorized counter_uniform stream
        # (splitmix64 v1) when the per-id default_rng loop was replaced.
        from repro.ft.failures import counter_uniform
        raw = counter_uniform(inj.seed, 2, ids) >= inj.p_fail
        assert raw.any()     # draw produced survivors, so no revive fired
        assert np.array_equal(a, raw)
        perm = np.array([17, 3])
        sub = inj.survivors_at(2, perm)
        assert sub.tolist() == [bool(raw[2]), bool(raw[0])]

    def test_survivors_at_scheduled_and_revive(self):
        inj = FailureInjector(p_fail=0.0, scheduled=[(1, 42)], seed=0)
        ids = np.array([7, 42, 99])
        alive = inj.survivors_at(1, ids)
        assert alive.tolist() == [True, False, True]
        dead = FailureInjector(p_fail=1.0, seed=0)
        alive = dead.survivors_at(0, ids)
        assert alive.tolist() == [True, False, False]   # never lose everyone

    def test_link_arrays_match_sample_links(self):
        a = cost_model.sample_link_arrays(40, np.random.default_rng(3))
        b = cost_model.sample_links(40, np.random.default_rng(3))
        for i in (0, 7, 39):
            assert a[i].bandwidth_bps == b[i].bandwidth_bps
            assert a[i].latency_s == b[i].latency_s
        sub = a.take(np.array([2, 5]))
        assert sub.bandwidth_bps.tolist() == [a.bandwidth_bps[2],
                                              a.bandwidth_bps[5]]

    def test_comm_time_batch_bitwise_matches_scalar(self):
        rng = np.random.default_rng(4)
        bw = rng.uniform(0.5e6, 20e6, 32)
        lat = rng.uniform(0.01, 0.3, 32)
        crs = rng.uniform(0.01, 1.0, 32)
        v = 4.0 * 12345
        batch = bcrs_mod.comm_time_batch(v, bw, lat, crs)
        scalar = np.array([
            bcrs_mod.comm_time(v, cost_model.ClientLink(
                bandwidth_bps=b, latency_s=l), cr)
            for b, l, cr in zip(bw, lat, crs)])
        assert np.array_equal(batch, scalar)

    def test_plan_cohort_population_mode(self):
        p, c = 100_000, 12
        links = cost_model.sample_link_arrays(p, np.random.default_rng(0))
        fracs = np.full(p, 1.0 / p)
        acfg = AggregationConfig(strategy="eftopk", cr=0.2)
        inj = FailureInjector(p_fail=0.3, seed=1)
        rng = np.random.default_rng(8)
        out = plan_cohort(3, rng, n_clients=p, participation=1.0,
                          fracs_all=fracs, links=links, v_bytes=4e4,
                          acfg=acfg, failure=inj, cohort=c,
                          sparse_failures=True)
        assert out is not None
        sel, fr = out
        assert 1 <= len(sel) <= c
        assert len(np.unique(sel)) == len(sel)
        assert sel.max() < p
        np.testing.assert_allclose(fr.sum(), 1.0)
        # deterministic under the same rng stream
        sel2, _ = plan_cohort(3, np.random.default_rng(8), n_clients=p,
                              participation=1.0, fracs_all=fracs,
                              links=links, v_bytes=4e4, acfg=acfg,
                              failure=inj, cohort=c, sparse_failures=True)
        assert np.array_equal(sel, sel2)

    def test_sample_cohort_unique_and_bounded(self):
        ids = pop_mod.sample_cohort(np.random.default_rng(0), 1_000_000, 16)
        assert len(ids) == 16 and len(np.unique(ids)) == 16
        small = pop_mod.sample_cohort(np.random.default_rng(0), 8, 16)
        assert len(small) == 8


# --------------------------------------------------- mesh per-leaf adapter
class TestMeshPopulationStep:
    @pytest.mark.parametrize("strategy", ("eftopk", "qtopk", "bcrs_opwa",
                                          "fedavg"))
    def test_parity_with_mesh_round_step(self, strategy):
        """The flat-wire population step reproduces the per-leaf reference
        exactly: params, loss, and the densified residual rows."""
        rng = np.random.default_rng(0)
        params = {"w1": jnp.asarray(rng.normal(size=(6, 5)).astype(
            np.float32)),
            "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32)),
            "w2": jnp.asarray(rng.normal(size=(5, 3)).astype(np.float32))}
        n_total = sum(l.size for l in jax.tree.leaves(params))

        def loss_fn(p, batch):
            x, y = batch
            h = jnp.tanh(x @ p["w1"] + p["b"])
            logits = h @ p["w2"]
            one = jax.nn.one_hot(y, 3)
            ll = jnp.sum(one * jax.nn.log_softmax(logits), -1)
            return -jnp.mean(ll), None

        c, s, b = 4, 3, 8
        batches = (jnp.asarray(rng.normal(size=(c, s, b, 6)).astype(
            np.float32)),
            jnp.asarray(rng.integers(0, 3, size=(c, s, b))))
        step_mask = jnp.asarray(np.array(
            [[1, 1, 1], [1, 1, 0], [1, 0, 0], [0, 0, 0]], bool))
        coeffs = jnp.asarray(np.array([0.4, 0.3, 0.3, 0.0], np.float32))
        crs = jnp.asarray(np.array([0.3, 0.5, 0.25, 0.3], np.float32))
        active = jnp.asarray(np.array([1, 1, 1, 0], bool))
        width = mesh_mod.mesh_residual_width(params, 0.25)

        strat = strat_mod.get(strategy)
        ef = strat.needs_residuals
        layout = strat.residual_layout if ef else None
        ref = mesh_mod.make_mesh_round_step(
            loss_fn, strategy=strategy, lr_local=0.05, use_kernel=False,
            donate=False)
        pop = mesh_mod.make_population_round_step(
            loss_fn, params, strategy=strategy, lr_local=0.05,
            use_kernel=False, width=width, donate=False)

        if ef:
            rows = TestSparseRoundTrip._random_sparse_rows(
                rng, c, n_total, width // 2)
            res_template = jax.tree.map(
                lambda l: jnp.zeros(l.shape, jnp.float32), params)
            unf = engine_mod.make_unflatten(res_template)
            res_tree = jax.vmap(unf)(jnp.asarray(rows))
            if layout == "topk_complement":
                idx, val, ov = engine_mod.sparsify_rows(jnp.asarray(rows),
                                                        width)
                assert not bool(ov)
                wire = (idx, val)
            else:
                wire = jnp.asarray(rows)
        else:
            res_tree, wire = None, jnp.zeros((0,), jnp.float32)

        p_ref, r_ref, l_ref = ref(params, res_tree, batches, step_mask,
                                  coeffs, crs, active)
        p_pop, w_pop, l_pop, ov = pop(params, wire, batches, step_mask,
                                      coeffs, crs, active)
        assert not bool(ov)
        for a, b2 in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_pop)):
            assert np.array_equal(np.asarray(a), np.asarray(b2))
        assert float(l_ref) == float(l_pop)
        if ef:
            rows_ref = np.asarray(engine_mod.flatten_client_trees(r_ref))
            if layout == "topk_complement":
                rows_pop = np.asarray(engine_mod.densify_rows(
                    *w_pop, n_total))
            else:
                rows_pop = np.asarray(w_pop)
            assert np.array_equal(rows_ref, rows_pop)

    def test_width_requires_positive_for_sparse(self):
        def loss_fn(p, batch):
            return jnp.float32(0.0), None
        with pytest.raises(ValueError, match="width"):
            mesh_mod.make_population_round_step(
                loss_fn, {"w": jnp.zeros((4,))}, strategy="eftopk", width=0)


# ------------------------------------------- fl_train streaming restart
class TestFLTrainPopulation:
    @pytest.mark.slow
    def test_restart_bit_exact_including_sparse_store(self, tmp_path):
        """Kill-and-resume of the real-model streaming driver: the resumed
        run's params, losses, and every client's persisted residual match
        an uninterrupted one bitwise."""
        from repro.launch import fl_train as flt
        base = dict(arch="stablelm-1.6b", reduced=True, clients=2,
                    local_steps=1, batch=2, seq=16, lr=0.05, seed=0,
                    verbose=False, strategy="eftopk", population=24,
                    cohort=3, fail_prob=0.25, checkpoint_every=2)
        d = str(tmp_path / "ckpt")
        full = flt.run(flt.FLTrainConfig(rounds=4, checkpoint_dir=d, **base))
        shutil.rmtree(d)
        flt.run(flt.FLTrainConfig(rounds=2, checkpoint_dir=d, **base))
        resumed = flt.run(flt.FLTrainConfig(rounds=4, checkpoint_dir=d,
                                            **base))
        assert resumed["resumed_from"] == 2
        for a, b in zip(jax.tree.leaves(full["params"]),
                        jax.tree.leaves(resumed["params"])):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert full["losses"][2:] == resumed["losses"]
        assert np.array_equal(full["store"].dump_dense(),
                              resumed["store"].dump_dense())
        assert full["store"].dump_dense().any()

    def test_config_validation(self):
        from repro.launch import fl_train as flt
        with pytest.raises(ValueError, match="cohort"):
            flt.FLTrainConfig(population=4, cohort=8)
        cfg = flt.FLTrainConfig(population=100, clients=5)
        assert cfg.cohort == 5 and cfg.c_slots == 5
        assert cfg.n_registered == 100
