"""HLO cost model + roofline unit tests (the §Roofline measurement layer)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis
from repro.roofline.hlo_cost import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestHLOCost:
    def test_matmul_exact(self):
        co = _compile(lambda a, b: a @ b,
                      jax.ShapeDtypeStruct((128, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 128), jnp.float32))
        c = analyze_hlo(co.as_text(), 1)
        assert c.flops == 2 * 128 ** 3

    def test_scan_trip_count(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out
        co = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 128), jnp.float32))
        c = analyze_hlo(co.as_text(), 1)
        assert c.flops == 7 * 2 * 128 ** 3

    def test_nested_scan(self):
        def f(x, w):
            def inner(c, _):
                return c @ w, None
            def outer(c, _):
                c2, _ = jax.lax.scan(inner, c, None, length=5)
                return c2, None
            out, _ = jax.lax.scan(outer, x, None, length=3)
            return out
        co = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                      jax.ShapeDtypeStruct((128, 128), jnp.float32))
        c = analyze_hlo(co.as_text(), 1)
        assert c.flops == 15 * 2 * 128 ** 3

    def test_bytes_reasonable(self):
        co = _compile(lambda a, b: jnp.tanh(a @ b),
                      jax.ShapeDtypeStruct((256, 256), jnp.float32),
                      jax.ShapeDtypeStruct((256, 256), jnp.float32))
        c = analyze_hlo(co.as_text(), 1)
        ideal = 3 * 256 * 256 * 4
        assert ideal <= c.bytes <= 4 * ideal


class TestCollectiveParsing:
    def test_iota_groups(self):
        groups = analysis._parse_groups(
            "replica_groups=[4,16]<=[64]", 64)
        assert len(groups) == 4 and all(len(g) == 16 for g in groups)
        assert groups[0].tolist() == list(range(16))

    def test_transposed_iota_groups(self):
        groups = analysis._parse_groups(
            "replica_groups=[16,4]<=[4,16]T(1,0)", 64)
        assert len(groups) == 16 and all(len(g) == 4 for g in groups)
        # transpose: group 0 = devices 0,16,32,48
        assert groups[0].tolist() == [0, 16, 32, 48]

    def test_wire_factors(self):
        text = ("ENTRY %main (p: f32[64]) -> f32[64] {\n"
                "  %p = f32[64]{0} parameter(0)\n"
                "  ROOT %ar = f32[64]{0} all-reduce(%p), "
                "replica_groups=[1,4]<=[4], to_apply=%add\n}\n")
        s = analysis.parse_collectives(text, 4)
        assert len(s.ops) == 1
        assert s.ops[0].wire_bytes_per_device == pytest.approx(
            2 * 3 / 4 * 64 * 4)

    def test_cross_pod_classification(self):
        text = ("ENTRY %main (p: f32[64]) -> f32[64] {\n"
                "  %p = f32[64]{0} parameter(0)\n"
                "  ROOT %ar = f32[64]{0} all-reduce(%p), "
                "replica_groups=[1,512]<=[512], to_apply=%add\n}\n")
        s = analysis.parse_collectives(text, 512, pod_size=256)
        assert s.ops[0].cross_pod


class TestKernelBytes:
    """Merge-hot-path traffic accounting (repro.roofline.kernel_bytes)."""

    def test_megakernel_traffic_model(self):
        from repro.kernels.threshold_find import SWEEPS
        from repro.roofline.kernel_bytes import megakernel_hbm_bytes
        c, n = 8, 1 << 14          # already tile-aligned
        b = megakernel_hbm_bytes(c, n, "topk")
        mat = c * n * 4
        # SWEEPS streamed reads + 1 merge read + the [n] aggregate write
        assert b["total"] == pytest.approx(
            (SWEEPS + 1) * mat + n * 4, rel=0.01)
        ef = megakernel_hbm_bytes(c, n, "eftopk")
        # EF doubles the streamed operands and adds the residual write
        assert ef["total"] == pytest.approx(
            2 * (SWEEPS + 1) * mat + n * 4 + mat, rel=0.01)

    def test_merge_ratio_exceeds_3x(self):
        from repro.fed.engine import ClientUpdateSpec
        from repro.roofline.kernel_bytes import merge_traffic_ratio
        for strategy in ("bcrs_opwa", "eftopk"):
            spec = ClientUpdateSpec(strategy=strategy, gamma=5.0,
                                    use_kernel=False)
            r = merge_traffic_ratio(spec, 8, 1 << 13)
            assert r["ratio"] >= 3.0, r
            # the trip-count-aware baseline must see the 32-iteration
            # bisection that XLA's cost_analysis hides
            assert (r["unfused"]["passes"]
                    > 3 * r["unfused"]["xla_cost_analysis_passes"])


class TestModelFlops:
    def test_train_vs_decode(self):
        from repro.configs import SHAPES, get_config
        cfg = get_config("yi-9b")
        tr = analysis.model_flops(cfg, SHAPES["train_4k"])
        de = analysis.model_flops(cfg, SHAPES["decode_32k"])
        assert tr == pytest.approx(6 * cfg.n_params() * 256 * 4096, rel=1e-6)
        assert de == pytest.approx(2 * cfg.n_params() * 128, rel=1e-6)

    def test_moe_uses_active(self):
        from repro.configs import SHAPES, get_config
        cfg = get_config("deepseek-v3-671b")
        tr = analysis.model_flops(cfg, SHAPES["train_4k"])
        assert tr < 6 * cfg.n_params() * 256 * 4096 * 0.2  # active << total
