"""Fused round program tests: parity with the legacy per-client loop,
EF bit-compatibility, O(1) compile behavior, traced-k correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core.aggregation import (AggregationConfig, compress_clients,
                                    compress_clients_loop, round_schedule)
from repro.fed import round_step
from repro.fed.simulation import FLSimConfig, run_fl

FAST = dict(rounds=8, n_train=2000, n_test=600, eval_every=2, seed=3)


def _accs(res):
    return np.array([a for _, a in res.accuracies])


class TestFusedParity:
    """Same seed -> fused and legacy engines see identical data streams and
    schedules; accuracies must match within 1e-3 (observed: bit-exact)."""

    @pytest.mark.parametrize("strategy,kw", [
        ("fedavg", {}),
        ("topk", dict(cr=0.05)),
        ("eftopk", dict(cr=0.05)),
        ("bcrs", dict(cr=0.05)),
        ("bcrs_opwa", dict(cr=0.05, gamma=5.0)),
    ])
    def test_accuracy_parity(self, strategy, kw):
        acfg = AggregationConfig(strategy=strategy, **kw)
        legacy = run_fl(FLSimConfig(**FAST), acfg, fused=False)
        fused = run_fl(FLSimConfig(**FAST), acfg, fused=True)
        np.testing.assert_allclose(_accs(fused), _accs(legacy), atol=1e-3)
        # host-side schedules are shared -> identical comm-time accounting
        assert fused.times.actual == pytest.approx(legacy.times.actual,
                                                   rel=1e-9)

    def test_overlap_histogram_parity(self):
        acfg = AggregationConfig(strategy="topk", cr=0.05)
        legacy = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                        fused=False)
        fused = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                       fused=True)
        np.testing.assert_array_equal(fused.overlap_hist, legacy.overlap_hist)

    def test_overlap_histogram_parity_fedavg(self):
        """fedavg has no schedule CRs; the overlap instrumentation must
        fall back to acfg.cr in both engines (not the all-ones schedule)."""
        acfg = AggregationConfig(strategy="fedavg", cr=0.05)
        legacy = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                        fused=False)
        fused = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                       fused=True)
        np.testing.assert_array_equal(fused.overlap_hist, legacy.overlap_hist)

    def test_failure_injection_fused(self):
        from repro.ft import FailureInjector
        acfg = AggregationConfig(strategy="bcrs", cr=0.05)
        inj = FailureInjector(p_fail=0.3, seed=1)
        res = run_fl(FLSimConfig(**FAST), acfg, failure=inj, fused=True)
        assert res.final_accuracy > 0.35


class TestTimeToAccuracy:
    def _result(self, executed, accs):
        from repro.core.cost_model import RoundTime, TimeAccumulator
        from repro.fed.simulation import FLSimResult
        times = TimeAccumulator()
        for _ in executed:
            times.add(RoundTime(actual=1.0, max=1.0, min=1.0))
        return FLSimResult(accuracies=accs, times=times,
                           executed_rounds=list(executed))

    def test_includes_hitting_round(self):
        res = self._result([0, 1, 2, 3], [(0, 0.1), (2, 0.5)])
        # rounds 0,1,2 executed by the time accuracy hits at round 2
        assert res.time_to_accuracy(0.5) == pytest.approx(3.0)

    def test_skipped_rounds_not_counted(self):
        # round 2 skipped by failure injection: no time entry for it
        res = self._result([0, 1, 3, 4], [(0, 0.1), (4, 0.5)])
        assert res.time_to_accuracy(0.5) == pytest.approx(4.0)
        assert res.time_to_accuracy(0.9) is None


class TestEFBitCompatibility:
    """The vectorized traced-k EF path must reproduce the legacy per-client
    static-CR loop bit for bit (values, masks, and residuals)."""

    def _updates(self, k=4, n=5000, seed=0):
        key = jax.random.PRNGKey(seed)
        ku, kr = jax.random.split(key)
        return (jax.random.normal(ku, (k, n)),
                jax.random.normal(kr, (k, n)) * 0.1)

    def test_ef_residuals_bitwise(self):
        updates, residuals = self._updates()
        crs = np.array([0.01, 0.1, 0.5, 1.0])
        acfg = AggregationConfig(strategy="eftopk")
        v1, m1, r1 = compress_clients_loop(updates, crs, acfg, residuals)
        v2, m2, r2 = compress_clients(updates, crs, acfg, residuals)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))

    def test_plain_compress_bitwise(self):
        updates, _ = self._updates(seed=5)
        crs = np.array([0.02, 0.3, 0.9, 1.0])
        acfg = AggregationConfig(strategy="bcrs")
        v1, m1, _ = compress_clients_loop(updates, crs, acfg)
        v2, m2, _ = compress_clients(updates, crs, acfg)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_block_compress_bitwise(self):
        updates, _ = self._updates(n=10000, seed=6)
        crs = np.array([0.05, 0.2, 0.7, 1.0])
        acfg = AggregationConfig(strategy="bcrs", block_topk=True,
                                 block_size=2048, use_kernel=False)
        v1, m1, _ = compress_clients_loop(updates, crs, acfg)
        v2, m2, _ = compress_clients(updates, crs, acfg)
        np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


class TestCompileCount:
    """One fused simulation = O(1) traces of the round program, independent
    of rounds and cohort size (trace-cache inspection via TRACE_COUNTS)."""

    def _traces(self):
        return sum(round_step.TRACE_COUNTS.values())

    def _run(self, rounds, n_clients):
        cfg = FLSimConfig(rounds=rounds, n_clients=n_clients,
                          n_train=2000, n_test=300, eval_every=100, seed=1)
        before = self._traces()
        run_fl(cfg, AggregationConfig(strategy="bcrs_opwa", cr=0.05),
               fused=True)
        return self._traces() - before

    def test_constant_in_rounds(self):
        t_short = self._run(rounds=3, n_clients=8)
        t_long = self._run(rounds=12, n_clients=8)
        assert t_short == t_long == 1

    def test_constant_in_clients(self):
        t_small = self._run(rounds=4, n_clients=6)
        t_big = self._run(rounds=4, n_clients=12)
        assert t_small == t_big == 1

    def test_overlap_variant_adds_one_trace(self):
        cfg = FLSimConfig(rounds=6, n_clients=8, n_train=2000, n_test=300,
                          eval_every=100, seed=2)
        before = self._traces()
        run_fl(cfg, AggregationConfig(strategy="topk", cr=0.1),
               collect_overlap=True, fused=True)
        assert self._traces() - before == 2  # plain step + overlap variant


class TestDynamicVsStatic:
    """Deterministic (non-hypothesis) equivalence sweep: the integer-bit
    bisection must reproduce exact static top-k masks, including the
    CR -> 1 (k = n) edge where a value-space bisection loses exactness."""

    @pytest.mark.parametrize("n,k", [(16, 1), (100, 7), (1000, 100),
                                     (1000, 999), (1000, 1000),
                                     (4096, 4096), (5000, 1)])
    def test_mask_equals_static(self, n, k, seed=0):
        u = jax.random.normal(jax.random.PRNGKey(seed + n + k), (n,))
        dyn = C.topk_compress_dynamic(u, jnp.int32(k))
        mag = jnp.abs(u)
        thresh = jax.lax.top_k(mag, k)[0][-1]
        np.testing.assert_array_equal(np.asarray(dyn.mask),
                                      np.asarray(mag >= thresh))
        np.testing.assert_array_equal(np.asarray(dyn.values),
                                      np.asarray(jnp.where(mag >= thresh,
                                                           u, 0)))

    def test_ties_kept(self):
        u = jnp.asarray([1.0, -1.0, 1.0, 0.5, 2.0])
        dyn = C.topk_compress_dynamic(u, jnp.int32(2))
        # threshold is 1.0; all three tied magnitudes stay (static semantics)
        np.testing.assert_array_equal(np.asarray(dyn.mask),
                                      [True, True, True, False, True])

    def test_batch_matches_per_row(self):
        u = jax.random.normal(jax.random.PRNGKey(9), (5, 777))
        ks = jnp.asarray([1, 10, 100, 776, 777], jnp.int32)
        batch = C.topk_compress_batch(u, ks)
        for i in range(5):
            one = C.topk_compress_dynamic(u[i], ks[i])
            np.testing.assert_array_equal(np.asarray(batch.mask[i]),
                                          np.asarray(one.mask))


class TestRoundScheduleHelper:
    def test_fedavg_has_no_crs(self):
        crs, w, info = round_schedule(AggregationConfig(strategy="fedavg"),
                                      4, np.full(4, 0.25))
        assert "crs" not in info          # time accounting falls back to CR=1
        np.testing.assert_allclose(crs, 1.0)

    def test_bcrs_matches_make_schedule(self):
        from repro.core import bcrs as bcrs_mod
        rng = np.random.default_rng(0)
        from repro.core.cost_model import sample_links
        links = sample_links(4, rng)
        fr = np.full(4, 0.25)
        acfg = AggregationConfig(strategy="bcrs", cr=0.05, alpha=1.0)
        crs, w, info = round_schedule(acfg, 4, fr, links, v_bytes=1e6)
        sched = bcrs_mod.make_schedule(links, fr, 1e6, 0.05, 1.0)
        np.testing.assert_allclose(crs, sched.crs)
        np.testing.assert_allclose(w, sched.coefficients)
        assert info["t_bench"] == sched.t_bench
