"""Fault tolerance: straggler policy, failure injection, elastic pool,
checkpoint-restart of the training drivers."""
import subprocess
import sys

import numpy as np
import pytest

from repro.ft import (ElasticPool, FailureInjector, StragglerPolicy, arrivals,
                      over_select, renormalize_coefficients)


class TestStraggler:
    def test_over_select(self):
        assert over_select(8, StragglerPolicy(over_selection=0.25)) == 10

    def test_arrivals_picks_fastest(self):
        times = [5.0, 1.0, 2.0, 9.0, 3.0]
        chosen, dur = arrivals(times, 3, StragglerPolicy())
        assert chosen.tolist() == [False, True, True, False, True]
        assert dur == 3.0

    def test_renormalize_preserves_total(self):
        c = np.array([0.4, 0.3, 0.2, 0.1])
        arrived = np.array([True, False, True, True])
        out = renormalize_coefficients(c, arrived)
        assert out[1] == 0
        assert out.sum() == pytest.approx(c.sum())

    def test_deadline_cuts_below_target(self):
        """The deadline excludes stragglers even when fewer than n_target
        have arrived — only the fastest finisher is guaranteed a slot."""
        times = [1.0, 1.2, 40.0, 50.0, 60.0]   # median 40 -> deadline 60
        pol = StragglerPolicy(deadline_factor=0.1)  # deadline 4.0
        chosen, dur = arrivals(times, 4, pol)
        assert chosen.tolist() == [True, True, False, False, False]
        assert dur == 1.2
        # degenerate: everyone past the deadline -> the fastest still runs
        chosen, dur = arrivals([10.0, 20.0], 2,
                               StragglerPolicy(deadline_factor=0.01))
        assert chosen.tolist() == [True, False]
        assert dur == 10.0

    def test_deadline_host_traced_parity(self):
        """`arrivals` (host) and `arrival_mask_traced` (in-jit) agree on
        the arrived set under the same deadline policy, infs included."""
        import jax.numpy as jnp
        from repro.ft.straggler import arrival_mask_traced
        rng = np.random.default_rng(11)
        pol = StragglerPolicy(deadline_factor=1.2)
        for _ in range(20):
            t = rng.exponential(2.0, size=8)
            t[rng.random(8) < 0.2] = np.inf
            if not np.isfinite(t).any():
                continue
            n_target = int(rng.integers(1, 9))
            finite = np.isfinite(t)
            host, _ = arrivals(t[finite], n_target, pol)
            host_full = np.zeros(8, bool)
            host_full[finite] = host
            traced = np.asarray(arrival_mask_traced(
                jnp.asarray(t, jnp.float32), n_target, pol))
            np.testing.assert_array_equal(traced, host_full)


class TestFailures:
    def test_injector_deterministic(self):
        inj = FailureInjector(p_fail=0.5, seed=7)
        a = inj.survivors(3, 10)
        b = inj.survivors(3, 10)
        np.testing.assert_array_equal(a, b)
        assert a.any()  # never kills everyone

    def test_scheduled_failure(self):
        inj = FailureInjector(scheduled=[(2, 5)])
        alive = inj.survivors(2, 10)
        assert not alive[5]
        assert inj.survivors(3, 10)[5]

    def test_elastic_pool(self):
        pool = ElasticPool(n_registered=10)
        pool.scale(+6)
        sel = pool.sample(0.5, np.random.default_rng(0))
        assert len(sel) == 8 and sel.max() < 16
        pool.scale(-12)
        assert pool.n_registered == 4


@pytest.mark.slow
class TestRestartDrivers:
    def test_train_resume(self, tmp_path):
        """Kill-and-restart: the driver resumes from the checkpoint."""
        cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
               "stablelm-1.6b", "--reduced", "--batch", "2", "--seq", "32",
               "--checkpoint-dir", str(tmp_path), "--checkpoint-every", "5"]
        r1 = subprocess.run(cmd + ["--steps", "5"], capture_output=True,
                            text=True, env=_env())
        assert r1.returncode == 0, r1.stderr[-2000:]
        r2 = subprocess.run(cmd + ["--steps", "10"], capture_output=True,
                            text=True, env=_env())
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resumed from step 5" in r2.stdout

    def test_fl_train_runs_with_failures(self, tmp_path):
        cmd = [sys.executable, "-m", "repro.launch.fl_train", "--arch",
               "stablelm-1.6b", "--reduced", "--rounds", "3", "--clients",
               "4", "--batch", "2", "--seq", "32", "--fail-prob", "0.3",
               "--checkpoint-dir", str(tmp_path)]
        r = subprocess.run(cmd, capture_output=True, text=True, env=_env())
        assert r.returncode == 0, r.stderr[-2000:]
        assert "done" in r.stdout


def _env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return env
