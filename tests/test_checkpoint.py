"""Checkpoint: atomicity, integrity, retention, restart, bf16 round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (32, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jax.random.normal(k, (8,)).astype(jnp.bfloat16)},
            "scalar": jnp.float32(3.5)}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        ckpt.save(str(tmp_path), 5, t)
        restored, step, extra = ckpt.restore(str(tmp_path), t)
        assert step == 5
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_and_retention(self, tmp_path):
        t = _tree()
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(str(tmp_path), s, t, keep=3)
        assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_extra_metadata(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(), extra={"arch": "x", "lr": 0.1})
        _, _, extra = ckpt.restore(str(tmp_path), _tree())
        assert extra == {"arch": "x", "lr": 0.1}

    def test_corruption_detected(self, tmp_path):
        path = ckpt.save(str(tmp_path), 1, _tree())
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with pytest.raises(Exception):
            ckpt.restore(str(tmp_path), _tree())

    def test_no_tmp_left_behind(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree())
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]

    def test_restore_specific_step(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        ckpt.save(str(tmp_path), 2, _tree(1), keep=5)
        r1, step, _ = ckpt.restore(str(tmp_path), _tree(), step=1)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(r1["a"]),
                                      np.asarray(_tree(0)["a"]))
