"""Checkpoint: atomicity, integrity, retention, restart, bf16 round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (32, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jax.random.normal(k, (8,)).astype(jnp.bfloat16)},
            "scalar": jnp.float32(3.5)}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        ckpt.save(str(tmp_path), 5, t)
        restored, step, extra = ckpt.restore(str(tmp_path), t)
        assert step == 5
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_and_retention(self, tmp_path):
        t = _tree()
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(str(tmp_path), s, t, keep=3)
        assert ckpt.list_steps(str(tmp_path)) == [3, 4, 5]
        assert ckpt.latest_step(str(tmp_path)) == 5

    def test_extra_metadata(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(), extra={"arch": "x", "lr": 0.1})
        _, _, extra = ckpt.restore(str(tmp_path), _tree())
        assert extra == {"arch": "x", "lr": 0.1}

    def test_corruption_detected(self, tmp_path):
        path = ckpt.save(str(tmp_path), 1, _tree())
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))
        with pytest.raises(Exception):
            ckpt.restore(str(tmp_path), _tree())

    def test_no_tmp_left_behind(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree())
        assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]

    def test_restore_specific_step(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        ckpt.save(str(tmp_path), 2, _tree(1), keep=5)
        r1, step, _ = ckpt.restore(str(tmp_path), _tree(), step=1)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(r1["a"]),
                                      np.asarray(_tree(0)["a"]))


class TestRestoreLatestValid:
    """Graceful degradation on corruption: fall back to the newest INTACT
    step with a warning instead of crashing the resumed run."""

    def _corrupt(self, tmp_path, step):
        path = os.path.join(str(tmp_path), f"step_{step}.msgpack")
        raw = bytearray(open(path, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        open(path, "wb").write(bytes(raw))

    def test_falls_back_past_corrupt_latest(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        ckpt.save(str(tmp_path), 2, _tree(1), keep=5)
        self._corrupt(tmp_path, 2)
        with pytest.warns(RuntimeWarning, match="corrupt checkpoint"):
            tree, step, _ = ckpt.restore_latest_valid(str(tmp_path), _tree())
        assert step == 1
        np.testing.assert_array_equal(np.asarray(tree["a"]),
                                      np.asarray(_tree(0)["a"]))

    def test_truncated_latest(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        path = ckpt.save(str(tmp_path), 2, _tree(1), keep=5)
        raw = open(path, "rb").read()
        open(path, "wb").write(raw[: len(raw) // 3])   # torn write
        with pytest.warns(RuntimeWarning):
            _, step, _ = ckpt.restore_latest_valid(str(tmp_path), _tree())
        assert step == 1

    def test_intact_latest_needs_no_warning(self, tmp_path):
        import warnings
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        ckpt.save(str(tmp_path), 2, _tree(1), keep=5)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            _, step, _ = ckpt.restore_latest_valid(str(tmp_path), _tree())
        assert step == 2

    def test_all_corrupt_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, _tree(0), keep=5)
        self._corrupt(tmp_path, 1)
        with pytest.warns(RuntimeWarning):
            with pytest.raises(FileNotFoundError, match="integrity"):
                ckpt.restore_latest_valid(str(tmp_path), _tree())

    def test_config_mismatch_still_raises(self, tmp_path):
        # a VALID checkpoint that disagrees with the requested structure is
        # a config error, never a fall-back case
        ckpt.save(str(tmp_path), 1, _tree(0))
        bad = dict(_tree(0), a=jnp.zeros((4, 4)))
        with pytest.raises(ValueError):
            ckpt.restore_latest_valid(str(tmp_path), bad, strict=False)
