"""repro.dist tests: sharding-rule resolution per arch family, constrain
no-op semantics, train-step smoke, and the compressed-step parity guarantee
(wire_cr=1.0 reproduces the dense step — strict generalization)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.dist import sharding as shd
from repro.dist.grad_sync import (init_compressed_state,
                                  make_compressed_train_step, make_train_step)
from repro.models import Model
from repro.optim import make_optimizer

B, S = 4, 32


def _mesh(axes=("data", "model")):
    return jax.make_mesh((1,) * len(axes), axes)


def _batch(cfg, b=B, s=S):
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s + 1))
    out = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
           "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = jnp.full((b, s, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "vlm":
        v = cfg.vision
        out["patches"] = jnp.full((b, v.n_patches, v.d_vision), 0.1,
                                  jnp.float32)
    return out


def _setup(arch="stablelm-1.6b", lr=0.1):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", lr)
    return cfg, model, params, opt


# ---------------------------------------------------------------- rules
class TestRules:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_resolution_per_family(self, arch):
        cfg = get_config(arch)
        rules = shd.make_rules(cfg, SHAPES["train_4k"], _mesh())
        assert rules.batch_axes == ("data",)
        assert rules.shard_batch
        assert rules.logical(("batch", "seq", "embed")) == \
            P(("data",), None, None)
        assert rules.logical(("batch", "vocab")) == P(("data",), "model")
        # act_d shards over model only for FSDP archs
        fsdp = cfg.n_params() >= cfg.fsdp_threshold
        assert rules.fsdp == fsdp
        assert rules.logical(("act_d",)) == (P("model") if fsdp else P(None))

    def test_multi_pod_batch_axes(self):
        cfg = get_config("stablelm-1.6b")
        mesh = _mesh(("pod", "data", "model"))
        rules = shd.make_rules(cfg, SHAPES["train_4k"], mesh)
        assert rules.batch_axes == ("pod", "data")
        assert rules.logical(("batch",)) == P(("pod", "data"))

    def test_unknown_logical_axis_replicates(self):
        rules = shd.make_rules(get_config("yi-9b"), SHAPES["train_4k"],
                               _mesh())
        assert rules.logical(("batch", "no_such_axis")) == P(("data",), None)

    def test_param_specs_structure(self):
        cfg, model, _, _ = _setup("yi-9b")
        params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        with shd.use_rules(shd.make_rules(cfg, SHAPES["train_4k"], _mesh())):
            pspecs = shd.param_specs(cfg, params_abs)
        assert jax.tree.structure(pspecs, is_leaf=lambda x: isinstance(x, P)) \
            == jax.tree.structure(params_abs)
        assert all(isinstance(sp, P) for sp in jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(x, P)))


class TestConstrain:
    def test_noop_without_rules(self):
        assert shd.get_rules() is None
        x = jnp.ones((2, 3))
        assert shd.constrain(x, ("batch", "embed")) is x

    def test_identity_value_under_rules(self):
        cfg = get_config("stablelm-1.6b")
        x = jnp.arange(12.0).reshape(4, 3)
        with shd.use_rules(shd.make_rules(cfg, SHAPES["train_4k"], _mesh())):
            y = jax.jit(lambda a: shd.constrain(a, ("batch", "embed")))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_rank_mismatch_passes_through(self):
        cfg = get_config("stablelm-1.6b")
        x = jnp.ones((2, 3, 4))
        with shd.use_rules(shd.make_rules(cfg, SHAPES["train_4k"], _mesh())):
            assert shd.constrain(x, ("batch",)) is x

    def test_use_rules_restores(self):
        cfg = get_config("stablelm-1.6b")
        with shd.use_rules(shd.make_rules(cfg, SHAPES["train_4k"], _mesh())):
            assert shd.get_rules() is not None
        assert shd.get_rules() is None


# ------------------------------------------------------------- dense step
class TestTrainStep:
    def test_loss_decreases(self):
        cfg, model, params, opt = _setup()
        step = jax.jit(make_train_step(model, opt))
        state, batch = opt.init(params), _batch(cfg)
        losses = []
        for _ in range(5):
            params, state, m = step(params, state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()

    def test_n_micro_matches_full_batch(self):
        cfg, model, params, opt = _setup(lr=0.05)
        batch = _batch(cfg)
        p1, _, m1 = jax.jit(make_train_step(model, opt))(
            params, opt.init(params), batch)
        p2, _, m2 = jax.jit(make_train_step(model, opt, n_micro=2))(
            params, opt.init(params), batch)
        assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-5)


# --------------------------------------------------------- compressed step
class TestCompressedStep:
    def test_wire_cr_one_matches_dense(self):
        """cr=1.0 keeps every coordinate: the compressed step must reproduce
        the dense update (strict generalization, not a fork)."""
        n_pods = 2
        cfg, model, params, opt = _setup(lr=0.05)
        batch = _batch(cfg)
        dense = jax.jit(make_train_step(model, opt))
        comp = jax.jit(make_compressed_train_step(
            model, opt, n_pods=n_pods, wire_cr=1.0, gamma=3.0,
            use_kernel=False))
        crs = jnp.ones((n_pods,), jnp.float32)
        coeffs = jnp.full((n_pods,), 1.0 / n_pods, jnp.float32)
        p1, _, m1 = dense(params, opt.init(params), batch)
        p2, s2, m2 = comp(params, init_compressed_state(opt, params,
                                                        n_pods=n_pods),
                          batch, crs, coeffs)
        assert float(m2["loss"]) == pytest.approx(float(m1["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=1e-4, atol=1e-6)
        # nothing was dropped -> error-feedback residuals stay zero
        assert max(float(jnp.max(jnp.abs(e)))
                   for e in jax.tree.leaves(s2["ef"])) == 0.0

    def test_ef_residual_carried_and_loss_finite(self):
        n_pods = 2
        cfg, model, params, opt = _setup(lr=0.05)
        step = jax.jit(make_compressed_train_step(
            model, opt, n_pods=n_pods, wire_cr=0.05, gamma=2.0,
            use_kernel=False))
        state = init_compressed_state(opt, params, n_pods=n_pods)
        crs = jnp.full((n_pods,), 0.05, jnp.float32)
        coeffs = jnp.full((n_pods,), 1.0 / n_pods, jnp.float32)
        for i in range(3):
            params, state, m = step(params, state, _batch(cfg), crs, coeffs)
            assert np.isfinite(float(m["loss"]))
        # at cr<1 the top-k drop leaves nonzero residual on the big leaves
        assert max(float(jnp.max(jnp.abs(e)))
                   for e in jax.tree.leaves(state["ef"])) > 0.0

    def test_bare_opt_state_structure_preserved(self):
        """launch/specs.py lowers with a bare opt.init state: in/out
        structures must match for out_shardings + donation."""
        n_pods = 2
        cfg, model, params, opt = _setup()
        step = make_compressed_train_step(model, opt, n_pods=n_pods,
                                          wire_cr=0.1, use_kernel=False)
        state = opt.init(params)
        crs = jnp.full((n_pods,), 0.1, jnp.float32)
        coeffs = jnp.full((n_pods,), 0.5, jnp.float32)
        _, new_state, m = jax.jit(step)(params, state, _batch(cfg), crs,
                                        coeffs)
        assert jax.tree.structure(new_state) == jax.tree.structure(state)
        assert np.isfinite(float(m["loss"]))

    def test_batch_not_divisible_raises(self):
        cfg, model, params, opt = _setup()
        step = make_compressed_train_step(model, opt, n_pods=3, wire_cr=0.1)
        with pytest.raises(ValueError, match="not divisible"):
            step(params, opt.init(params), _batch(cfg),
                 jnp.ones((3,)), jnp.ones((3,)) / 3)
