"""Model correctness: GLA chunked-vs-recurrent equivalence, prefill/decode
consistency, attention masks, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models import attention as attn
from repro.models import gla
from repro.models import moe as moe_mod


class TestGLA:
    @pytest.mark.parametrize("inclusive", [False, True])
    @pytest.mark.parametrize("scalar", [False, True])
    def test_chunked_matches_recurrence(self, inclusive, scalar):
        b, h, t, dk, dv = 2, 3, 64, 8, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        r = jax.random.normal(ks[0], (b, h, t, dk))
        k = jax.random.normal(ks[1], (b, h, t, dk))
        v = jax.random.normal(ks[2], (b, h, t, dv))
        gshape = (b, h, t) if scalar else (b, h, t, dk)
        g = -jax.nn.softplus(jax.random.normal(ks[3], gshape))
        u = None if inclusive else jax.random.normal(ks[4], (h, dk)) * 0.1
        o_c, s_c = gla.chunked_gla(r, k, v, g, u=u, chunk=16,
                                   inclusive=inclusive)
        o_r, s_r = gla.reference_recurrence(r, k, v, g, u=u,
                                            inclusive=inclusive)
        np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                                   rtol=2e-4, atol=2e-4)

    def test_chunk_size_invariance(self):
        b, h, t, d = 1, 2, 96, 8
        ks = jax.random.split(jax.random.PRNGKey(1), 4)
        r, k, v = (jax.random.normal(kk, (b, h, t, d)) for kk in ks[:3])
        g = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, t, d)))
        o16, _ = gla.chunked_gla(r, k, v, g, chunk=16)
        o32, _ = gla.chunked_gla(r, k, v, g, chunk=32)
        np.testing.assert_allclose(np.asarray(o16), np.asarray(o32),
                                   rtol=2e-4, atol=2e-4)

    def test_strong_decay_stability(self):
        """Aggressive decay (rwkv-style) must not produce inf/nan."""
        b, h, t, d = 1, 2, 128, 8
        ks = jax.random.split(jax.random.PRNGKey(2), 4)
        r, k, v = (jax.random.normal(kk, (b, h, t, d)) for kk in ks[:3])
        g = jnp.full((b, h, t, d), -5.0)  # decay ~ exp(-5) per step
        o, s = gla.chunked_gla(r, k, v, g, chunk=32)
        assert np.isfinite(np.asarray(o)).all()
        assert np.isfinite(np.asarray(s)).all()

    def test_decode_step_matches_recurrence(self):
        b, h, t, d = 1, 2, 8, 4
        ks = jax.random.split(jax.random.PRNGKey(3), 4)
        r, k, v = (jax.random.normal(kk, (b, h, t, d)) for kk in ks[:3])
        g = -jax.nn.softplus(jax.random.normal(ks[3], (b, h, t, d)))
        o_ref, s_ref = gla.reference_recurrence(r, k, v, g)
        s = jnp.zeros((b, h, d, d))
        outs = []
        for i in range(t):
            o, s = gla.gla_decode(r[:, :, i], k[:, :, i], v[:, :, i],
                                  g[:, :, i], s)
            outs.append(o)
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 2)),
                                   np.asarray(o_ref), rtol=1e-5, atol=1e-5)


class TestAttention:
    def test_causal_mask(self):
        """Future tokens must not influence earlier outputs."""
        b, s, h, d = 1, 16, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        out1 = attn.attend(q, k, v, causal=True)
        k2 = k.at[:, -1].set(99.0)
        v2 = v.at[:, -1].set(-99.0)
        out2 = attn.attend(q, k2, v2, causal=True)
        np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                                   np.asarray(out2[:, :-1]), rtol=1e-5)

    def test_chunked_equals_unchunked(self):
        b, s, h, d = 2, 256, 4, 16
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        a = attn.attend(q, k, v, causal=True, chunk=64)
        b_ = attn.attend(q, k, v, causal=True, chunk=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-5)

    def test_nondivisible_chunk_padding(self):
        b, s, h, d = 1, 100, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        a = attn.attend(q, k, v, causal=True, chunk=32)
        b_ = attn.attend(q, k, v, causal=True, chunk=100)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                                   atol=1e-5)

    def test_window_subset_of_causal(self):
        b, s, h, d = 1, 64, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q, k, v = (jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        w = attn.attend(q, k, v, causal=True, window=8)
        # windowed output at position i only depends on keys in (i-8, i]
        k2 = k.at[:, 0].set(50.0)
        w2 = attn.attend(q, k2, v, causal=True, window=8)
        np.testing.assert_allclose(np.asarray(w[:, 16:]),
                                   np.asarray(w2[:, 16:]), rtol=1e-5)

    def test_gqa_group_broadcast(self):
        """GQA with kv=1 equals MQA: every head group sees the same kv."""
        b, s, h, d = 1, 32, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(8), 3)
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, 1, d))
        v = jax.random.normal(ks[2], (b, s, 1, d))
        out = attn.attend(q, k, v, causal=True)
        kb = jnp.broadcast_to(k, (b, s, h, d))
        vb = jnp.broadcast_to(v, (b, s, h, d))
        out_b = attn.attend(q, kb, vb, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_b),
                                   rtol=1e-5, atol=1e-6)

    def test_decode_matches_full(self):
        """decode_attend over a filled cache == last row of full attention."""
        b, s, h, d = 2, 24, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q = jax.random.normal(ks[0], (b, s, h, d))
        k = jax.random.normal(ks[1], (b, s, 2, d))
        v = jax.random.normal(ks[2], (b, s, 2, d))
        full = attn.attend(q, k, v, causal=True)
        dec = attn.decode_attend(q[:, -1], k, v, jnp.asarray(s - 1))
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                                   rtol=1e-4, atol=1e-5)


class TestMoE:
    def _setup(self, n_experts=8, top_k=2, d=16, dexp=32):
        mo = MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=dexp,
                       n_shared=1, d_shared=dexp, capacity_factor=2.0)
        p = moe_mod.init_moe(jax.random.PRNGKey(0), d, mo, jnp.float32)
        return mo, p

    def test_output_shape_and_finite(self):
        mo, p = self._setup()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
        out, aux = moe_mod.apply_moe(p, x, mo=mo)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) > 0

    def test_capacity_drops_when_tight(self):
        """With capacity_factor ~ 0, most tokens are dropped and the output
        shrinks toward just the shared-expert path."""
        mo, p = self._setup()
        import dataclasses
        mo_tight = dataclasses.replace(mo, capacity_factor=0.01)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 16))
        out_full, _ = moe_mod.apply_moe(p, x, mo=mo)
        out_tight, _ = moe_mod.apply_moe(p, x, mo=mo_tight)
        # shared expert output (routed path zeroed)
        sh = p["shared"]
        xt = x.reshape(-1, 16)
        shared = (jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"])) @ sh["w_down"]
        shared = shared.reshape(x.shape)
        d_tight = float(jnp.mean(jnp.abs(out_tight - shared)))
        d_full = float(jnp.mean(jnp.abs(out_full - shared)))
        assert d_tight < d_full

    def test_aux_loss_balanced_lower(self):
        """Uniform router (zero weights) -> aux close to 1 (its minimum)."""
        mo, p = self._setup(n_experts=16)
        p = dict(p, router=jnp.zeros_like(p["router"]))
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 16))
        _, aux = moe_mod.apply_moe(p, x, mo=mo)
        assert float(aux) < 1.5
