"""Import guard for the optional ``hypothesis`` dev dependency.

``from hyputil import given, settings, st`` behaves exactly like the real
hypothesis imports when the package is installed (see requirements-dev.txt).
Without it, property tests degrade to per-test skips — collection never
errors, and the plain unit tests in the same module still run.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # degrade: @given tests skip, everything else runs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``st.*`` strategy builders at decoration time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # signature-free wrapper: pytest must not try to resolve the
            # wrapped test's strategy parameters as fixtures
            def skipper(self=None):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
