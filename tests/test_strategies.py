"""Strategy plugin registry (core.strategies): capability validation,
bit-exact pre-registry goldens for the five built-ins across the host
engines, third-party registration running through every engine untouched,
and the qtopk registry-only plugin (int8 codec + EF + packed wire).

The goldens were captured on the pre-registry tree (the closed strategy
enum) and are asserted EXACTLY: the registry refactor — and any strategy
added after it — must not move a single bit of the built-ins' trajectories,
comm times, or EF residuals.
"""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import strategies
from repro.core.aggregation import AggregationConfig
from repro.fed import engine as engine_mod
from repro.fed.simulation import FLSimConfig, run_fl

REPO = pathlib.Path(__file__).resolve().parent.parent

# seeded config the goldens were captured with (pre-registry tree)
GOLDEN_SIM = dict(n_clients=8, participation=0.5, rounds=8, n_train=1600,
                  n_test=400, dim=48, hidden=48, n_classes=8, batch_size=32,
                  eval_every=3, seed=3)
GOLDEN_CR = 0.1

GOLDENS = json.loads(r"""
{
 "fedavg": {
  "legacy": {
   "accuracies": [
    [
     0,
     0.4650000035762787
    ],
    [
     3,
     0.6674999594688416
    ],
    [
     6,
     0.5349999666213989
    ],
    [
     7,
     0.8650000095367432
    ]
   ],
   "comm_actual": 2.7352610533509347,
   "residual_sum": null
  },
  "fused": {
   "accuracies": [
    [
     0,
     0.4650000035762787
    ],
    [
     3,
     0.6674999594688416
    ],
    [
     6,
     0.5349999666213989
    ],
    [
     7,
     0.8650000095367432
    ]
   ],
   "comm_actual": 2.7352610533509347,
   "residual_sum": null
  },
  "scan": {
   "accuracies": [
    [
     0,
     0.4650000035762787
    ],
    [
     3,
     0.6674999594688416
    ],
    [
     6,
     0.5349999666213989
    ],
    [
     7,
     0.8650000095367432
    ]
   ],
   "comm_actual": 2.7352610533509347,
   "residual_sum": null
  }
 },
 "topk": {
  "legacy": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.6049999594688416
    ],
    [
     6,
     0.48249998688697815
    ],
    [
     7,
     0.8174999952316284
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "fused": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.6049999594688416
    ],
    [
     6,
     0.48249998688697815
    ],
    [
     7,
     0.8174999952316284
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "scan": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.6049999594688416
    ],
    [
     6,
     0.48249998688697815
    ],
    [
     7,
     0.8174999952316284
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  }
 },
 "eftopk": {
  "legacy": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.637499988079071
    ],
    [
     6,
     0.5049999952316284
    ],
    [
     7,
     0.8324999809265137
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": 67.38092041015625
  },
  "fused": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.637499988079071
    ],
    [
     6,
     0.5049999952316284
    ],
    [
     7,
     0.8324999809265137
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": 67.38092041015625
  },
  "scan": {
   "accuracies": [
    [
     0,
     0.3774999976158142
    ],
    [
     3,
     0.637499988079071
    ],
    [
     6,
     0.5049999952316284
    ],
    [
     7,
     0.8324999809265137
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": 67.38092041015625
  }
 },
 "bcrs": {
  "legacy": {
   "accuracies": [
    [
     0,
     0.23250000178813934
    ],
    [
     3,
     0.737500011920929
    ],
    [
     6,
     0.7749999761581421
    ],
    [
     7,
     0.9149999618530273
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "fused": {
   "accuracies": [
    [
     0,
     0.23250000178813934
    ],
    [
     3,
     0.737500011920929
    ],
    [
     6,
     0.7749999761581421
    ],
    [
     7,
     0.9149999618530273
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "scan": {
   "accuracies": [
    [
     0,
     0.23250000178813934
    ],
    [
     3,
     0.737500011920929
    ],
    [
     6,
     0.7749999761581421
    ],
    [
     7,
     0.9149999618530273
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  }
 },
 "bcrs_opwa": {
  "legacy": {
   "accuracies": [
    [
     0,
     0.367499977350235
    ],
    [
     3,
     0.33249998092651367
    ],
    [
     6,
     0.8274999856948853
    ],
    [
     7,
     0.7999999523162842
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "fused": {
   "accuracies": [
    [
     0,
     0.367499977350235
    ],
    [
     3,
     0.33249998092651367
    ],
    [
     6,
     0.8274999856948853
    ],
    [
     7,
     0.7999999523162842
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  },
  "scan": {
   "accuracies": [
    [
     0,
     0.367499977350235
    ],
    [
     3,
     0.33249998092651367
    ],
    [
     6,
     0.8274999856948853
    ],
    [
     7,
     0.7999999523162842
    ]
   ],
   "comm_actual": 1.7293823236740713,
   "residual_sum": null
  }
 }
}
"""
)


def _snapshot(res):
    return {
        "accuracies": [[int(a), float(b)] for a, b in res.accuracies],
        "comm_actual": float(res.times.actual),
        "residual_sum": (float(np.abs(res.final_residuals).sum())
                         if res.final_residuals is not None else None),
    }


def _run(strategy, engine, **overrides):
    sim_kw = dict(GOLDEN_SIM)
    sim_kw.update(overrides)
    acfg = AggregationConfig(strategy=strategy, cr=GOLDEN_CR)
    return run_fl(FLSimConfig(**sim_kw), acfg, engine=engine)


#: cheap config for parity tests that do not need the golden trajectory
FAST_SIM = dict(n_clients=6, participation=0.5, rounds=4, n_train=480,
                n_test=120, dim=16, hidden=16, n_classes=4, batch_size=32,
                eval_every=2, seed=5)


# ---------------------------------------------------------------- wire format
class TestWireFormat:
    def test_bytes_on_wire(self):
        assert strategies.DENSE32.bytes_on_wire(1000, 10) == 4000.0
        assert strategies.SPARSE32.bytes_on_wire(1000, 10) == 80.0
        assert strategies.PACKED_INT8.bytes_on_wire(1000, 10) == 54.0

    def test_cr_eff_reference_pair_is_identity(self):
        # bitwise: the pre-registry accounting multiplied by nothing, so
        # the reference pair must return the input object unchanged
        cr = 0.1
        assert strategies.SPARSE32.cr_eff(cr) is cr
        crs = np.asarray([0.1, 0.03])
        assert strategies.SPARSE32.cr_eff(crs) is crs

    def test_cr_eff_dense_is_one(self):
        assert strategies.DENSE32.cr_eff(0.1) == 1.0
        np.testing.assert_array_equal(
            strategies.DENSE32.cr_eff(np.asarray([0.1, 0.5])),
            np.asarray([1.0, 1.0]))

    def test_cr_eff_packed(self):
        n = 1000
        got = strategies.PACKED_INT8.cr_eff(0.1, n)
        assert got == 0.1 * (5.0 / 8.0) + 4.0 / (8.0 * n)
        with pytest.raises(ValueError, match="needs n_params"):
            strategies.PACKED_INT8.cr_eff(0.1)

    def test_cr_eff_prices_exact_wire_bytes(self):
        # cr_eff is DEFINED as: the cr that makes the paper's 2x-reference
        # comm_time charge this format's exact payload bytes
        n, cr = 4096, 0.07
        k = int(round(cr * n))
        eff = strategies.PACKED_INT8.cr_eff(k / n, n)
        assert np.isclose(eff * 8.0 * n,
                          strategies.PACKED_INT8.bytes_on_wire(n, k))


# -------------------------------------------------------------- registration
class TestRegistration:
    def test_duplicate_name_refused(self):
        with pytest.raises(ValueError, match="already registered"):
            strategies.register(strategies.Strategy(name="topk"))

    def test_unknown_capability_values_refused(self):
        with pytest.raises(ValueError, match="unknown carry"):
            strategies.register(strategies.Strategy(name="x", carry="elf"))
        with pytest.raises(ValueError, match="unknown selector"):
            strategies.register(
                strategies.Strategy(name="x", selector="bottomk"))
        with pytest.raises(ValueError, match="unknown weighting"):
            strategies.register(
                strategies.Strategy(name="x", weighting="uniform"))

    def test_codec_requires_ef_carry(self):
        with pytest.raises(ValueError, match="requires carry='ef'"):
            strategies.register(strategies.Strategy(
                name="x", carry="none",
                value_codec=strategies.int8_symmetric_codec,
                megakernel=False))

    def test_codec_megakernel_needs_kernel_codec(self):
        """value_codec + megakernel=True is only legal when the codec has a
        registered kernel lowering (fused_merge's dequantization stage)."""
        with pytest.raises(ValueError, match="kernel_codec"):
            strategies.register(strategies.Strategy(
                name="x", carry="ef",
                value_codec=strategies.int8_symmetric_codec,
                megakernel=True))

    def test_kernel_codec_requires_value_codec(self):
        with pytest.raises(ValueError, match="value_codec"):
            strategies.register(strategies.Strategy(
                name="x", carry="ef", kernel_codec="int8"))

    def test_unknown_kernel_codec_refused(self):
        with pytest.raises(ValueError, match="unknown kernel_codec"):
            strategies.register(strategies.Strategy(
                name="x", carry="ef",
                value_codec=strategies.int8_symmetric_codec,
                kernel_codec="fp8", megakernel=True))

    def test_dense_selector_needs_dense_wire(self):
        with pytest.raises(ValueError, match="dense wire"):
            strategies.register(strategies.Strategy(
                name="x", selector="none", wire=strategies.SPARSE32,
                megakernel=False))
        with pytest.raises(ValueError, match="misprice"):
            strategies.register(strategies.Strategy(
                name="x", selector="topk", wire=strategies.DENSE32))

    def test_unknown_name_lists_registered(self):
        with pytest.raises(ValueError, match="registered: fedavg"):
            strategies.get("nope")

    def test_config_time_errors(self):
        with pytest.raises(ValueError, match="unknown strategy 'nope'"):
            AggregationConfig(strategy="nope")
        from repro.launch.fl_train import FLTrainConfig
        with pytest.raises(ValueError, match="unknown strategy 'nope'"):
            FLTrainConfig(strategy="nope")

    def test_no_strategy_enum_matching_outside_registry(self):
        """The CI guard, run in-suite: engines dispatch on capabilities."""
        sys.path.insert(0, str(REPO / "tools"))
        try:
            from check_strategy_enum import check
        finally:
            sys.path.pop(0)
        assert check(REPO) == []


# ------------------------------------------------------------------- goldens
class TestBuiltinGoldens:
    """The five built-ins, three host engines, captured pre-registry: the
    registry refactor must be invisible at the bit level."""

    @pytest.mark.parametrize("strategy", list(GOLDENS))
    def test_bit_exact_with_pre_registry_tree(self, strategy):
        for engine in ("legacy", "fused", "scan"):
            got = _snapshot(_run(strategy, engine))
            assert got == GOLDENS[strategy][engine], (strategy, engine)


# -------------------------------------------------- third-party registration
@pytest.fixture
def toy_eftopk():
    """A 'third-party' strategy: an exact capability clone of eftopk under a
    new name, registered through the public API only."""
    name = "toy_eftopk"
    strategies.register(strategies.Strategy(
        name=name, description="third-party EF Top-K clone",
        carry="ef", selector="topk", weighting="data",
        wire=strategies.SPARSE32, megakernel=True))
    try:
        yield name
    finally:
        strategies.unregister(name)


class TestThirdPartyStrategy:
    """A strategy registered in a test file runs through every engine with
    no engine edits — and, being a capability clone of eftopk, must
    reproduce eftopk's trajectory bitwise."""

    def test_host_engines_parity_and_one_trace(self, toy_eftopk):
        ref = {e: _snapshot(_run("eftopk", e, **FAST_SIM))
               for e in ("legacy", "fused", "scan")}
        key = ("sim_scan", toy_eftopk, False)
        traces0 = engine_mod.TRACE_COUNTS[key]
        for engine in ("legacy", "fused", "scan"):
            got = _snapshot(_run(toy_eftopk, engine, **FAST_SIM))
            assert got == ref[engine], engine
        assert engine_mod.TRACE_COUNTS[key] - traces0 == 1

    def test_mesh_engine_parity_and_one_trace(self, toy_eftopk):
        from repro.fed import mesh_round
        from repro.fed.engine import init_mesh_residuals, make_mesh_sim_scan

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            err = pred - batch["t"]
            return jnp.mean(err * err), pred

        rng = np.random.default_rng(0)
        t, c, s, b, dim, out = 3, 3, 2, 4, 8, 3
        params = {"w": jnp.asarray(rng.normal(size=(dim, out)), jnp.float32)}
        xs = {"batches": {
                  "x": jnp.asarray(rng.normal(size=(t, c, s, b, dim)),
                                   jnp.float32),
                  "t": jnp.asarray(rng.normal(size=(t, c, s, b, out)),
                                   jnp.float32)},
              "step_mask": jnp.ones((t, c, s), bool),
              "active": jnp.ones((t, c), bool),
              "weights": jnp.full((t, c), 1.0 / c, jnp.float32),
              "crs": jnp.full((t, c), 0.25, jnp.float32)}
        outs = {}
        for name in ("eftopk", toy_eftopk):
            key = ("mesh_scan", name)
            traces0 = engine_mod.TRACE_COUNTS[key]
            sim = make_mesh_sim_scan(loss_fn, params, lr=1e-2, strategy=name)
            outs[name] = sim(jax.tree.map(jnp.copy, params),
                             init_mesh_residuals(params, c), xs)
            assert engine_mod.TRACE_COUNTS[key] - traces0 == 1
        for field in ("params", "residuals"):
            for a, b in zip(jax.tree.leaves(outs["eftopk"][field]),
                            jax.tree.leaves(outs[toy_eftopk][field])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(outs["eftopk"]["ys"]["loss"]),
            np.asarray(outs[toy_eftopk]["ys"]["loss"]))


# --------------------------------------------------------------------- qtopk
class TestQtopk:
    """The shipped registry-only plugin: int8-quantized Top-K survivors.
    No engine file mentions it (asserted), yet it runs end-to-end through
    all engines with EF absorbing the quantization error and the packed
    wire format pricing its uploads 8/5x cheaper than idx32+f32."""

    def test_no_engine_code_mentions_qtopk(self):
        """Docstrings may cite qtopk as the registry-only example; no engine
        may reference it STRUCTURALLY (identifiers or non-docstring string
        literals) — that would mean the plugin needed an engine edit."""
        import ast
        engines = ["src/repro/fed/server.py", "src/repro/fed/round_step.py",
                   "src/repro/fed/engine.py", "src/repro/fed/mesh_round.py",
                   "src/repro/fed/simulation.py", "src/repro/dist/grad_sync.py",
                   "src/repro/core/aggregation.py",
                   "src/repro/launch/fl_train.py"]
        for rel in engines:
            tree = ast.parse((REPO / rel).read_text())
            doc_ids = set()
            for node in ast.walk(tree):
                if isinstance(node, (ast.Module, ast.ClassDef,
                                     ast.FunctionDef, ast.AsyncFunctionDef)):
                    body = node.body
                    if (body and isinstance(body[0], ast.Expr)
                            and isinstance(body[0].value, ast.Constant)):
                        doc_ids.add(id(body[0].value))
            for node in ast.walk(tree):
                if (isinstance(node, ast.Constant)
                        and isinstance(node.value, str)
                        and id(node) not in doc_ids):
                    assert "qtopk" not in node.value, (rel, node.value)
                if isinstance(node, ast.Name):
                    assert "qtopk" not in node.id, rel

    def test_engines_agree_and_ef_absorbs_quantization(self):
        snaps, finals = {}, {}
        for engine in ("legacy", "fused", "scan"):
            res = _run("qtopk", engine, **FAST_SIM)
            snaps[engine] = _snapshot(res)
            finals[engine] = res
        assert snaps["legacy"] == snaps["fused"] == snaps["scan"]
        # EF must be live: quantization error lands in the residuals
        assert snaps["legacy"]["residual_sum"] > 0.0
        # and the codec must actually change the trajectory vs plain eftopk
        ef = _snapshot(_run("eftopk", "fused", **FAST_SIM))
        assert snaps["fused"]["accuracies"] != ef["accuracies"] or \
            snaps["fused"]["residual_sum"] != ef["residual_sum"]

    def test_packed_wire_cheaper_than_reference_pair(self):
        q = _snapshot(_run("qtopk", "fused", **FAST_SIM))
        ef = _snapshot(_run("eftopk", "fused", **FAST_SIM))
        # identical selection CRs, packed values: strictly cheaper uploads,
        # and (latency aside) by about the 5/8 byte ratio
        assert q["comm_actual"] < ef["comm_actual"]

    def test_codec_roundtrip_properties(self):
        rng = np.random.default_rng(7)
        v = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32))
        mask = jnp.abs(v) > 0.5
        v = jnp.where(mask, v, 0.0)
        deq = strategies.int8_symmetric_codec(v, mask)
        # zeros stay exactly zero (non-survivors never leak value)
        np.testing.assert_array_equal(np.asarray(deq)[~np.asarray(mask)], 0.0)
        # per-client max |v| is on the grid's end point -> reconstructed
        # exactly; everything else within half a step
        scale = np.abs(np.asarray(v)).max(axis=1, keepdims=True) / 127.0
        assert np.all(np.abs(np.asarray(deq - v)) <= scale / 2 + 1e-7)

    def test_mesh_engine_runs_qtopk(self):
        from repro.fed.engine import init_mesh_residuals, make_mesh_sim_scan

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            err = pred - batch["t"]
            return jnp.mean(err * err), pred

        rng = np.random.default_rng(1)
        t, c, s, b, dim, out = 2, 3, 2, 4, 8, 3
        params = {"w": jnp.asarray(rng.normal(size=(dim, out)), jnp.float32)}
        xs = {"batches": {
                  "x": jnp.asarray(rng.normal(size=(t, c, s, b, dim)),
                                   jnp.float32),
                  "t": jnp.asarray(rng.normal(size=(t, c, s, b, out)),
                                   jnp.float32)},
              "step_mask": jnp.ones((t, c, s), bool),
              "active": jnp.ones((t, c), bool),
              "weights": jnp.full((t, c), 1.0 / c, jnp.float32),
              "crs": jnp.full((t, c), 0.25, jnp.float32)}
        sim = make_mesh_sim_scan(loss_fn, params, lr=1e-2, strategy="qtopk")
        out = sim(jax.tree.map(jnp.copy, params),
                  init_mesh_residuals(params, c), xs)
        assert np.isfinite(np.asarray(out["ys"]["loss"])).all()
        # quantization error landed in the per-leaf residuals
        assert sum(float(np.abs(np.asarray(l)).sum())
                   for l in jax.tree.leaves(out["residuals"])) > 0.0

    def test_pod_sync_accepts_registry_strategy(self):
        """dist.grad_sync consumes the registry: qtopk picks the codec, a
        non-compressing strategy is refused."""
        from repro.dist.grad_sync import make_compressed_train_step

        class TinyModel:
            @staticmethod
            def loss_fn(params, batch):
                pred = batch["x"] @ params["w"]
                loss = jnp.mean((pred - batch["t"]) ** 2)
                return loss, {"mse": loss}

        class SGD:
            @staticmethod
            def init(params):
                return ()

            @staticmethod
            def update(grads, state, params):
                return (jax.tree.map(lambda p, g: p - 1e-2 * g,
                                     params, grads), state)

        with pytest.raises(ValueError, match="does not compress"):
            make_compressed_train_step(TinyModel, SGD, n_pods=2,
                                       strategy="fedavg")
        step = jax.jit(make_compressed_train_step(
            TinyModel, SGD, n_pods=2, wire_cr=0.5, min_leaf_size=1,
            strategy="qtopk"))
        rng = np.random.default_rng(2)
        params = {"w": jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)}
        batch = {"x": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
                 "t": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
        from repro.dist.grad_sync import init_compressed_state
        state = init_compressed_state(SGD, params, n_pods=2)
        new_params, new_state, out = step(params, state,
                                          batch, jnp.full((2,), 0.5),
                                          jnp.full((2,), 0.5))
        assert np.isfinite(float(out["loss"]))
        assert float(jnp.abs(jax.tree.leaves(new_state["ef"])[0]).sum()) > 0.0


class TestCodecNumerics:
    """satellite coverage for the shared quantization op sequence: the
    zero-row path, the elementwise round-trip bound, and the exact-product
    scale rounding that makes the kernel route fma-immune."""

    def test_scale_mantissa_bits(self):
        # 23 - ceil(log2(levels + 1)): q in [-levels, levels] has
        # <= ceil(log2(levels+1)) + 1 significand bits, so q * scale fits
        # f32's 24 exactly
        assert strategies.scale_mantissa_bits(127.0) == 16
        assert strategies.scale_mantissa_bits(7.0) == 20

    def test_zero_rows_dequantize_to_exact_zeros(self):
        # the old 1e-30 scale floor is gone: an all-zero row has scale 0 and
        # the safe-divisor where() keeps every output exactly 0.0
        v = jnp.zeros((3, 64), jnp.float32)
        mask = jnp.zeros((3, 64), bool)
        for codec in (strategies.int8_symmetric_codec,
                      strategies.int4_symmetric_codec):
            out = np.asarray(codec(v, mask))
            assert not np.any(out)
            assert not np.signbit(out).any()

    def test_mixed_zero_and_live_rows(self):
        rng = np.random.default_rng(11)
        v = rng.normal(size=(4, 128)).astype(np.float32)
        v[2] = 0.0
        deq = np.asarray(strategies.int4_symmetric_codec(
            jnp.asarray(v), jnp.asarray(v) != 0))
        assert not np.any(deq[2])
        assert np.any(deq[[0, 1, 3]])

    @pytest.mark.parametrize("codec_name", ["int8", "int4"])
    def test_roundtrip_error_at_most_half_step_elementwise(self, codec_name):
        # |dequant(v) - v| <= scale/2 elementwise, with the documented
        # <= 2^-16-relative scale slack from quantization_scale's
        # reciprocal-multiply + mantissa rounding (clip at the grid edge
        # turns that scale perturbation into levels * |dscale| of error)
        levels = strategies.CODEC_LEVELS[codec_name]
        fn = (strategies.int8_symmetric_codec if codec_name == "int8"
              else strategies.int4_symmetric_codec)
        for seed in range(25):
            rng = np.random.default_rng(seed)
            c, n = int(rng.integers(1, 6)), int(rng.integers(1, 400))
            v = rng.normal(size=(c, n)).astype(np.float32)
            v *= 10.0 ** rng.integers(-10, 10, size=(c, 1)).astype(np.float32)
            v[rng.random(size=(c, n)) < 0.5] = 0.0
            if rng.random() < 0.3:
                v[rng.integers(c)] = 0.0
            vj = jnp.asarray(v)
            deq = np.asarray(fn(vj, vj != 0))
            absmax = np.abs(v).max(axis=1, keepdims=True)
            scale = np.asarray(strategies.quantization_scale(
                jnp.asarray(absmax), levels))
            bound = scale / 2.0 + absmax * 2.0 ** -15
            assert np.all(np.abs(deq - v) <= bound), seed
            # non-survivors (exact zeros) never leak value
            np.testing.assert_array_equal(deq[v == 0.0], 0.0)

    def test_quantization_scale_products_are_exact_in_f32(self):
        # the whole point of the mantissa rounding: every q * scale is
        # exactly representable, so fma contraction and mul-then-sub agree
        # under any lowering — verified against float64 ground truth
        rng = np.random.default_rng(12)
        absmax = jnp.asarray(
            (rng.random(4096).astype(np.float32) + 1e-6)
            * 10.0 ** rng.integers(-30, 30, size=4096).astype(np.float32))
        for levels in strategies.CODEC_LEVELS.values():
            scale = np.asarray(strategies.quantization_scale(absmax, levels))
            qs = np.arange(-levels, levels + 1, dtype=np.float32)
            prod32 = qs[None, :] * scale[:, None]
            prod64 = qs[None, :].astype(np.float64) * scale[:, None]
            np.testing.assert_array_equal(prod32.astype(np.float64), prod64)


class TestInt4Strategy:
    """Registration sanity + wire accounting for the int4 plugin and the
    bitmask wire formats that ride along."""

    def test_registered_capabilities(self):
        s = strategies.get("int4")
        assert s.carry == "ef" and s.selector == "topk"
        assert s.value_codec is strategies.int4_symmetric_codec
        assert s.megakernel and s.kernel_codec == "int4"
        assert s.wire is strategies.PACKED_INT4
        q = strategies.get("qtopk")
        assert q.megakernel and q.kernel_codec == "int8"

    def test_packed_int4_bytes_on_wire(self):
        # idx32 + int4 + scale32: 4k + 0.5k + 4
        assert strategies.PACKED_INT4.bytes_on_wire(1000, 10) == 49.0
        # vs the idx32 + f32 reference pair's 8k = 80: the 9/16 ratio
        assert strategies.PACKED_INT4.bytes_on_wire(10 ** 6, 10 ** 5) \
            / strategies.SPARSE32.bytes_on_wire(10 ** 6, 10 ** 5) \
            == pytest.approx(9.0 / 16.0, rel=1e-4)

    def test_bitmask_bytes_on_wire(self):
        # bitmask + int8 + scale32: n/8 + 1k + 4
        assert strategies.BITMASK_INT8.bytes_on_wire(1000, 10) == 139.0
        # bitmask + int4 + scale32: n/8 + 0.5k + 4
        assert strategies.BITMASK_INT4.bytes_on_wire(1000, 10) == 134.0
        # dense-ish selection: the 1-bit mask beats 4-byte indices when
        # k/n > 1/32
        n = 10 ** 5
        for k in (n // 10, n // 5):
            assert strategies.BITMASK_INT8.bytes_on_wire(n, k) \
                < strategies.PACKED_INT8.bytes_on_wire(n, k)
        assert strategies.BITMASK_INT8.bytes_on_wire(n, n // 100) \
            > strategies.PACKED_INT8.bytes_on_wire(n, n // 100)

    def test_cr_eff_prices_exact_wire_bytes(self):
        # comm_time's 2x factor charges 8 * n * cr bytes for the reference
        # pair, so cr_eff is DEFINED by 8 * n * cr_eff == bytes_on_wire
        n = 10 ** 6
        for wf in (strategies.PACKED_INT4, strategies.BITMASK_INT8,
                   strategies.BITMASK_INT4):
            for k in (10, 10 ** 4, 10 ** 5):
                eff = wf.cr_eff(k / n, n)
                np.testing.assert_allclose(8.0 * n * float(eff),
                                           wf.bytes_on_wire(n, k), rtol=1e-9)


class TestBitmaskTopkStrategy:
    """The bitmask-wire built-in: qtopk's exact math (topk + int8 codec +
    EF + data weighting) shipped under a 1-bit coordinate bitmask instead
    of packed idx32 — the strategy that exercises the BITMASK_* mask-bits
    pricing end to end."""

    def test_registered_capabilities(self):
        s = strategies.get("bitmask_topk")
        assert s.carry == "ef" and s.selector == "topk"
        assert s.value_codec is strategies.int8_symmetric_codec
        assert s.weighting == "data"
        assert s.wire is strategies.BITMASK_INT8
        assert s.megakernel and s.kernel_codec == "int8"
        assert s.residual_layout == "dense"

    def test_wire_pricing_beats_packed_indices_above_break_even(self):
        # mask bits amortize over n: above k/n = 1/32 the bitmask wire is
        # strictly cheaper than packed idx32 + int8; below it, dearer
        s = strategies.get("bitmask_topk")
        n = 10 ** 4
        eff = float(s.wire.cr_eff(0.05, n))
        # n/8 + k + 4 bytes over the 8k-byte reference pair
        k = int(0.05 * n)
        np.testing.assert_allclose(
            eff, (n / 8.0 + k + 4.0) / (8.0 * n), rtol=1e-12)
        assert eff < float(strategies.PACKED_INT8.cr_eff(0.05, n))
        assert float(s.wire.cr_eff(0.01, n)) \
            > float(strategies.PACKED_INT8.cr_eff(0.01, n))

    def test_same_trajectory_as_qtopk_cheaper_comm(self):
        """Wire format is accounting only: the bitmask_topk trajectory is
        bit-identical to qtopk's (same selector, codec, EF carry), while
        its comm time is strictly lower at GOLDEN_CR = 10% density — the
        regime where the 1-bit mask beats 4-byte indices."""
        bm = _run("bitmask_topk", "fused", **FAST_SIM)
        q = _run("qtopk", "fused", **FAST_SIM)
        assert _snapshot(bm)["accuracies"] == _snapshot(q)["accuracies"]
        np.testing.assert_array_equal(bm.final_residuals, q.final_residuals)
        assert bm.times.actual < q.times.actual
