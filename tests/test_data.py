"""Data pipeline: Dirichlet partitions, client datasets, synthetic streams."""
import numpy as np
import pytest
from hyputil import given, settings, st

from repro.data import (ClientDataset, build_client_datasets,
                        client_label_histogram, data_fractions,
                        dirichlet_partition, synthetic_classification,
                        synthetic_lm_tokens)


class TestPartition:
    @given(st.integers(2, 16), st.floats(0.05, 5.0), st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_property_exact_cover(self, n_clients, beta, seed):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, 10, 2000)
        parts = dirichlet_partition(labels, n_clients, beta, rng, min_size=1)
        allidx = np.sort(np.concatenate(parts))
        np.testing.assert_array_equal(allidx, np.arange(2000))

    def test_low_beta_more_skewed(self):
        rng = np.random.default_rng(0)
        labels = rng.integers(0, 10, 20000)

        def skew(beta):
            parts = dirichlet_partition(labels, 10, beta,
                                        np.random.default_rng(1))
            h = client_label_histogram(labels, parts).astype(float)
            h = h / h.sum(1, keepdims=True)
            # mean per-client entropy: lower = more skewed
            return float(-(h * np.log(h + 1e-12)).sum(1).mean())

        assert skew(0.1) < skew(0.5) < skew(100.0)

    def test_fractions_sum_to_one(self):
        rng = np.random.default_rng(2)
        labels = rng.integers(0, 10, 3000)
        parts = dirichlet_partition(labels, 7, 0.5, rng)
        assert data_fractions(parts).sum() == pytest.approx(1.0)


class TestClientDataset:
    def test_epoch_batches_drop_last(self):
        ds = ClientDataset(np.arange(25).reshape(25, 1).astype(np.float32),
                           np.arange(25).astype(np.int32))
        batches = list(ds.epoch_batches(8, np.random.default_rng(0)))
        assert len(batches) == 3
        assert all(b[0].shape == (8, 1) for b in batches)

    def test_fixed_batches_shape_and_cycling(self):
        ds = ClientDataset(np.zeros((10, 3), np.float32),
                           np.zeros(10, np.int32))
        xs, ys = ds.fixed_batches(4, 5, np.random.default_rng(0))
        assert xs.shape == (5, 4, 3) and ys.shape == (5, 4)


class TestSynthetic:
    def test_classification_learnable_structure(self):
        x, y = synthetic_classification(2000, 10, 32,
                                        np.random.default_rng(0), noise=0.5)
        # class means are separated: nearest-centroid accuracy high
        cents = np.stack([x[y == c].mean(0) for c in range(10)])
        pred = np.argmin(((x[:, None] - cents[None]) ** 2).sum(-1), 1)
        assert (pred == y).mean() > 0.9

    def test_lm_tokens_planted_bigram(self):
        toks = synthetic_lm_tokens(64, 128, 100, np.random.default_rng(0))
        assert toks.min() >= 0 and toks.max() < 100
        # ~50% of transitions follow the planted permutation
        from collections import Counter
        follows = Counter()
        for row in toks:
            for a, b in zip(row[:-1], row[1:]):
                follows[(a, b)] += 1
        top = follows.most_common(50)
        assert top[0][1] > 5  # repeated deterministic transitions exist
