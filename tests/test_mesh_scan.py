"""Scanned mesh driver: the pytree-native multi-round `lax.scan` program
(`engine.make_mesh_sim_scan` / `mesh_round.make_round_body`) must be
bit-exact with the per-round dispatch loop, carry EF residuals with
`engine.aggregate_updates` semantics, compile once per checkpoint chunk,
and checkpoint/restart without perturbing the trajectory."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bcrs as bcrs_mod
from repro.core.compression import k_for_ratio, k_for_ratio_traced
from repro.fed import engine as engine_mod
from repro.fed.engine import (ClientUpdateSpec, aggregate_updates,
                              compress_merge_leaf, init_mesh_residuals,
                              make_masked_local_trainer, make_mesh_sim_scan)
from repro.fed.mesh_round import make_mesh_round_step

STRATEGIES = ("fedavg", "topk", "bcrs", "bcrs_opwa", "eftopk")


def _loss_fn(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    err = pred - batch["t"]
    return jnp.mean(err * err), pred


def _setup(seed=0, t=4, c=3, s=2, b=4, dim=12, out=5):
    """Params + T stacked rounds of xs with ragged steps, padded cohort
    slots, and per-client CR spreads."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(dim, out)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(out,)), jnp.float32)}
    active = np.zeros((t, c), bool)
    step_mask = np.zeros((t, c, s), bool)
    weights = np.zeros((t, c), np.float32)
    for r in range(t):
        c_r = int(rng.integers(1, c + 1))
        active[r, :c_r] = True
        for j in range(c_r):
            step_mask[r, j, : int(rng.integers(1, s + 1))] = True
        w = rng.dirichlet(np.ones(c_r))
        weights[r, :c_r] = w
    xs = {"batches": {
              "x": jnp.asarray(rng.normal(size=(t, c, s, b, dim)),
                               jnp.float32),
              "t": jnp.asarray(rng.normal(size=(t, c, s, b, out)),
                               jnp.float32)},
          "step_mask": jnp.asarray(step_mask),
          "active": jnp.asarray(active),
          "weights": jnp.asarray(weights),
          "crs": jnp.asarray(rng.uniform(0.05, 0.9, size=(t, c)),
                             jnp.float32)}
    return params, xs


def _residuals0(params, c, strategy):
    return (init_mesh_residuals(params, c) if strategy == "eftopk"
            else jnp.zeros((0,), jnp.float32))


def _copy(tree):
    """The scanned program donates its carry buffers — copy before calling
    when the test reuses the inputs afterwards."""
    return jax.tree.map(jnp.copy, tree)


class TestScanVsRoundLoop:
    """Acceptance: the scanned program equals the per-round jitted step
    dispatched in a Python loop — params trajectory, losses, and EF
    residuals, bitwise."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_bit_exact(self, strategy):
        params, xs = _setup(seed=3)
        t, c = xs["active"].shape
        res0 = _residuals0(params, c, strategy)
        sim = make_mesh_sim_scan(_loss_fn, params, lr=1e-2,
                                 strategy=strategy, gamma=3.0)
        out = sim(_copy(params), _copy(res0), xs)

        from repro.fed import mesh_round
        traces0 = mesh_round.TRACE_COUNTS[(strategy,)]
        step = make_mesh_round_step(_loss_fn, lr_local=1e-2,
                                    strategy=strategy, gamma=3.0,
                                    donate=False)
        p = params
        res = res0 if strategy == "eftopk" else None
        losses = []
        for r in range(t):
            batch_r = jax.tree.map(lambda a: a[r], xs["batches"])
            p, res, loss = step(p, res, batch_r, xs["step_mask"][r],
                                xs["weights"][r], xs["crs"][r],
                                xs["active"][r])
            losses.append(loss)
        # the per-round step is one trace regardless of dispatch count
        assert mesh_round.TRACE_COUNTS[(strategy,)] - traces0 == 1
        for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(p)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(out["ys"]["loss"]),
                                      np.asarray(jnp.stack(losses)))
        if strategy == "eftopk":
            for a, b in zip(jax.tree.leaves(out["residuals"]),
                            jax.tree.leaves(res)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_inactive_rounds_leave_carry_untouched(self):
        """A round whose cohort is entirely padded must be a no-op on the
        params AND the residuals (the plan simply omits dead rounds; this
        guards the padding semantics that makes that sound)."""
        params, xs = _setup(seed=11, t=3)
        dead = jax.tree.map(lambda a: a.at[1].set(jnp.zeros_like(a[1])),
                            {"active": xs["active"],
                             "weights": xs["weights"]})
        xs = {**xs, **dead}
        res0 = _residuals0(params, xs["active"].shape[1], "eftopk")
        sim = make_mesh_sim_scan(_loss_fn, params, lr=1e-2,
                                 strategy="eftopk")
        out = sim(_copy(params), res0, xs)
        # rerun rounds 0 and 2 only -> same endpoint
        xs2 = jax.tree.map(lambda a: a[jnp.asarray([0, 2])], xs)
        out2 = sim(_copy(params), _residuals0(params, xs["active"].shape[1],
                                              "eftopk"), xs2)
        for a, b in zip(jax.tree.leaves(out["params"]),
                        jax.tree.leaves(out2["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(out["residuals"]),
                        jax.tree.leaves(out2["residuals"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestEFCarrySemantics:
    def test_matches_aggregate_updates(self):
        """On a single flat leaf the per-leaf mesh path and the flat-space
        substrate coincide: the scanned driver's EF residual carry must
        reproduce `engine.aggregate_updates` round by round, bitwise."""
        rng = np.random.default_rng(7)
        n, c, s, b, t = 64, 3, 2, 4, 4
        params = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}

        def loss_fn(p, batch):
            pred = batch["x"] @ p["w"]
            err = pred - batch["t"]
            return jnp.mean(err * err), pred

        _, xs = _setup(seed=7, t=t, c=c, s=s, b=b, dim=n, out=1)
        xs["batches"]["t"] = xs["batches"]["t"][..., 0]
        sim = make_mesh_sim_scan(loss_fn, params, lr=1e-2,
                                 strategy="eftopk")
        out = sim(_copy(params), init_mesh_residuals(params, c), xs)

        spec = ClientUpdateSpec(strategy="eftopk", use_kernel=False)
        local = make_masked_local_trainer(loss_fn, 1e-2)
        flat = params["w"]
        res = jnp.zeros((c, n), jnp.float32)
        for r in range(t):
            batch_r = jax.tree.map(lambda a: a[r], xs["batches"])
            deltas, _ = jax.vmap(local, in_axes=(None, 0, 0))(
                {"w": flat}, batch_r, xs["step_mask"][r])
            ks = k_for_ratio_traced(n, xs["crs"][r])
            w = jnp.where(xs["active"][r], xs["weights"][r], 0.0)
            agg, res = aggregate_updates(spec, deltas["w"], w, ks,
                                         residuals=res,
                                         active=xs["active"][r])
            flat = flat - agg
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(flat))
        np.testing.assert_array_equal(np.asarray(out["residuals"]["w"]),
                                      np.asarray(res))


class TestChunkCompiles:
    def test_one_trace_per_chunk_shape(self):
        """Equal-length checkpoint chunks reuse ONE executable; only a
        ragged tail chunk costs a second trace."""
        params, xs = _setup(seed=5, t=6)
        key = ("mesh_scan", "bcrs_opwa")
        sim = make_mesh_sim_scan(_loss_fn, params, lr=1e-2,
                                 strategy="bcrs_opwa")
        before = engine_mod.TRACE_COUNTS[key]
        p, res = _copy(params), jnp.zeros((0,), jnp.float32)
        for lo in (0, 2, 4):    # 3 chunks of 2 rounds
            chunk = jax.tree.map(lambda a: a[lo:lo + 2], xs)
            out = sim(p, res, chunk)
            p, res = out["params"], out["residuals"]
        assert engine_mod.TRACE_COUNTS[key] - before == 1
        # a ragged final chunk is a second shape -> exactly one more trace
        out = sim(p, res, jax.tree.map(lambda a: a[:1], xs))
        assert engine_mod.TRACE_COUNTS[key] - before == 2


class TestCompressMergeLeafKernel:
    """Satellite: `use_kernel` is a tri-state plumbed through the per-leaf
    path — "auto" must resolve to the jnp route on CPU bit-exactly, and the
    interpret-mode megakernel route must match the jnp route bitwise."""

    def _inputs(self):
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.normal(size=(4, 6, 37)), jnp.float32)
        res = jnp.asarray(rng.normal(size=(4, 6, 37)) * 0.3, jnp.float32)
        w = jnp.asarray(rng.random(4), jnp.float32)
        ks = jnp.asarray([1, 20, 222, 100], jnp.int32)
        act = jnp.asarray([True, False, True, True])
        return u, res, w, ks, act

    @pytest.mark.parametrize("opwa", (False, True))
    @pytest.mark.parametrize("ef", (False, True))
    def test_auto_and_kernel_match_jnp(self, opwa, ef):
        u, res, w, ks, act = self._inputs()
        r = res if ef else None
        outs = {uk: compress_merge_leaf(u, w, ks, gamma=3.0, opwa=opwa,
                                        use_kernel=uk, residuals=r,
                                        active=act)
                for uk in (False, True, "auto")}
        ref_agg, ref_res = outs[False]
        for uk in (True, "auto"):
            agg, new_res = outs[uk]
            np.testing.assert_array_equal(np.asarray(agg),
                                          np.asarray(ref_agg))
            if ef:
                np.testing.assert_array_equal(np.asarray(new_res),
                                              np.asarray(ref_res))

    def test_auto_resolves_to_jnp_off_tpu(self):
        from repro.core.compression import resolve_use_kernel
        if jax.devices()[0].platform != "tpu":
            assert resolve_use_kernel("auto") is False


class TestKForRatioHelpers:
    def test_traced_matches_host_grid(self):
        """The shared rounding rule: the traced twin must agree with the
        host `k_for_ratio` across n and CR grids (incl. CR=1 -> k=n and
        tiny CRs -> k=1)."""
        crs = np.concatenate([np.geomspace(1e-4, 1.0, 60),
                              [0.05, 0.1, 0.25, 0.5, 1.0]])
        for n in (1, 7, 100, 8192, 65536):
            host = np.array([k_for_ratio(n, float(c)) for c in crs])
            traced = np.asarray(
                k_for_ratio_traced(n, jnp.asarray(crs, jnp.float32)))
            np.testing.assert_array_equal(host, traced)
            assert traced.min() >= 1 and traced.max() <= n


class TestScheduleBatch:
    def test_rowwise_bit_exact_with_make_schedule(self):
        """The vectorized R-round schedule must equal per-round
        `make_schedule` over each round's active prefix, bit-for-bit,
        despite cohort-slot padding."""
        from repro.core.cost_model import sample_links
        links = sample_links(8, np.random.default_rng(1))
        r_n, c = 6, 5
        v_bytes = 4e6
        active = np.zeros((r_n, c), bool)
        bw = np.ones((r_n, c))
        lat = np.zeros((r_n, c))
        fr = np.zeros((r_n, c))
        sels = []
        rng = np.random.default_rng(3)
        for r in range(r_n):
            c_r = int(rng.integers(2, c + 1))
            sel = rng.choice(8, c_r, replace=False)
            sels.append(sel)
            active[r, :c_r] = True
            bw[r, :c_r] = [links[i].bandwidth_bps for i in sel]
            lat[r, :c_r] = [links[i].latency_s for i in sel]
            fr[r, :c_r] = rng.dirichlet(np.ones(c_r))
        crs_b, coef_b, tb = bcrs_mod.make_schedule_batch(
            bw, lat, fr, v_bytes, 0.05, 1.0, active=active)
        for r in range(r_n):
            c_r = int(active[r].sum())
            sched = bcrs_mod.make_schedule([links[i] for i in sels[r]],
                                           fr[r, :c_r], v_bytes, 0.05, 1.0)
            np.testing.assert_array_equal(sched.crs, crs_b[r, :c_r])
            np.testing.assert_array_equal(sched.coefficients,
                                          coef_b[r, :c_r])
            assert sched.t_bench == tb[r]
            assert (crs_b[r, c_r:] == 0).all()
            assert (coef_b[r, c_r:] == 0).all()


class TestFlTrainDriver:
    """End-to-end driver contract on a reduced real arch: engine parity,
    one compile per chunk shape, and bit-exact checkpoint/restart
    including the carried EF residual state."""

    BASE = dict(arch="stablelm-1.6b", reduced=True, clients=4,
                local_steps=1, batch=2, seq=16, cr=0.1, seed=5,
                verbose=False)

    def _run(self, **kw):
        from repro.launch.fl_train import FLTrainConfig, run
        return run(FLTrainConfig(**{**self.BASE, **kw}))

    def test_scan_matches_round_engine_under_faults(self):
        kw = dict(rounds=4, strategy="bcrs_opwa", fail_prob=0.25,
                  over_selection=0.5, participation=0.75,
                  checkpoint_every=2)
        key = ("mesh_scan", "bcrs_opwa")
        before = engine_mod.TRACE_COUNTS[key]
        scan = self._run(engine="scan", **kw)
        assert engine_mod.TRACE_COUNTS[key] - before == 1
        assert sum(scan["chunk_rounds"]) == len(scan["executed_rounds"])
        loop = self._run(engine="round", **kw)
        assert scan["executed_rounds"] == loop["executed_rounds"]
        np.testing.assert_array_equal(np.asarray(scan["losses"]),
                                      np.asarray(loop["losses"]))
        for a, b in zip(jax.tree.leaves(scan["params"]),
                        jax.tree.leaves(loop["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resumes_legacy_params_only_checkpoint(self, tmp_path):
        """A checkpoint from the pre-scan driver (bare params pytree, no
        'params/' prefix, no residual state) must actually LOAD — not
        silently fall back to fresh weights while skipping rounds."""
        from repro import checkpoint as ckpt
        ref = self._run(rounds=2, strategy="bcrs_opwa")
        ckpt.save(str(tmp_path), 2, ref["params"])   # legacy layout
        resumed = self._run(rounds=2, strategy="bcrs_opwa",
                            checkpoint_dir=str(tmp_path))
        assert resumed["resumed_from"] == 2
        assert resumed["executed_rounds"] == []      # nothing left to run
        # the returned params must be the RESTORED (trained) ones — a silent
        # no-match fallback would hand back the fresh init instead
        for a, b in zip(jax.tree.leaves(ref["params"]),
                        jax.tree.leaves(resumed["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_restore_rejects_unrelated_structure(self, tmp_path):
        """`strict=False` is for partial restores; a checkpoint sharing NO
        leaf with the requested structure is a layout mismatch and raises."""
        from repro import checkpoint as ckpt
        ckpt.save(str(tmp_path), 2, {"foo": np.zeros((3,), np.float32)})
        with pytest.raises(ckpt.LayoutMismatch, match="no leaves"):
            ckpt.restore(str(tmp_path), {"bar": np.zeros((3,), np.float32)},
                         strict=False)

    def test_restore_rejects_shape_drift(self, tmp_path):
        """A matching key with a drifted shape (e.g. EF residuals saved for
        a different cohort size) must fail at load with a named error, not
        later inside the compiled scan."""
        from repro import checkpoint as ckpt
        ckpt.save(str(tmp_path), 1, {"r": np.zeros((4, 3), np.float32)})
        with pytest.raises(ValueError, match="shape"):
            ckpt.restore(str(tmp_path), {"r": np.zeros((8, 3), np.float32)},
                         strict=False)

    def test_restart_bit_exact_with_residuals(self, tmp_path):
        kw = dict(strategy="eftopk", fail_prob=0.2, checkpoint_every=2)
        full = self._run(rounds=6, **kw)
        part = self._run(rounds=3, checkpoint_dir=str(tmp_path), **kw)
        assert part["resumed_from"] is None
        resumed = self._run(rounds=6, checkpoint_dir=str(tmp_path), **kw)
        assert resumed["resumed_from"] == 3
        assert (part["executed_rounds"] + resumed["executed_rounds"]
                == full["executed_rounds"])
        for a, b in zip(jax.tree.leaves(full["params"]),
                        jax.tree.leaves(resumed["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(full["residuals"]),
                        jax.tree.leaves(resumed["residuals"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
