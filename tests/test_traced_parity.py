"""Statistical-equivalence harness for the fully-traced sampling engine
(ROADMAP open item): ``run_fl_traced`` draws cohorts/failures/batches from
its own in-jit PRNG stream, so it cannot be bit-compared with the host-rng
engines — instead the MOMENTS of its accuracy trajectory over seeds must
match the host-rng scan engine's.

Both engines see the same seeded dataset/partition/links per seed (the
``_setup_sim`` host-rng prefix is shared); only the per-round sampling
streams differ. With >= 5 seeds the mean trajectories must agree within a
few pooled standard errors, and the cross-seed spread must be the same
order — a distribution-level parity check, deliberately robust to the
per-seed noise that bit-parity tests cannot tolerate.
"""
import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl, run_fl_traced

SEEDS = (0, 1, 2, 3, 4)
CFG = dict(rounds=6, n_clients=8, participation=0.75, n_train=1600,
           n_test=500, dim=64, hidden=64, n_classes=10, batch_size=32,
           eval_every=2, noise=3.0)


def _trajectories(acfg):
    host, traced = [], []
    for seed in SEEDS:
        cfg = FLSimConfig(seed=seed, **CFG)
        h = run_fl(cfg, acfg, engine="scan")
        t = run_fl_traced(cfg, acfg)
        assert [r for r, _ in h.accuracies] == [r for r, _ in t.accuracies]
        host.append([a for _, a in h.accuracies])
        traced.append([a for _, a in t.accuracies])
    return np.asarray(host), np.asarray(traced)   # [S, E] each


class TestTracedSamplingMoments:
    def test_matched_moments_bcrs_opwa(self):
        host, traced = _trajectories(
            AggregationConfig(strategy="bcrs_opwa", cr=0.1))
        # first moment: mean trajectory within 3 pooled standard errors
        # (floored at 5 accuracy points — the two streams are genuinely
        # different samples, not the same draw)
        sem = np.sqrt((host.var(0, ddof=1) + traced.var(0, ddof=1))
                      / len(SEEDS))
        gap = np.abs(host.mean(0) - traced.mean(0))
        assert (gap <= np.maximum(3.0 * sem, 0.05)).all(), (gap, sem)
        # second moment: cross-seed spread of the final accuracy is the
        # same order of magnitude (neither stream collapses or explodes)
        s_h, s_t = host[:, -1].std(ddof=1), traced[:, -1].std(ddof=1)
        assert s_t <= 5.0 * s_h + 0.02 and s_h <= 5.0 * s_t + 0.02
        # both engines actually learn
        assert host[:, -1].mean() > 0.3 and traced[:, -1].mean() > 0.3

    def test_matched_final_accuracy_eftopk(self):
        host, traced = _trajectories(
            AggregationConfig(strategy="eftopk", cr=0.05))
        gap = abs(host[:, -1].mean() - traced[:, -1].mean())
        sem = np.sqrt((host[:, -1].var(ddof=1)
                       + traced[:, -1].var(ddof=1)) / len(SEEDS))
        assert gap <= max(3.0 * sem, 0.05), (gap, sem)
