"""Traced-k Pallas megakernel pipeline: bit-exact parity with the jnp
reference path across every strategy, per-client ks pattern, and padding
edge, plus the regression for the old static-CR EF-kernel route.

Everything runs the kernels in interpret mode (this suite executes on CPU);
the jnp path of ``fed.engine.aggregate_updates`` is the parity oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hyputil import given, settings, st

from repro.core import compression as C
from repro.core.opwa import opwa_aggregate_traced_k
from repro.fed import engine
from repro.kernels import ops, ref
from repro.kernels.fused_merge import fused_merge_pallas
from repro.kernels.threshold_find import threshold_find_pallas

STRATEGIES = ("fedavg", "topk", "eftopk", "bcrs", "bcrs_opwa")


def _bits(x):
    return jax.lax.bitcast_convert_type(
        jnp.abs(jnp.asarray(x, jnp.float32)), jnp.uint32)


def _case(c, n, seed=0, scale=1.0):
    key = jax.random.PRNGKey(seed)
    ku, ke, kw, kk = jax.random.split(key, 4)
    u = jax.random.normal(ku, (c, n)) * scale
    e = jax.random.normal(ke, (c, n)) * 0.3 * scale
    w = jax.random.uniform(kw, (c,)) + 0.1
    w = w / jnp.sum(w)
    ks = jax.random.randint(kk, (c,), 1, n + 1).astype(jnp.int32)
    return u, e, w, ks


class TestThresholdFind:
    @pytest.mark.parametrize("c,n", [(1, 512), (8, 4096), (16, 1024),
                                     (3, 512 * 7)])
    def test_vs_ref(self, c, n):
        u, e, _, ks = _case(c, n, seed=c * 100 + n)
        th = threshold_find_pallas(u, ks.reshape(c, 1), interpret=True)
        np.testing.assert_array_equal(np.asarray(th),
                                      np.asarray(ref.threshold_find_ref(u, ks)))
        # EF variant selects on corrected = residuals + updates
        th_ef = threshold_find_pallas(u, ks.reshape(c, 1), e, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(th_ef), np.asarray(ref.threshold_find_ref(u, ks, e)))

    @pytest.mark.parametrize("k", [1, 2, 511, 512])
    def test_k_edges_exact_mask(self, k):
        u, _, _, _ = _case(4, 512, seed=k)
        ks = jnp.full((4,), k, jnp.int32)
        th = threshold_find_pallas(u, ks.reshape(4, 1), interpret=True)
        mask = _bits(u) >= th
        np.testing.assert_array_equal(
            np.asarray(mask),
            np.asarray(C.topk_compress_batch(u, ks).mask))

    def test_ties_zeros_and_scales(self):
        u, _, _, _ = _case(6, 1024, seed=7)
        u = u.at[0].set(0.0)                       # all-zero row
        u = u.at[1, :500].set(u[1, 0])             # heavy ties
        u = u.at[2].mul(1e-40)                     # subnormal magnitudes
        u = u.at[3].mul(1e30)
        ks = jnp.asarray([5, 500, 13, 1, 1024, 512], jnp.int32)
        th = threshold_find_pallas(u, ks.reshape(6, 1), interpret=True)
        np.testing.assert_array_equal(np.asarray(th),
                                      np.asarray(ref.threshold_find_ref(u, ks)))

    def test_wrapper_pads_ragged_n(self):
        u, e, _, ks = _case(5, 700, seed=3)
        th = ops.topk_thresholds(u, ks)
        np.testing.assert_array_equal(
            np.asarray(th), np.asarray(ref.threshold_find_ref(u, ks))[:, 0])
        th_ef = ops.topk_thresholds(u, ks, residuals=e)
        np.testing.assert_array_equal(
            np.asarray(th_ef),
            np.asarray(ref.threshold_find_ref(u, ks, e))[:, 0])


class TestFusedMerge:
    @pytest.mark.parametrize("opwa", [False, True])
    @pytest.mark.parametrize("ef", [False, True])
    @pytest.mark.parametrize("gated", [False, True])
    def test_vs_ref(self, opwa, ef, gated):
        c, n = 7, 2048
        u, e, w, ks = _case(c, n, seed=11)
        active = (jnp.asarray([True] * 5 + [False] * 2) if gated else None)
        if gated:
            u = u * active[:, None]                # padded rows are zero
        th = ref.threshold_find_ref(u, ks, e if ef else None)
        act_f = active.astype(jnp.float32).reshape(c, 1) if gated else None
        out = fused_merge_pallas(u, th, w.reshape(c, 1),
                                 e if ef else None, act_f,
                                 opwa=opwa, gamma=4.0, d=2, interpret=True)
        want = ref.fused_merge_ref(u, th, w, e if ef else None,
                                   active if gated else None,
                                   opwa=opwa, gamma=4.0, d=2)
        if ef:
            np.testing.assert_array_equal(np.asarray(out[0]),
                                          np.asarray(want[0]))
            np.testing.assert_array_equal(np.asarray(out[1]),
                                          np.asarray(want[1]))
        else:
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(want))


def _agg_both(strategy, u, w, ks, residuals=None, active=None, **spec_kw):
    """aggregate_updates through the kernel route and the jnp reference."""
    res = dict()
    for use_kernel in (False, True):
        spec = engine.ClientUpdateSpec(strategy=strategy,
                                       use_kernel=use_kernel, **spec_kw)
        res[use_kernel] = engine.aggregate_updates(
            spec, u, w, ks, residuals=residuals, active=active)
    return res


class TestAggregateUpdatesParity:
    """Kernel-routed aggregate_updates must match the traced jnp path BIT
    FOR BIT for all five strategies with per-client traced ks."""

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_bit_exact(self, strategy):
        u, e, w, ks = _case(9, 3000, seed=21)
        residuals = e if strategy == "eftopk" else None
        out = _agg_both(strategy, u, w, ks, residuals=residuals, gamma=5.0)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        if strategy == "eftopk":
            np.testing.assert_array_equal(np.asarray(out[True][1]),
                                          np.asarray(out[False][1]))

    @pytest.mark.parametrize("strategy", STRATEGIES)
    def test_bit_exact_with_active_padding(self, strategy):
        c_act, c_pad, n = 5, 3, 2048
        u, e, w, ks = _case(c_act + c_pad, n, seed=33)
        active = jnp.asarray([True] * c_act + [False] * c_pad)
        u = u * active[:, None]
        w = jnp.where(active, w, 0.0)
        residuals = e if strategy == "eftopk" else None
        out = _agg_both(strategy, u, w, ks, residuals=residuals,
                        active=active, gamma=3.0, overlap_d=2)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        if strategy == "eftopk":
            # inactive rows' residuals pass through unchanged on both routes
            np.testing.assert_array_equal(np.asarray(out[True][1]),
                                          np.asarray(out[False][1]))
            np.testing.assert_array_equal(
                np.asarray(out[True][1][c_act:]), np.asarray(e[c_act:]))

    def test_k_extremes_and_ties(self):
        u, e, w, _ = _case(4, 1024, seed=5)
        u = u.at[2].set(0.0)
        u = u.at[3, :700].set(u[3, 0])
        ks = jnp.asarray([1, 1024, 512, 700], jnp.int32)
        for strategy in ("topk", "bcrs_opwa", "eftopk"):
            residuals = e if strategy == "eftopk" else None
            out = _agg_both(strategy, u, w, ks, residuals=residuals)
            np.testing.assert_array_equal(np.asarray(out[True][0]),
                                          np.asarray(out[False][0]))


class TestEFKernelKsRegression:
    """The old ``use_ef_kernel`` route compressed at the STATIC spec.cr,
    silently ignoring varying traced ks. Both kernel-on EF configs must now
    honor the per-client counts exactly."""

    def _varying(self):
        u, e, w, _ = _case(6, 4096, seed=44)
        # strongly varying BCRS-style retained counts — the old route kept
        # k_for_ratio(block, cr)=410 per block for every client
        ks = jnp.asarray([1, 41, 410, 1200, 3000, 4096], jnp.int32)
        return u, e, w, ks

    def test_global_ef_kernel_honors_traced_ks(self):
        u, e, w, ks = self._varying()
        out = _agg_both("eftopk", u, w, ks, residuals=e, cr=0.1)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))

    def test_block_ef_kernel_config_honors_traced_ks(self):
        u, e, w, _ = self._varying()
        ks_block = jnp.asarray([1, 8, 64, 256, 410, 512], jnp.int32)
        out = _agg_both("eftopk", u, w, ks_block, residuals=e,
                        cr=0.1, block_topk=True, block_size=512)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))

    def test_retained_counts_follow_ks_not_cr(self):
        """Direct symptom check: retained count per client == ks, not the
        static-CR count the old kernel route produced."""
        u, e, _, ks = self._varying()
        spec = engine.ClientUpdateSpec(strategy="eftopk", use_kernel=True,
                                       cr=0.1)
        comp_obj, _ = C.ef_compress_batch(e, u, ks, use_kernel=True)
        kept = np.asarray(jnp.sum(comp_obj.mask, axis=1))
        np.testing.assert_array_equal(kept, np.asarray(ks))
        assert spec.use_megakernel


class TestCompressionKernelRoutes:
    def test_topk_compress_batch_kernel_route(self):
        u, _, _, ks = _case(5, 3333, seed=9)
        a = C.topk_compress_batch(u, ks)
        b = C.topk_compress_batch(u, ks, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
        np.testing.assert_array_equal(np.asarray(a.values),
                                      np.asarray(b.values))

    def test_ef_compress_batch_kernel_route(self):
        u, e, _, ks = _case(5, 3333, seed=10)
        a, ra = C.ef_compress_batch(e, u, ks)
        b, rb = C.ef_compress_batch(e, u, ks, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
        np.testing.assert_array_equal(np.asarray(a.values),
                                      np.asarray(b.values))
        np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))

    def test_ef_kernel_route_rejects_custom_compressor(self):
        """use_kernel=True implements global Top-K only — combining it with
        a non-global compressor must fail loudly, not silently switch."""
        u, e, _, ks = _case(3, 1024, seed=11)
        with pytest.raises(ValueError, match="global Top-K"):
            C.ef_compress_batch(e, u, ks,
                                compress_batch=C.block_topk_compress_batch,
                                use_kernel=True)

    def test_opwa_traced_k_routes_agree(self):
        u, _, w, ks = _case(8, 2048, seed=12)
        a = opwa_aggregate_traced_k(u, ks, w, 5.0, 1, use_kernel=False)
        b = opwa_aggregate_traced_k(u, ks, w, 5.0, 1, use_kernel=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestKernelProperty:
    """Hypothesis sweep: random shapes, ks patterns (k=1, k=n, ties at the
    threshold, all-zero rows, inactive masks) — agg and residuals bit-exact
    for every strategy."""

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 10), st.integers(2, 1500), st.integers(0, 10 ** 6),
           st.sampled_from(["topk", "eftopk", "bcrs", "bcrs_opwa"]))
    def test_bit_exact_everywhere(self, c, n, seed, strategy):
        rng = np.random.default_rng(seed)
        u = rng.normal(size=(c, n)).astype(np.float32)
        u *= 10.0 ** rng.integers(-12, 12, size=(c, 1)).astype(np.float32)
        if rng.random() < 0.3:
            u[rng.integers(c)] = 0.0               # all-zero row
        if rng.random() < 0.3 and n > 3:
            r = int(rng.integers(c))
            u[r, : n // 2] = u[r, 0]               # ties at the threshold
        ks = rng.integers(1, n + 1, size=c).astype(np.int32)
        ks[rng.integers(c)] = 1
        ks[rng.integers(c)] = n
        active = None
        if rng.random() < 0.5:
            active = rng.random(c) < 0.7
            active[rng.integers(c)] = True         # >= 1 active row
            u *= active[:, None]
        w = (rng.random(c) + 0.05).astype(np.float32)
        e = (rng.normal(size=(c, n)) * 0.3).astype(np.float32)
        residuals = jnp.asarray(e) if strategy == "eftopk" else None
        out = _agg_both(strategy, jnp.asarray(u), jnp.asarray(w),
                        jnp.asarray(ks),
                        residuals=residuals,
                        active=jnp.asarray(active) if active is not None
                        else None,
                        gamma=float(rng.uniform(1.0, 8.0)),
                        overlap_d=int(rng.integers(1, c + 1)))
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        if strategy == "eftopk":
            np.testing.assert_array_equal(np.asarray(out[True][1]),
                                          np.asarray(out[False][1]))


class TestKernelRoutedScanSim:
    """The kernel-routed scan simulation still compiles exactly once and its
    trajectory is bit-exact with the jnp-routed scan engine."""

    def test_one_compile_and_parity(self):
        from repro.core.aggregation import AggregationConfig
        from repro.fed.simulation import FLSimConfig, run_fl
        cfg = FLSimConfig(rounds=4, n_clients=6, n_train=1200, n_test=300,
                          dim=32, hidden=32, n_classes=5, eval_every=2,
                          seed=2)
        accs = {}
        for use_kernel in (False, True):
            acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.1,
                                     use_kernel=use_kernel)
            before = sum(engine.TRACE_COUNTS.values())
            res = run_fl(cfg, acfg, engine="scan")
            assert sum(engine.TRACE_COUNTS.values()) - before == 1
            accs[use_kernel] = np.array([a for _, a in res.accuracies])
        np.testing.assert_array_equal(accs[True], accs[False])

# --------------------------------------------------------------- codec stage
CODEC_STRATEGIES = ("qtopk", "int4")


def _codec_of(strategy):
    from repro.core import strategies as strat_mod
    return strat_mod.get(strategy).kernel_codec


def _codec_scales(corrected, codec):
    from repro.core.strategies import CODEC_LEVELS, quantization_scale
    absmax = jnp.max(jnp.abs(corrected.astype(jnp.float32)), axis=1,
                     keepdims=True)
    return quantization_scale(absmax, CODEC_LEVELS[codec])


class TestFusedMergeCodec:
    """Tile-level oracle parity for the quantize/dequantize merge stage."""

    @pytest.mark.parametrize("codec", ["int8", "int4"])
    @pytest.mark.parametrize("gated", [False, True])
    @pytest.mark.parametrize("opwa", [False, True])
    def test_vs_ref(self, codec, gated, opwa):
        c, n = 7, 2048
        u, e, w, ks = _case(c, n, seed=61)
        u = u.at[3].set(0.0)                    # all-zero row -> scale 0
        e = e.at[3].set(0.0)
        th = ref.threshold_find_ref(u, ks, e)
        scales = _codec_scales(e + u, codec)
        active = jnp.asarray([1.0] * (c - 2) + [0.0] * 2).reshape(c, 1)
        act = active if gated else None
        out = fused_merge_pallas(u, th, w.reshape(c, 1), e, act,
                                 opwa=opwa, gamma=4.0, d=2, codec=codec,
                                 scales=scales, interpret=True)
        want = ref.fused_merge_ref(u, th, w, e, act, opwa=opwa, gamma=4.0,
                                   d=2, codec=codec, scales=scales)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(want[1]))

    def test_codec_requires_scales_and_residuals(self):
        c, n = 3, 1024
        u, e, w, ks = _case(c, n, seed=62)
        th = ref.threshold_find_ref(u, ks, e)
        with pytest.raises(AssertionError, match="scales"):
            fused_merge_pallas(u, th, w.reshape(c, 1), e, codec="int8",
                               interpret=True)
        with pytest.raises(AssertionError, match="residuals"):
            fused_merge_pallas(u, th, w.reshape(c, 1), codec="int8",
                               scales=_codec_scales(e + u, "int8"),
                               interpret=True)


class TestFusedMergeRaggedWidth:
    """The merge kernel zero-pads ragged widths internally (the old hard
    ``n % TILE_N == 0`` assert) and slices the outputs back."""

    @pytest.mark.parametrize("n", [4, 10, 1500, 2050])
    @pytest.mark.parametrize("codec", ["none", "int8"])
    def test_vs_ref_even_ragged(self, n, codec):
        # even widths: the jnp reference einsum and the kernel's tile-padded
        # dot accumulate identically (see DESIGN.md §10 on the XLA:CPU gemv
        # tail of small ODD widths — a pre-existing artifact shared by every
        # kernel strategy, orthogonal to padding and codecs)
        c = 5
        u, e, w, ks = _case(c, n, seed=63 + n)
        th = ref.threshold_find_ref(u, ks, e)
        scales = _codec_scales(e + u, "int8") if codec != "none" else None
        out = fused_merge_pallas(u, th, w.reshape(c, 1), e, codec=codec,
                                 scales=scales, interpret=True)
        want = ref.fused_merge_ref(u, th, w, e, codec=codec, scales=scales)
        assert out[0].shape == (1, n) and out[1].shape == (c, n)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(want[1]))

    def test_odd_width_residuals_exact(self):
        # literally-odd width: the elementwise outputs (residuals) are still
        # bit-exact; the merged aggregate is only pinned to a few ULP
        # because the reference's [C, n] gemv uses a different tail
        # accumulation than the kernel's tile-aligned dot at small odd n
        c, n = 5, 17
        u, e, w, ks = _case(c, n, seed=64)
        th = ref.threshold_find_ref(u, ks, e)
        out = fused_merge_pallas(u, th, w.reshape(c, 1), e, interpret=True)
        want = ref.fused_merge_ref(u, th, w, e)
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(want[1]))
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want[0]),
                                   rtol=1e-6, atol=0)


class TestCodecScaleProvenance:
    """threshold_find's emitted absmax IS the jnp codec's scale source: for
    Top-K (ties kept, k >= 1) the survivors' absmax equals the row absmax,
    and fp max is exact, so the tile-accumulated max matches ``jnp.max``
    bit for bit — including all-zero rows (scale 0) and tied rows."""

    def test_absmax_matches_row_max(self):
        c, n = 6, 512 * 5
        u, e, _, ks = _case(c, n, seed=65)
        u = u.at[2].set(0.0)
        e = e.at[2].set(0.0)
        u = u.at[4, :600].set(u[4, 0])          # ties
        th, absmax = threshold_find_pallas(u, ks.reshape(c, 1), e,
                                           emit_scale=True, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(th),
            np.asarray(threshold_find_pallas(u, ks.reshape(c, 1), e,
                                             interpret=True)))
        want = jnp.max(jnp.abs(e + u), axis=1, keepdims=True)
        np.testing.assert_array_equal(np.asarray(absmax), np.asarray(want))

    def test_survivor_absmax_equals_row_absmax(self):
        u, e, _, ks = _case(8, 2048, seed=66)
        corrected = e + u
        comp = jax.vmap(C.topk_compress_dynamic)(corrected, ks)
        surv = jnp.max(jnp.abs(comp.values), axis=1)
        np.testing.assert_array_equal(
            np.asarray(surv), np.asarray(jnp.max(jnp.abs(corrected), axis=1)))


class TestCodecKernelParity:
    """End-to-end aggregate_updates: the codec megakernel route must match
    the jnp value_codec path bit for bit — aggregate AND EF residuals."""

    @pytest.mark.parametrize("strategy", CODEC_STRATEGIES)
    def test_bit_exact(self, strategy):
        u, e, w, ks = _case(9, 3000, seed=67)
        out = _agg_both(strategy, u, w, ks, residuals=e, gamma=5.0)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))

    @pytest.mark.parametrize("strategy", CODEC_STRATEGIES)
    def test_bit_exact_with_active_padding(self, strategy):
        c_act, c_pad, n = 5, 3, 2048
        u, e, w, ks = _case(c_act + c_pad, n, seed=68)
        active = jnp.asarray([True] * c_act + [False] * c_pad)
        u = u * active[:, None]
        w = jnp.where(active, w, 0.0)
        out = _agg_both(strategy, u, w, ks, residuals=e, active=active,
                        gamma=3.0, overlap_d=2)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))
        # inactive rows' residuals pass through unchanged on both routes
        np.testing.assert_array_equal(np.asarray(out[True][1][c_act:]),
                                      np.asarray(e[c_act:]))

    @pytest.mark.parametrize("strategy", CODEC_STRATEGIES)
    def test_k_extremes_ties_and_zero_rows(self, strategy):
        u, e, w, _ = _case(4, 1024, seed=69)
        u = u.at[2].set(0.0)                    # zero row: codec scale 0
        e = e.at[2].set(0.0)
        u = u.at[3, :700].set(u[3, 0])          # ties at the threshold
        ks = jnp.asarray([1, 1024, 512, 700], jnp.int32)
        out = _agg_both(strategy, u, w, ks, residuals=e)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))
        # the zero row's residual stays exactly zero on both routes
        assert not np.any(np.asarray(out[True][1][2]))


class TestKernelPropertyCodec:
    """Hypothesis sweep for the codec strategies: random shapes, per-client
    ks, ties, zero rows, inactive masks — agg and residuals bit-exact.
    Widths are even (see DESIGN.md §10: XLA:CPU's gemv accumulates the tail
    of small odd widths differently between the reference's [C, n] einsum
    and the kernel's tile-aligned dot — for every kernel strategy, codec or
    not — so odd widths are pinned at tile level, not end-to-end)."""

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 10), st.integers(1, 750), st.integers(0, 10 ** 6),
           st.sampled_from(CODEC_STRATEGIES))
    def test_bit_exact_everywhere(self, c, half_n, seed, strategy):
        n = 2 * half_n
        rng = np.random.default_rng(seed)
        u = rng.normal(size=(c, n)).astype(np.float32)
        u *= 10.0 ** rng.integers(-12, 12, size=(c, 1)).astype(np.float32)
        if rng.random() < 0.3:
            u[rng.integers(c)] = 0.0               # all-zero row
        if rng.random() < 0.3 and n > 3:
            r = int(rng.integers(c))
            u[r, : n // 2] = u[r, 0]               # ties at the threshold
        ks = rng.integers(1, n + 1, size=c).astype(np.int32)
        ks[rng.integers(c)] = 1
        ks[rng.integers(c)] = n
        e = (rng.normal(size=(c, n)) * 0.3).astype(np.float32)
        active = None
        if rng.random() < 0.5:
            active = rng.random(c) < 0.7
            active[rng.integers(c)] = True         # >= 1 active row
            u *= active[:, None]
            e = np.where(active[:, None], e, e * 0.5)
        w = (rng.random(c) + 0.05).astype(np.float32)
        out = _agg_both(strategy, jnp.asarray(u), jnp.asarray(w),
                        jnp.asarray(ks), residuals=jnp.asarray(e),
                        active=jnp.asarray(active) if active is not None
                        else None)
        np.testing.assert_array_equal(np.asarray(out[True][0]),
                                      np.asarray(out[False][0]))
        np.testing.assert_array_equal(np.asarray(out[True][1]),
                                      np.asarray(out[False][1]))


class TestCodecKernelRoutedScanSim:
    """The codec kernel route through the scanned driver: one compile, and
    the whole trajectory bit-exact with the jnp-routed scan."""

    def test_one_compile_and_parity(self):
        from repro.core.aggregation import AggregationConfig
        from repro.fed.simulation import FLSimConfig, run_fl
        cfg = FLSimConfig(rounds=4, n_clients=6, n_train=1200, n_test=300,
                          dim=32, hidden=32, n_classes=5, eval_every=2,
                          seed=3)
        accs = {}
        for use_kernel in (False, True):
            acfg = AggregationConfig(strategy="qtopk", cr=0.1,
                                     use_kernel=use_kernel)
            before = sum(engine.TRACE_COUNTS.values())
            res = run_fl(cfg, acfg, engine="scan")
            assert sum(engine.TRACE_COUNTS.values()) - before == 1
            accs[use_kernel] = np.array([a for _, a in res.accuracies])
        np.testing.assert_array_equal(accs[True], accs[False])
