"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + one decode step on CPU, asserting shapes and finiteness.
(The FULL configs are exercised only via the dry-run — no allocation here.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.dist.grad_sync import make_train_step
from repro.models import Model
from repro.optim import make_optimizer

B, S = 2, 32


def _batch(cfg):
    b = {"tokens": jnp.full((B, S), 5, jnp.int32),
         "labels": jnp.full((B, S), 7, jnp.int32)}
    if cfg.family == "encdec":
        b["frames"] = jnp.full((B, S, cfg.d_model), 0.1, jnp.float32)
    if cfg.family == "vlm":
        v = cfg.vision
        b["patches"] = jnp.full((B, v.n_patches, v.d_vision), 0.1, jnp.float32)
    return b


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step(self, arch, models):
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        models[arch] = (cfg, model, params)
        opt = make_optimizer("sgd", 1e-2)
        step = jax.jit(make_train_step(model, opt))
        new_params, _, metrics = step(params, opt.init(params), _batch(cfg))
        assert np.isfinite(float(metrics["loss"]))
        # a step must actually change the parameters
        diff = jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params))
        assert max(diff) > 0

    def test_decode_shapes_and_finite(self, arch, models):
        cfg, model, params = models[arch]
        cache = model.init_cache(B, S, jnp.float32)
        logits, new_cache = jax.jit(model.decode_step)(
            params, cache, jnp.full((B,), 3, jnp.int32), jnp.int32(0))
        assert logits.shape == (B, model.v_pad)
        assert np.isfinite(np.asarray(logits)).all()
        # cache structure is preserved (scan over layers round-trips)
        assert (jax.tree.structure(cache) == jax.tree.structure(new_cache))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
            assert a.shape == b.shape, (a.shape, b.shape)

    def test_multi_step_decode_no_nan(self, arch, models):
        cfg, model, params = models[arch]
        cache = model.init_cache(B, S, jnp.float32)
        step = jax.jit(model.decode_step)
        tok = jnp.full((B,), 3, jnp.int32)
        for pos in range(4):
            logits, cache = step(params, cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
            assert np.isfinite(np.asarray(logits)).all()

    def test_loss_decreases_under_training(self, arch, models):
        cfg, model, params = models[arch]
        opt = make_optimizer("sgd", 0.1 if cfg.family != "moe" else 0.05)
        step = jax.jit(make_train_step(model, opt))
        batch = _batch(cfg)  # constant batch -> loss must drop
        opt_state = opt.init(params)
        losses = []
        for _ in range(5):
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]


def test_param_counts_match_analytic():
    """cfg.n_params() within 2% of the actual initialized count (reduced
    configs; full configs use the same code path)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        model = Model(cfg)
        abs_params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abs_params))
        analytic = cfg.n_params()
        # vocab padding + small glue params (norms, gates, loras) dominate
        # at reduced scale; at full scale the counts match the published
        # numbers (see test_full_config_param_counts)
        assert abs(actual - analytic) / actual < 0.35, \
            f"{arch}: analytic {analytic} vs actual {actual}"


def test_full_config_param_counts():
    """Full-size analytic counts are in the published ballpark."""
    expect = {
        "deepseek-v3-671b": (600e9, 700e9),
        "kimi-k2-1t-a32b": (950e9, 1150e9),
        "qwen2.5-32b": (28e9, 36e9),
        "qwen2.5-14b": (13e9, 16e9),
        "yi-9b": (8e9, 10e9),
        "stablelm-1.6b": (1.3e9, 2.0e9),
        "rwkv6-1.6b": (1.3e9, 2.2e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
        "whisper-medium": (0.6e9, 1.0e9),
        "llama-3.2-vision-11b": (9e9, 12e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
