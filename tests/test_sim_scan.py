"""Scan-engine tests: the whole-simulation ``lax.scan`` program must be
bit-exact with the fused per-round engine on the shared seeded rng stream
(accuracy trajectory, comm-time accounting, EF residuals — including the
failure-injection and straggler-renormalization paths), compile exactly once
per simulation, and the fully-traced sampling variant must stand on its own.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import AggregationConfig
from repro.fed import engine
from repro.fed.simulation import (FLSimConfig, _steps_by_client, run_fl,
                                  run_fl_traced)
from repro.ft import FailureInjector, StragglerPolicy
from repro.ft.failures import survivors_traced
from repro.ft.straggler import (arrival_mask_traced,
                                renormalize_coefficients_traced)

FAST = dict(rounds=8, n_train=2000, n_test=600, eval_every=2, seed=3)


def _accs(res):
    return np.array([a for _, a in res.accuracies])


class TestScanParity:
    """engine="scan" and engine="fused" consume the identical host rng
    stream, so their trajectories must match BIT FOR BIT."""

    @pytest.mark.parametrize("strategy,kw", [
        ("fedavg", {}),
        ("topk", dict(cr=0.05)),
        ("eftopk", dict(cr=0.05)),
        ("bcrs", dict(cr=0.05)),
        ("bcrs_opwa", dict(cr=0.05, gamma=5.0)),
    ])
    def test_bitwise_accuracy_and_time_parity(self, strategy, kw):
        acfg = AggregationConfig(strategy=strategy, **kw)
        fused = run_fl(FLSimConfig(**FAST), acfg, engine="fused")
        scan = run_fl(FLSimConfig(**FAST), acfg, engine="scan")
        np.testing.assert_array_equal(_accs(scan), _accs(fused))
        assert scan.times.actual == fused.times.actual
        assert scan.executed_rounds == fused.executed_rounds
        if strategy == "eftopk":
            np.testing.assert_array_equal(scan.final_residuals,
                                          fused.final_residuals)

    @pytest.mark.parametrize("strategy", ["bcrs", "eftopk"])
    def test_failure_injection_parity(self, strategy):
        """Dead clients become zero-weight padded slots in the scan xs; the
        EF residual reset-on-cohort-resize bookkeeping must also line up."""
        acfg = AggregationConfig(strategy=strategy, cr=0.05)
        inj = FailureInjector(p_fail=0.3, seed=1)
        fused = run_fl(FLSimConfig(**FAST), acfg, failure=inj,
                       engine="fused")
        scan = run_fl(FLSimConfig(**FAST), acfg, failure=inj, engine="scan")
        assert scan.executed_rounds == fused.executed_rounds
        np.testing.assert_array_equal(_accs(scan), _accs(fused))
        assert scan.times.actual == fused.times.actual
        if strategy == "eftopk":
            np.testing.assert_array_equal(scan.final_residuals,
                                          fused.final_residuals)

    def test_straggler_renormalization_parity(self):
        """Over-selection + arrival deadline trims the cohort on host; both
        engines must see the same arrived set and renormalized weights."""
        pol = StragglerPolicy(over_selection=0.5)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        fused = run_fl(FLSimConfig(**FAST), acfg, straggler=pol,
                       engine="fused")
        scan = run_fl(FLSimConfig(**FAST), acfg, straggler=pol,
                      engine="scan")
        np.testing.assert_array_equal(_accs(scan), _accs(fused))
        assert scan.times.actual == fused.times.actual
        assert fused.final_accuracy > 0.35

    def test_overlap_histogram_parity(self):
        acfg = AggregationConfig(strategy="topk", cr=0.05)
        fused = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                       engine="fused")
        scan = run_fl(FLSimConfig(**FAST), acfg, collect_overlap=True,
                      engine="scan")
        np.testing.assert_array_equal(scan.overlap_hist, fused.overlap_hist)

    def test_legacy_engine_still_matches(self):
        """The engine= spelling routes to the same legacy loop the ``fused``
        bool used to select."""
        acfg = AggregationConfig(strategy="topk", cr=0.05)
        legacy = run_fl(FLSimConfig(**FAST), acfg, engine="legacy")
        scan = run_fl(FLSimConfig(**FAST), acfg, engine="scan")
        np.testing.assert_allclose(_accs(scan), _accs(legacy), atol=1e-3)


class TestScanCompileCount:
    """One scan simulation = exactly ONE trace of the scanned program,
    independent of rounds and cohort size."""

    def _traces(self):
        return sum(engine.TRACE_COUNTS.values())

    def _run(self, rounds, n_clients):
        cfg = FLSimConfig(rounds=rounds, n_clients=n_clients,
                          n_train=2000, n_test=300, eval_every=100, seed=1)
        before = self._traces()
        run_fl(cfg, AggregationConfig(strategy="bcrs_opwa", cr=0.05),
               engine="scan")
        return self._traces() - before

    def test_one_compile_per_simulation(self):
        assert self._run(rounds=3, n_clients=8) == 1
        assert self._run(rounds=12, n_clients=8) == 1

    def test_constant_in_clients(self):
        assert self._run(rounds=4, n_clients=6) == 1
        assert self._run(rounds=4, n_clients=12) == 1


class TestScanTrajectoryMemory:
    """The scanned program snapshots eval rounds into an O(E x n) carried
    buffer — it must NOT emit the model every round (O(rounds x n))."""

    def test_eval_buffer_is_o_evals_not_o_rounds(self):
        from jax.flatten_util import ravel_pytree
        from repro.fed.simulation import mlp_init, mlp_loss
        params = mlp_init(jax.random.PRNGKey(0), dim=8, n_classes=3,
                          hidden=8)
        flat = ravel_pytree(params)[0].astype(jnp.float32)
        n = flat.shape[0]
        r, c, s, b, e = 6, 2, 1, 4, 2
        sim_fn = engine.make_sim_scan(
            mlp_loss, params, lr=0.1,
            acfg=AggregationConfig(strategy="topk", cr=0.5))
        key = jax.random.PRNGKey(1)
        xs = {
            "batches": {
                "x": jax.random.normal(key, (r, c, s, b, 8)),
                "y": jnp.zeros((r, c, s, b), jnp.int32)},
            "step_mask": jnp.ones((r, c, s), bool),
            "active": jnp.ones((r, c), bool),
            "weights": jnp.full((r, c), 0.5, jnp.float32),
            "ks": jnp.full((r, c), 5, jnp.int32),
            "eval_write": jnp.asarray([False, False, True, False, False,
                                       True]),
            "eval_slot": jnp.asarray([0, 0, 0, 0, 0, 1], jnp.int32),
        }
        out = sim_fn(flat, jnp.zeros((0,), jnp.float32),
                     jnp.zeros((e, n), jnp.float32), xs)
        # O(E x n) snapshot buffer; the per-round ys carry no model copy
        assert out["evals"].shape == (e, n)
        assert "flat" not in out["ys"]
        assert all(v.ndim <= 1 for v in out["ys"].values())
        # the last snapshot is the final model (round 5 wrote slot 1)
        np.testing.assert_array_equal(np.asarray(out["evals"][1]),
                                      np.asarray(out["flat"]))


class TestStepCap:
    def test_quantile_cap_tightens_static_shape(self):
        from repro.data import (build_client_datasets, dirichlet_partition,
                                synthetic_classification)
        sim = FLSimConfig(**FAST)            # beta=0.1: extreme skew
        rng = np.random.default_rng(sim.seed)
        x, y = synthetic_classification(sim.n_train + sim.n_test,
                                        sim.n_classes, sim.dim, rng,
                                        noise=sim.noise)
        parts = dirichlet_partition(y[: sim.n_train], sim.n_clients,
                                    sim.beta, rng, min_size=sim.batch_size)
        clients = build_client_datasets(x[: sim.n_train], y[: sim.n_train],
                                        parts)
        full = _steps_by_client(clients, sim)
        capped = _steps_by_client(
            clients, FLSimConfig(**{**FAST, "step_cap_quantile": 0.5}))
        assert capped.max() < full.max()
        assert capped.min() == full.min()    # small clients untouched

    def test_capped_engines_agree_and_learn(self):
        cfg = FLSimConfig(**{**FAST, "step_cap_quantile": 0.5})
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.05)
        fused = run_fl(cfg, acfg, engine="fused")
        scan = run_fl(cfg, acfg, engine="scan")
        np.testing.assert_array_equal(_accs(scan), _accs(fused))
        assert scan.final_accuracy > 0.35


class TestActiveMaskSemantics:
    """aggregate_updates with padded inactive rows must equal the compacted
    computation — in particular the OPWA overlap counts must not see the
    all-True Top-K masks that zero rows produce."""

    def _case(self, strategy, c_act=3, c_pad=2, n=4096, seed=0):
        key = jax.random.PRNGKey(seed)
        u_act = jax.random.normal(key, (c_act, n))
        u = jnp.concatenate([u_act, jnp.zeros((c_pad, n))])
        w_act = jnp.asarray(np.full(c_act, 1.0 / c_act), jnp.float32)
        w = jnp.concatenate([w_act, jnp.zeros((c_pad,))])
        ks = jnp.full((c_act + c_pad,), 128, jnp.int32)
        active = jnp.asarray([True] * c_act + [False] * c_pad)
        spec = engine.ClientUpdateSpec(strategy=strategy, gamma=4.0)
        return spec, u, u_act, w, w_act, ks, active

    @pytest.mark.parametrize("strategy", ["fedavg", "topk", "bcrs_opwa"])
    def test_padded_equals_compacted(self, strategy):
        spec, u, u_act, w, w_act, ks, active = self._case(strategy)
        agg_pad, _ = engine.aggregate_updates(spec, u, w, ks, active=active)
        agg_cmp, _ = engine.aggregate_updates(spec, u_act, w_act, ks[:3])
        np.testing.assert_array_equal(np.asarray(agg_pad),
                                      np.asarray(agg_cmp))

    def test_eftopk_inactive_residuals_pass_through(self):
        spec, u, u_act, w, w_act, ks, active = self._case("eftopk")
        res = jax.random.normal(jax.random.PRNGKey(7), u.shape) * 0.1
        agg_pad, r_pad = engine.aggregate_updates(spec, u, w, ks,
                                                  residuals=res,
                                                  active=active)
        agg_cmp, r_cmp = engine.aggregate_updates(spec, u_act, w_act, ks[:3],
                                                  residuals=res[:3])
        np.testing.assert_array_equal(np.asarray(agg_pad),
                                      np.asarray(agg_cmp))
        np.testing.assert_array_equal(np.asarray(r_pad[:3]),
                                      np.asarray(r_cmp))
        np.testing.assert_array_equal(np.asarray(r_pad[3:]),
                                      np.asarray(res[3:]))


class TestTracedSampling:
    """run_fl_traced: cohort/survival/arrival draws fully inside the jit."""

    def test_learns_and_compiles_once(self):
        before = sum(engine.TRACE_COUNTS.values())
        res = run_fl_traced(FLSimConfig(**FAST),
                            AggregationConfig(strategy="bcrs_opwa", cr=0.05))
        assert sum(engine.TRACE_COUNTS.values()) - before == 1
        assert res.final_accuracy > 0.4
        assert len(res.executed_rounds) == FAST["rounds"]

    def test_survives_failures_and_stragglers(self):
        res = run_fl_traced(
            FLSimConfig(**FAST),
            AggregationConfig(strategy="eftopk", cr=0.05),
            p_fail=0.3, straggler=StragglerPolicy(over_selection=0.5))
        assert res.final_accuracy > 0.3
        assert res.final_residuals is not None

    def test_survivors_traced_guarantee(self):
        key = jax.random.PRNGKey(0)
        all_alive = survivors_traced(key, 16, 0.0)
        assert bool(all_alive.all())
        # p_fail=1 would kill everyone; exactly one client is revived
        one = survivors_traced(key, 16, 1.0)
        assert int(jnp.sum(one)) == 1

    def test_arrival_mask_traced_picks_fastest(self):
        t = jnp.asarray([3.0, 1.0, jnp.inf, 2.0, 5.0])
        mask = np.asarray(arrival_mask_traced(t, 3))
        np.testing.assert_array_equal(mask, [True, True, False, True, False])

    def test_renormalize_traced_matches_host(self):
        from repro.ft import renormalize_coefficients
        coeffs = np.array([0.4, 0.1, 0.3, 0.2])
        arrived = np.array([True, False, True, False])
        host = renormalize_coefficients(coeffs, arrived)
        traced = np.asarray(renormalize_coefficients_traced(
            jnp.asarray(coeffs, jnp.float32), jnp.asarray(arrived)))
        np.testing.assert_allclose(traced, host, rtol=1e-6)
