"""Aggregate the dry-run JSONs into the §Roofline table (markdown + CSV)."""
from __future__ import annotations

import glob
import json
import os

COLS = ("arch", "shape", "step", "compute_s", "memory_s", "collective_s",
        "dominant", "compute_fraction", "model_flops_ratio",
        "per_device_gib", "fits_16gib")


def load(mesh_tag: str = "pod1", base: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(base, mesh_tag, "*.json"))):
        r = json.load(open(path))
        if r.get("skipped"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "step": "SKIP", "reason": r["reason"]})
            continue
        if not r.get("ok", True):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "step": "FAIL", "reason": r.get("error", "")[:80]})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "step": r["step"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "compute_fraction": rf["compute_fraction"],
            "model_flops_ratio": rf["model_flops_ratio"],
            "per_device_gib": r["memory"]["per_device_gib"],
            "fits_16gib": r["memory"]["fits_16gib"],
        })
    return rows


def markdown(rows) -> str:
    out = ["| arch | shape | step | compute_s | memory_s | coll_s | dominant "
           "| frac | 6ND/HLO | GiB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["step"] in ("SKIP", "FAIL"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['step']} | "
                       f"{r.get('reason', '')} |" + " |" * 7)
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | {r['dominant']} "
            f"| {r['compute_fraction']:.3f} | {r['model_flops_ratio']:.2f} "
            f"| {r['per_device_gib']} | {'Y' if r['fits_16gib'] else 'N'} |")
    return "\n".join(out)


def main():
    for tag in ("pod1", "pod2"):
        rows = load(tag)
        if not rows:
            continue
        print(f"\n===== roofline table ({tag}) =====")
        print(markdown(rows))
    rows = load("pod1")
    print("\nname,us_per_call,derived")
    for r in rows:
        if r["step"] in ("SKIP", "FAIL"):
            continue
        print(f"roofline/{r['arch']}/{r['shape']},"
              f"{r['compute_s'] * 1e6:.0f},"
              f"dom={r['dominant']};frac={r['compute_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    main()
