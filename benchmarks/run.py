"""Benchmark harness: one module per paper table/figure. Prints a combined
``name,us_per_call,derived`` CSV at the end.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only table2,fig4]
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer FL rounds (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma list: table2,table3,table4,fig4,fig6,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    rounds = 16 if args.fast else 40

    from benchmarks import (fig4_overlap, fig6_breakdown, roofline_table,
                            table2_accuracy, table3_comm_time, table4_gamma)

    csv = ["name,us_per_call,derived"]

    def want(name):
        return only is None or name in only

    if want("table2"):
        print("== Table 2: accuracy grid ==")
        for r in table2_accuracy.run(rounds=rounds):
            csv.append(f"table2/{r['strategy']}/b{r['beta']}/cr{r['cr']},"
                       f"{r['wall_s'] * 1e6:.0f},acc={r['final_acc']:.4f}")
    if want("table3"):
        print("== Table 3: time-to-accuracy ==")
        for r in table3_comm_time.run(rounds=rounds):
            t = r["time_to_target"]
            csv.append(f"table3/{r['name']},{(t or 0) * 1e6:.0f},"
                       f"acc={r['final_acc']:.4f};actual={r['actual']:.1f}")
    if want("table4"):
        print("== Table 4: gamma sweep ==")
        for r in table4_gamma.run(rounds=rounds):
            csv.append(f"table4/gamma{r['gamma']},0,acc={r['final_acc']:.4f}")
    if want("fig4"):
        print("== Fig 4: overlap histogram ==")
        for r in fig4_overlap.run():
            csv.append(f"fig4/cr{r['cr']},0,"
                       f"frac_overlap1={r['frac_overlap1']:.4f}")
    if want("fig6"):
        print("== Fig 6: round breakdown ==")
        rows = fig6_breakdown.run()
        for k, v in rows.items():
            csv.append(f"fig6/{k},{v * 1e6:.1f},")
    if want("roofline"):
        print("== Roofline table (from dry-run artifacts) ==")
        for tag in ("pod1", "pod2"):
            rows = roofline_table.load(tag)
            if rows:
                print(f"\n--- {tag} ---")
                print(roofline_table.markdown(rows))
                for r in rows:
                    if r["step"] in ("SKIP", "FAIL"):
                        continue
                    csv.append(
                        f"roofline/{tag}/{r['arch']}/{r['shape']},"
                        f"{r['compute_s'] * 1e6:.0f},"
                        f"dom={r['dominant']};frac={r['compute_fraction']:.3f}")

    print()
    print("\n".join(csv))


if __name__ == "__main__":
    main()
