"""Paper Fig. 4: distribution of the degree of overlap of retained
parameters after compression, at CR=0.1 and CR=0.01.

Expected pattern: at CR=0.01 the majority of retained indices appear in only
ONE selected client's update; higher CR shifts mass to higher overlap.
"""
from __future__ import annotations

import numpy as np

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl


def run(verbose: bool = True):
    rows = []
    for cr in [0.1, 0.01]:
        sim = FLSimConfig(rounds=12, beta=0.1, seed=1, eval_every=100)
        acfg = AggregationConfig(strategy="topk", cr=cr)
        res = run_fl(sim, acfg, collect_overlap=True)
        hist = res.overlap_hist
        total = hist[1:].sum()
        fracs = hist[1:] / max(total, 1)
        rows.append({"cr": cr, "hist": hist.tolist(),
                     "frac_overlap1": float(fracs[0])})
        if verbose:
            print(f"fig4 cr={cr}: overlap histogram (1..K) = {hist[1:]} "
                  f"-> {np.round(fracs, 3)} (frac@1={fracs[0]:.3f})")
    return rows


def main():
    rows = run()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"fig4/cr{r['cr']},0,frac_overlap1={r['frac_overlap1']:.4f}")
    return rows


if __name__ == "__main__":
    main()
