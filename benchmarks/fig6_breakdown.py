"""Paper Fig. 6: per-round time breakdown — compress/decompress, training,
uncompressed communication vs BCRS communication — plus kernel-path timing
for the compression hot-spot (block_topk / overlap_combine wall time).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bcrs as bcrs_mod
from repro.core import compression as C
from repro.core import cost_model
from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, mlp_init, mlp_loss
from repro.fed.client import make_local_trainer
from repro.core.compression import flatten_tree


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / reps


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    params = mlp_init(jax.random.PRNGKey(0), 64, 10)
    flat, _ = flatten_tree(params)
    n = flat.shape[0]
    v_bytes = 4.0 * n
    links = cost_model.sample_links(5, rng)

    # training time (one client, E=1 epoch equivalent: 8 steps of bs=64)
    local = jax.jit(make_local_trainer(mlp_loss, 0.05))
    batches = {"x": jnp.asarray(rng.normal(0, 1, (8, 64, 64)), jnp.float32),
               "y": jnp.asarray(rng.integers(0, 10, (8, 64)), jnp.int32)}
    t_train = _time(lambda: local(params, batches))

    # compression time (jnp path vs Pallas interpret path)
    u = jnp.asarray(rng.normal(0, 1, (n,)), jnp.float32)
    t_topk = _time(lambda: C.topk_compress(u, 0.01).values)
    t_block = _time(lambda: C.block_topk_compress(u, 0.01, 4096).values)

    # communication: uncompressed vs uniform top-k vs BCRS
    t_dense = cost_model.uncompressed_round(links, v_bytes).actual
    t_topk_comm = cost_model.round_times(links, v_bytes, [0.01] * 5).actual
    crs = bcrs_mod.schedule_crs(links, v_bytes, 0.01)
    t_bcrs_comm = cost_model.round_times(links, v_bytes, crs).actual

    rows = {
        "train_s": t_train, "compress_topk_s": t_topk,
        "compress_block_s": t_block, "comm_dense_s": t_dense,
        "comm_topk_s": t_topk_comm, "comm_bcrs_s": t_bcrs_comm,
    }
    if verbose:
        print(f"fig6 train={t_train * 1e3:.1f}ms "
              f"compress(topk)={t_topk * 1e3:.1f}ms "
              f"compress(block)={t_block * 1e3:.1f}ms")
        print(f"fig6 comm: dense={t_dense:.2f}s topk={t_topk_comm:.3f}s "
              f"bcrs={t_bcrs_comm:.3f}s "
              f"(bcrs == topk benchmark time, by construction)")
    return rows


def main():
    rows = run()
    print("\nname,us_per_call,derived")
    for k, v in rows.items():
        print(f"fig6/{k},{v * 1e6:.1f},")
    return rows


if __name__ == "__main__":
    main()
