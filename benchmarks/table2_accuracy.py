"""Paper Table 2: final test accuracy under the CR × beta grid for
FedAvg / TopK / EFTopK / BCRS / BCRS+OPWA.

Offline stand-in for CIFAR/SVHN: synthetic Dirichlet-partitioned Gaussian
classification (docs/DESIGN.md §7). Validation targets the paper's RELATIVE
ordering: BCRS(+OPWA) >= TopK/EFTOPK at equal CR, with the gap widest at
CR=0.01 and severe heterogeneity.
"""
from __future__ import annotations

import time

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl

GRID_CRS = [0.1, 0.01]
GRID_BETAS = [0.1, 0.5]
STRATEGIES = ["fedavg", "topk", "eftopk", "bcrs", "bcrs_opwa"]


def run(rounds: int = 40, seed: int = 0, verbose: bool = True):
    rows = []
    for beta in GRID_BETAS:
        for cr in GRID_CRS:
            for strat in STRATEGIES:
                sim = FLSimConfig(rounds=rounds, beta=beta, seed=seed)
                acfg = AggregationConfig(strategy=strat, cr=cr, alpha=1.0,
                                         gamma=5.0)
                t0 = time.time()
                res = run_fl(sim, acfg)
                rows.append({
                    "beta": beta, "cr": cr, "strategy": strat,
                    "final_acc": res.final_accuracy,
                    "best_acc": max(a for _, a in res.accuracies),
                    "wall_s": round(time.time() - t0, 1),
                })
                if verbose:
                    r = rows[-1]
                    print(f"table2 beta={beta} cr={cr} {strat:10s} "
                          f"acc={r['final_acc']:.4f} best={r['best_acc']:.4f}"
                          f" ({r['wall_s']}s)")
    return rows


def main():
    rows = run()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"table2/{r['strategy']}/b{r['beta']}/cr{r['cr']},"
              f"{r['wall_s'] * 1e6:.0f},acc={r['final_acc']:.4f}")
    return rows


if __name__ == "__main__":
    main()
