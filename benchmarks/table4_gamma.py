"""Paper Table 4 + Fig. 11/12: OPWA enlarge-rate gamma sweep.

Expected: accuracy varies systematically with gamma; the optimal gamma
scales with the number of selected clients (paper Fig. 12).
"""
from __future__ import annotations

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl

GAMMAS = [1.0, 3.0, 5.0, 7.0, 10.0]


def run(cr: float = 0.01, rounds: int = 40, verbose: bool = True):
    rows = []
    for gamma in GAMMAS:
        sim = FLSimConfig(rounds=rounds, beta=0.1, seed=0)
        acfg = AggregationConfig(strategy="bcrs_opwa", cr=cr, gamma=gamma,
                                 alpha=1.0)
        res = run_fl(sim, acfg)
        rows.append({"gamma": gamma, "final_acc": res.final_accuracy})
        if verbose:
            print(f"table4 gamma={gamma:5.1f} acc={res.final_accuracy:.4f}")
    return rows


def main():
    rows = run()
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"table4/gamma{r['gamma']},0,acc={r['final_acc']:.4f}")
    return rows


if __name__ == "__main__":
    main()
