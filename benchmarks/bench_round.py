"""Round-engine benchmark: legacy per-client loop vs the fused jitted round.

    PYTHONPATH=src python -m benchmarks.bench_round [--fast] [--out PATH]

For each (strategy, cohort size K) cell it runs the same seeded simulation
through both engines, times steady-state rounds (first round excluded as
warmup/compile), counts XLA backend compilations via jax.monitoring, and
writes ``BENCH_round.json``:

    {"schema": "bench_round/v1",
     "env":    {"platform", "jax", "cpu_count"},
     "config": {"rounds", "warmup", "cr", "fast"},
     "results": [{"strategy", "clients",
                  "legacy": {"s_per_round", "s_per_round_min", "total_s",
                             "compiles"},
                  "fused":  {"s_per_round", "s_per_round_min", "total_s",
                             "compiles", "round_step_traces"},
                  "speedup", "accuracy_max_abs_diff"}, ...]}

``s_per_round`` is the median post-warmup wall time of one full round
(batch staging + local training + compression + aggregation + server
update; evaluation excluded); ``s_per_round_min`` the fastest such round.
``speedup`` = legacy min / fused min (scheduler noise only adds time, so
per-engine minima give the stable ratio on shared CI hardware).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

import numpy as np

import jax

from repro.core.aggregation import AggregationConfig
from repro.fed import round_step
from repro.fed.simulation import FLSimConfig, run_fl

STRATEGIES = ("fedavg", "eftopk", "bcrs_opwa")


class CompileCounter:
    """Counts XLA backend compilations via jax.monitoring duration events."""

    def __init__(self):
        self.n = 0
        self._active = False

    def _cb(self, name, duration, **kwargs):
        if self._active and "backend_compile" in name:
            self.n += 1

    def __enter__(self):
        jax.monitoring.register_event_duration_secs_listener(self._cb)
        self._active = True
        return self

    def __exit__(self, *exc):
        # the gate above makes a leaked listener inert; the unregister hook
        # is private jax API, so treat it as best-effort
        self._active = False
        try:
            from jax._src import monitoring
            monitoring._unregister_event_duration_listener_by_callback(
                self._cb)
        except (ImportError, AttributeError):
            pass
        return False


BENCH_BETA = 20.0


def _sim_config(clients: int, rounds: int) -> FLSimConfig:
    # Full participation (cohort size == n_clients == K), ~96 samples and
    # one local batch per client per round: the paper's communication-bound
    # regime (large model, few local steps), where the round engine — not
    # local SGD — is the cost. beta=20 keeps Dirichlet label skew but
    # balanced enough that min_size=batch_size partitions sample quickly and
    # per-client step counts are comparable (extreme skew inflates the
    # fused path's padded-step waste; tracked as a ROADMAP open item).
    return FLSimConfig(n_clients=clients, participation=1.0, rounds=rounds,
                       n_train=96 * clients, n_test=600,
                       eval_every=10_000, seed=7, beta=BENCH_BETA)


def bench_cell(strategy: str, clients: int, rounds: int, warmup: int,
               cr: float) -> dict:
    acfg = AggregationConfig(strategy=strategy, cr=cr)
    sim = _sim_config(clients, rounds)
    out = {"strategy": strategy, "clients": clients}
    accs = {}
    for mode, fused in (("legacy", False), ("fused", True)):
        traces0 = sum(round_step.TRACE_COUNTS.values())
        with CompileCounter() as cc:
            t0 = time.perf_counter()
            res = run_fl(sim, acfg, fused=fused)
            total = time.perf_counter() - t0
        steady = res.wall_per_round[warmup:]
        out[mode] = {
            "s_per_round": statistics.median(steady),
            "s_per_round_min": min(steady),
            "total_s": total,
            "compiles": cc.n,
        }
        if fused:
            out[mode]["round_step_traces"] = (
                sum(round_step.TRACE_COUNTS.values()) - traces0)
        accs[mode] = np.array([a for _, a in res.accuracies])
    # ratio of fastest observed steady-state rounds (timeit-style: scheduler
    # noise only ever adds time, so min is the robust per-engine estimate)
    out["speedup"] = (out["legacy"]["s_per_round_min"]
                      / out["fused"]["s_per_round_min"])
    out["accuracy_max_abs_diff"] = float(
        np.abs(accs["legacy"] - accs["fused"]).max())
    return out


def run(fast: bool = False, rounds: int = 0, out_path: str = "BENCH_round.json"
        ) -> dict:
    ks = (8, 16) if fast else (8, 16, 32)
    rounds = rounds or (8 if fast else 12)
    warmup, cr = 2, 0.1
    if rounds <= warmup:
        raise SystemExit(f"--rounds must exceed the {warmup} warmup rounds")
    results = []
    for clients in ks:
        for strategy in STRATEGIES:
            cell = bench_cell(strategy, clients, rounds, warmup, cr)
            results.append(cell)
            print(f"{strategy:>10} K={clients:<3} "
                  f"legacy {cell['legacy']['s_per_round_min'] * 1e3:8.1f} "
                  f"ms/round ({cell['legacy']['compiles']:3d} compiles)  "
                  f"fused {cell['fused']['s_per_round_min'] * 1e3:8.1f} "
                  f"ms/round ({cell['fused']['compiles']:3d} compiles)  "
                  f"speedup {cell['speedup']:.2f}x  "
                  f"|dacc| {cell['accuracy_max_abs_diff']:.1e}")
    doc = {
        "schema": "bench_round/v1",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"rounds": rounds, "warmup": warmup, "cr": cr,
                   "beta": BENCH_BETA, "participation": 1.0,
                   "n_train_per_client": 96, "fast": fast},
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="K in {8,16}, fewer rounds (CI-speed)")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--out", default="BENCH_round.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless fused beats legacy >=3x at "
                         "K=16 bcrs_opwa")
    args = ap.parse_args()
    doc = run(fast=args.fast, rounds=args.rounds, out_path=args.out)
    if args.check:
        cell = next(r for r in doc["results"]
                    if r["strategy"] == "bcrs_opwa" and r["clients"] == 16)
        if cell["speedup"] < 3.0:
            print(f"FAIL: bcrs_opwa K=16 speedup {cell['speedup']:.2f}x < 3x")
            return 1
        print(f"OK: bcrs_opwa K=16 speedup {cell['speedup']:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
