"""Round-engine benchmark: legacy per-client loop vs the fused jitted round,
plus the multi-round dimension (fused per-round dispatch vs the ONE-compile
``lax.scan`` simulation engine).

    PYTHONPATH=src python -m benchmarks.bench_round [--fast] [--out PATH]
    PYTHONPATH=src python -m benchmarks.bench_round --sim-scan [--fast]
    PYTHONPATH=src python -m benchmarks.bench_round --kernels [--fast]
    PYTHONPATH=src python -m benchmarks.bench_round --mesh-scan [--fast]
    PYTHONPATH=src python -m benchmarks.bench_round --async [--fast]

For each (strategy, cohort size K) cell it runs the same seeded simulation
through both engines, times steady-state rounds (first round excluded as
warmup/compile), counts XLA backend compilations via jax.monitoring, and
writes ``BENCH_round.json``:

    {"schema": "bench_round/v1",
     "env":    {"platform", "jax", "cpu_count"},
     "config": {"rounds", "warmup", "cr", "fast"},
     "results": [{"strategy", "clients",
                  "legacy": {"s_per_round", "s_per_round_min", "total_s",
                             "compiles"},
                  "fused":  {"s_per_round", "s_per_round_min", "total_s",
                             "compiles", "round_step_traces"},
                  "speedup", "accuracy_max_abs_diff"}, ...]}

``s_per_round`` is the median post-warmup wall time of one full round
(batch staging + local training + compression + aggregation + server
update; evaluation excluded); ``s_per_round_min`` the fastest such round.
``speedup`` = legacy min / fused min (scheduler noise only adds time, so
per-engine minima give the stable ratio on shared CI hardware).

``--sim-scan`` runs the multi-round benchmark instead and writes
``BENCH_sim_scan.json``: for each (strategy, rounds) cell it times the fused
per-round engine's steady-state round (median post-warmup wall) against the
scan engine's per-round execution cost — the scan path AOT-compiles the
whole trajectory, so wall/rounds of the compiled program excludes the
one-off compile exactly like the fused numbers exclude warmup. The model is
kept small so per-round *overhead* (Python dispatch, host staging), not
local SGD, dominates — the regime the scan lowering targets. Compile counts
must stay O(1) for both engines (recorded in the JSON). A ``ragged``
section records the step-cap (``FLSimConfig.step_cap_quantile``) win under
extreme Dirichlet skew.

``--mesh-scan`` benchmarks the REAL-MODEL mesh driver
(``repro.launch.fl_train``) and writes ``BENCH_mesh_scan.json``: for each
strategy it runs the same seeded reduced-arch training through the legacy
one-jit-per-round dispatch loop (``--engine round``, steady-state median
after warmup) and through the scanned multi-round program
(``--engine scan``, AOT-compiled chunk — wall/rounds of the executable, the
compile excluded exactly like the loop's warmup rounds), asserting the two
trajectories' losses agree bitwise and the scan traced exactly once.

``--kernels`` benchmarks the traced-k Pallas megakernel pipeline
(``threshold_find`` + ``fused_merge``) against the unfused jnp merge and
writes ``BENCH_kernels.json``: per (strategy, C, n) cell the roofline HBM
bytes of both lowerings (analytic kernel DMA model vs trip-count-aware HLO
accounting — repro.roofline.kernel_bytes), wall-clock (interpret mode off
TPU), a bit-exactness flag, and a trace-count assertion that the
kernel-routed scan simulation still compiles exactly once.
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import strategies as strat_mod
from repro.core.aggregation import AggregationConfig
from repro.fed import round_step
from repro.fed.simulation import FLSimConfig, run_fl

STRATEGIES = ("fedavg", "eftopk", "bcrs_opwa")


class CompileCounter:
    """Counts XLA backend compilations via jax.monitoring duration events."""

    def __init__(self):
        self.n = 0
        self._active = False

    def _cb(self, name, duration, **kwargs):
        if self._active and "backend_compile" in name:
            self.n += 1

    def __enter__(self):
        jax.monitoring.register_event_duration_secs_listener(self._cb)
        self._active = True
        return self

    def __exit__(self, *exc):
        # the gate above makes a leaked listener inert; the unregister hook
        # is private jax API, so treat it as best-effort
        self._active = False
        try:
            from jax._src import monitoring
            monitoring._unregister_event_duration_listener_by_callback(
                self._cb)
        except (ImportError, AttributeError):
            pass
        return False


BENCH_BETA = 20.0


def _sim_config(clients: int, rounds: int) -> FLSimConfig:
    # Full participation (cohort size == n_clients == K), ~96 samples and
    # one local batch per client per round: the paper's communication-bound
    # regime (large model, few local steps), where the round engine — not
    # local SGD — is the cost. beta=20 keeps Dirichlet label skew but
    # balanced enough that min_size=batch_size partitions sample quickly and
    # per-client step counts are comparable (extreme skew inflates the
    # fused path's padded-step waste; tracked as a ROADMAP open item).
    return FLSimConfig(n_clients=clients, participation=1.0, rounds=rounds,
                       n_train=96 * clients, n_test=600,
                       eval_every=10_000, seed=7, beta=BENCH_BETA)


def bench_cell(strategy: str, clients: int, rounds: int, warmup: int,
               cr: float) -> dict:
    acfg = AggregationConfig(strategy=strategy, cr=cr)
    sim = _sim_config(clients, rounds)
    out = {"strategy": strategy, "clients": clients}
    accs = {}
    for mode, fused in (("legacy", False), ("fused", True)):
        traces0 = sum(round_step.TRACE_COUNTS.values())
        with CompileCounter() as cc:
            t0 = time.perf_counter()
            res = run_fl(sim, acfg, fused=fused)
            total = time.perf_counter() - t0
        steady = res.wall_per_round[warmup:]
        out[mode] = {
            "s_per_round": statistics.median(steady),
            "s_per_round_min": min(steady),
            "total_s": total,
            "compiles": cc.n,
        }
        if fused:
            out[mode]["round_step_traces"] = (
                sum(round_step.TRACE_COUNTS.values()) - traces0)
        accs[mode] = np.array([a for _, a in res.accuracies])
    # ratio of fastest observed steady-state rounds (timeit-style: scheduler
    # noise only ever adds time, so min is the robust per-engine estimate)
    out["speedup"] = (out["legacy"]["s_per_round_min"]
                      / out["fused"]["s_per_round_min"])
    out["accuracy_max_abs_diff"] = float(
        np.abs(accs["legacy"] - accs["fused"]).max())
    return out


def run(fast: bool = False, rounds: int = 0, out_path: str = "BENCH_round.json"
        ) -> dict:
    ks = (8, 16) if fast else (8, 16, 32)
    rounds = rounds or (8 if fast else 12)
    warmup, cr = 2, 0.1
    if rounds <= warmup:
        raise SystemExit(f"--rounds must exceed the {warmup} warmup rounds")
    results = []
    for clients in ks:
        for strategy in STRATEGIES:
            cell = bench_cell(strategy, clients, rounds, warmup, cr)
            results.append(cell)
            print(f"{strategy:>10} K={clients:<3} "
                  f"legacy {cell['legacy']['s_per_round_min'] * 1e3:8.1f} "
                  f"ms/round ({cell['legacy']['compiles']:3d} compiles)  "
                  f"fused {cell['fused']['s_per_round_min'] * 1e3:8.1f} "
                  f"ms/round ({cell['fused']['compiles']:3d} compiles)  "
                  f"speedup {cell['speedup']:.2f}x  "
                  f"|dacc| {cell['accuracy_max_abs_diff']:.1e}")
    doc = {
        "schema": "bench_round/v1",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"rounds": rounds, "warmup": warmup, "cr": cr,
                   "beta": BENCH_BETA, "participation": 1.0,
                   "n_train_per_client": 96, "fast": fast},
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


# ------------------------------------------------------- multi-round (scan)
SCAN_STRATEGIES = ("bcrs_opwa", "eftopk")


def _scan_sim_config(clients: int, rounds: int, **kw) -> FLSimConfig:
    # dispatch-bound regime: tiny model + one local batch per client, so the
    # per-round engine overhead (Python loop, staging, dispatch) dominates
    # and the scan lowering's amortization is what gets measured
    base = dict(n_clients=clients, participation=1.0, rounds=rounds,
                dim=32, hidden=32, n_classes=10, batch_size=32,
                n_train=64 * clients, n_test=128, noise=3.0,
                eval_every=10_000, seed=7, beta=BENCH_BETA)
    base.update(kw)
    return FLSimConfig(**base)


def bench_scan_cell(strategy: str, clients: int, rounds: int,
                    warmup: int, cr: float) -> dict:
    """Fused steady-state ms/round vs the scan engine's per-round execution
    cost (``run_fl(engine="scan")`` AOT-compiles the trajectory and reports
    wall/rounds of the compiled program — the one-off compile is excluded
    exactly like the fused engine's discarded warmup rounds; the host plan
    build is reported separately as ``s_total``)."""
    from repro.fed import engine as engine_mod
    acfg = AggregationConfig(strategy=strategy, cr=cr)
    out = {"strategy": strategy, "clients": clients, "rounds": rounds}

    with CompileCounter() as cc:
        res_f = run_fl(_scan_sim_config(clients, rounds), acfg,
                       engine="fused")
    steady = res_f.wall_per_round[warmup:]
    out["fused"] = {"s_per_round": statistics.median(steady),
                    "s_per_round_min": min(steady),
                    "compiles": cc.n}

    traces0 = sum(engine_mod.TRACE_COUNTS.values())
    with CompileCounter() as cc:
        t0 = time.perf_counter()
        res_s = run_fl(_scan_sim_config(clients, rounds), acfg,
                       engine="scan")
        total = time.perf_counter() - t0
    out["scan"] = {"s_per_round": res_s.wall_per_round[0],
                   "s_total": total, "compiles": cc.n,
                   "sim_traces": (sum(engine_mod.TRACE_COUNTS.values())
                                  - traces0)}
    out["dispatch_overhead_ratio"] = (out["fused"]["s_per_round"]
                                      / out["scan"]["s_per_round"])
    out["accuracy_max_abs_diff"] = float(np.abs(
        np.array([a for _, a in res_f.accuracies])
        - np.array([a for _, a in res_s.accuracies])).max())
    return out


def bench_ragged(fast: bool, quantile: float = 0.5) -> dict:
    """Step-cap datapoint: beta=0.1 Dirichlet skew makes the fused/scan
    engines pad every client to the cohort-max local step count; capping at
    the ``quantile`` of the per-client step distribution trades a little
    tail-client local work for a much tighter static shape."""
    from repro.fed.simulation import planned_client_steps
    rounds = 6 if fast else 10
    kw = dict(n_clients=8, participation=1.0, rounds=rounds, batch_size=32,
              n_train=2400, n_test=128, dim=64, hidden=64, n_classes=10,
              eval_every=10_000, seed=7, beta=0.1)
    acfg = AggregationConfig(strategy="bcrs_opwa", cr=0.1)
    out = {"beta": 0.1, "quantile": quantile, "rounds": rounds}
    for label, q in (("uncapped", 1.0), ("capped", quantile)):
        sim = FLSimConfig(**kw, step_cap_quantile=q)
        steps = planned_client_steps(sim)
        res = run_fl(sim, acfg, engine="fused")
        steady = res.wall_per_round[2:]
        out[label] = {
            "s_per_round": statistics.median(steady),
            "s_per_round_min": min(steady),
            "s_max_steps": int(steps.max()),
            "padded_step_frac": float(1.0 - steps.mean() / steps.max()),
        }
    out["speedup"] = (out["uncapped"]["s_per_round_min"]
                      / out["capped"]["s_per_round_min"])
    return out


def run_sim_scan(fast: bool = False,
                 out_path: str = "BENCH_sim_scan.json") -> dict:
    clients = 8
    rounds = 60 if fast else 120
    warmup, cr = 2, 0.1
    results = []
    for strategy in SCAN_STRATEGIES:
        cell = bench_scan_cell(strategy, clients, rounds, warmup, cr)
        results.append(cell)
        print(f"{strategy:>10} R={rounds:<4} "
              f"fused {cell['fused']['s_per_round'] * 1e3:7.2f} ms/round "
              f"({cell['fused']['compiles']:3d} compiles)  "
              f"scan {cell['scan']['s_per_round'] * 1e3:7.2f} "
              f"ms/round ({cell['scan']['sim_traces']} traces)  "
              f"overhead ratio {cell['dispatch_overhead_ratio']:.2f}x  "
              f"|dacc| {cell['accuracy_max_abs_diff']:.1e}")
    ragged = bench_ragged(fast)
    print(f"    ragged beta=0.1 cap@q{ragged['quantile']}: "
          f"{ragged['uncapped']['s_per_round_min'] * 1e3:.1f} -> "
          f"{ragged['capped']['s_per_round_min'] * 1e3:.1f} ms/round "
          f"({ragged['speedup']:.2f}x; padded frac "
          f"{ragged['uncapped']['padded_step_frac']:.2f} -> "
          f"{ragged['capped']['padded_step_frac']:.2f})")
    doc = {
        "schema": "bench_sim_scan/v1",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"clients": clients, "rounds": rounds,
                   "warmup": warmup, "cr": cr, "fast": fast},
        "results": results,
        "ragged": ragged,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


# ------------------------------------------------- real-model mesh driver
MESH_STRATEGIES = ("bcrs_opwa", "eftopk")


def bench_mesh_cell(strategy: str, rounds: int, warmup: int) -> dict:
    """One strategy through both fl_train engines on the same seeded
    reduced arch: legacy per-round-jit dispatch loop vs the scanned
    multi-round program (single AOT-compiled chunk, so its wall_per_round
    excludes the compile like the loop numbers exclude warmup)."""
    from repro.fed import engine as engine_mod
    from repro.launch.fl_train import FLTrainConfig, run as run_fl_train

    base = dict(arch="stablelm-1.6b", reduced=True, rounds=rounds,
                clients=4, local_steps=1, batch=2, seq=32,
                strategy=strategy, cr=0.1, seed=7, verbose=False)
    out = {"strategy": strategy, "rounds": rounds}

    with CompileCounter() as cc:
        res_r = run_fl_train(FLTrainConfig(**base, engine="round"))
    steady = res_r["wall_per_round"][warmup:]
    out["round"] = {"s_per_round": statistics.median(steady),
                    "s_per_round_min": min(steady),
                    "compiles": cc.n}

    key = ("mesh_scan", strategy)
    traces0 = engine_mod.TRACE_COUNTS[key]
    with CompileCounter() as cc:
        t0 = time.perf_counter()
        res_s = run_fl_train(FLTrainConfig(**base, engine="scan"))
        total = time.perf_counter() - t0
    out["scan"] = {"s_per_round": res_s["wall_per_round"][0],
                   "s_total": total, "compiles": cc.n,
                   "mesh_scan_traces": engine_mod.TRACE_COUNTS[key] - traces0}
    out["dispatch_overhead_ratio"] = (out["round"]["s_per_round"]
                                      / out["scan"]["s_per_round"])
    out["loss_max_abs_diff"] = float(np.abs(
        np.array(res_r["losses"]) - np.array(res_s["losses"])).max())
    return out


def run_mesh_scan(fast: bool = False,
                  out_path: str = "BENCH_mesh_scan.json") -> dict:
    rounds = 8 if fast else 16
    warmup = 2
    results = []
    for strategy in MESH_STRATEGIES:
        cell = bench_mesh_cell(strategy, rounds, warmup)
        results.append(cell)
        print(f"{strategy:>10} R={rounds:<4} "
              f"round-loop {cell['round']['s_per_round'] * 1e3:7.1f} "
              f"ms/round ({cell['round']['compiles']:3d} compiles)  "
              f"scan {cell['scan']['s_per_round'] * 1e3:7.1f} ms/round "
              f"({cell['scan']['mesh_scan_traces']} traces)  "
              f"overhead ratio {cell['dispatch_overhead_ratio']:.2f}x  "
              f"|dloss| {cell['loss_max_abs_diff']:.1e}")
    doc = {
        "schema": "bench_mesh_scan/v1",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"rounds": rounds, "warmup": warmup,
                   "arch": "stablelm-1.6b-reduced", "clients": 4,
                   "fast": fast},
        "results": results,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


# ------------------------------------------------- megakernel pipeline
KERNEL_STRATEGIES = ("topk", "bcrs_opwa", "eftopk", "qtopk", "int4")
#: scanned-simulation 1-compile probes: the plain megakernel route and the
#: codec route
KERNEL_SCAN_STRATEGIES = ("bcrs_opwa", "qtopk")


def bench_kernels_cell(strategy: str, clients: int, n: int,
                       iters: int) -> dict:
    """One [C, n] merge through the unfused jnp ``aggregate_updates`` vs the
    traced-k Pallas megakernel pipeline: roofline HBM bytes (analytic DMA
    model vs trip-count-aware HLO accounting — see
    repro.roofline.kernel_bytes), wall-clock, and bit-exact parity.

    On non-TPU platforms the kernel route runs in Pallas INTERPRET mode, so
    its wall-clock is a correctness/overhead datapoint, not a hardware
    prediction — the roofline bytes are the portable win metric."""
    from repro.core.compression import k_for_ratio
    from repro.fed import engine as engine_mod
    from repro.roofline import merge_traffic_ratio, wire_stream_bytes

    rng = np.random.default_rng(clients * 7 + n % 1009)
    u = jnp.asarray(rng.normal(size=(clients, n)).astype(np.float32))
    e = jnp.asarray((rng.normal(size=(clients, n)) * 0.3).astype(np.float32))
    w = rng.random(clients).astype(np.float32) + 0.05
    w = jnp.asarray(w / w.sum())
    # BCRS-style spread of per-client retained counts
    crs = np.geomspace(0.01, 0.5, clients)
    ks = jnp.asarray([k_for_ratio(n, float(c)) for c in crs], jnp.int32)
    ef = strat_mod.get(strategy).needs_residuals

    platform = jax.devices()[0].platform
    out = {"strategy": strategy, "clients": clients, "n": n,
           # per-entry provenance: off-TPU the kernel route runs in Pallas
           # INTERPRET mode, so this cell's wall-clock must never be read
           # as a hardware comparison (--check warns on exactly this)
           "backend": platform, "interpret": platform != "tpu"}
    aggs = {}
    for label, use_kernel in (("unfused", False), ("kernel", True)):
        spec = engine_mod.ClientUpdateSpec(strategy=strategy, gamma=5.0,
                                           use_kernel=use_kernel)
        fn = jax.jit(lambda u, w, ks, e, spec=spec: engine_mod.
                     aggregate_updates(spec, u, w, ks,
                                       residuals=e if ef else None))
        agg, new_res = fn(u, w, ks, e)              # warmup/compile
        agg.block_until_ready()
        walls = []
        for _ in range(iters):
            t0 = time.perf_counter()
            a, r = fn(u, w, ks, e)
            a.block_until_ready()
            if r is not None:
                r.block_until_ready()
            walls.append(time.perf_counter() - t0)
        aggs[label] = (np.asarray(agg),
                       np.asarray(new_res) if ef else None)
        out[label] = {"s_per_merge": statistics.median(walls),
                      "s_per_merge_min": min(walls)}
    out["agg_max_abs_diff"] = float(
        np.abs(aggs["kernel"][0] - aggs["unfused"][0]).max())
    out["bit_exact"] = bool(
        (aggs["kernel"][0] == aggs["unfused"][0]).all()
        and (not ef or (aggs["kernel"][1] == aggs["unfused"][1]).all()))
    spec_ref = engine_mod.ClientUpdateSpec(strategy=strategy, gamma=5.0,
                                           use_kernel=False)
    out["roofline"] = merge_traffic_ratio(spec_ref, clients, n)
    # upload pricing of the cell's median per-client k under the strategy's
    # registered wire format (packed codecs beat the idx32+f32 reference
    # pair on the per-survivor stream: int8 5/8, int4 9/16)
    out["wire"] = wire_stream_bytes(strategy, n,
                                    int(np.median(np.asarray(ks))))
    return out


def run_kernels(fast: bool = False,
                out_path: str = "BENCH_kernels.json") -> dict:
    from repro.core.aggregation import AggregationConfig
    from repro.fed import engine as engine_mod
    from repro.fed.simulation import FLSimConfig, run_fl

    cells = ([(8, 1 << 13), (16, 1 << 14)] if fast
             else [(8, 1 << 14), (16, 1 << 16), (32, 1 << 16)])
    iters = 3 if fast else 5
    results = []
    for clients, n in cells:
        for strategy in KERNEL_STRATEGIES:
            cell = bench_kernels_cell(strategy, clients, n, iters)
            results.append(cell)
            r = cell["roofline"]
            print(f"{strategy:>10} C={clients:<3} n={n:<7} "
                  f"HBM {r['unfused']['passes']:6.1f} -> "
                  f"{r['kernel']['passes']:5.1f} passes "
                  f"({r['ratio']:.1f}x less traffic)  "
                  f"wall unfused {cell['unfused']['s_per_merge'] * 1e3:7.1f} "
                  f"ms  kernel {cell['kernel']['s_per_merge'] * 1e3:7.1f} ms"
                  f"  bit_exact={cell['bit_exact']}")

    # the kernel-routed scan simulation must still be ONE compile end to
    # end — for the plain megakernel route AND the codec route
    scan_traces = {}
    for scan_strat in KERNEL_SCAN_STRATEGIES:
        before = sum(engine_mod.TRACE_COUNTS.values())
        run_fl(FLSimConfig(rounds=4, n_clients=6, n_train=1200, n_test=300,
                           dim=32, hidden=32, n_classes=5, eval_every=2,
                           seed=2),
               AggregationConfig(strategy=scan_strat, cr=0.1,
                                 use_kernel=True),
               engine="scan")
        scan_traces[scan_strat] = (sum(engine_mod.TRACE_COUNTS.values())
                                   - before)
        print(f"kernel-routed scan simulation [{scan_strat}]: "
              f"{scan_traces[scan_strat]} trace(s)")

    doc = {
        "schema": "bench_kernels/v2",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count(),
                "pallas_interpret": jax.devices()[0].platform != "tpu"},
        "config": {"iters": iters, "fast": fast,
                   "note": ("roofline bytes: analytic kernel DMA model vs "
                            "trip-count-aware HLO accounting of the unfused "
                            "lowering; wall-clock on non-TPU runs the "
                            "kernels in interpret mode")},
        "results": results,
        "scan_traces_with_kernels": scan_traces,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


# ------------------------------------------------- population-scale sweep
POPULATIONS_FULL = (1_000, 10_000, 100_000, 1_000_000)
POPULATIONS_FAST = (1_000, 10_000)
POP_STRATEGY = "eftopk"


def run_population(fast: bool = False,
                   out_path: str = "BENCH_population.json",
                   strategy: str = POP_STRATEGY) -> dict:
    """Streaming-cohort flatness sweep: the SAME compiled round program
    (``round_step.make_population_round_step``, reused across every P —
    TRACE_COUNTS must grow by exactly 1 over the whole sweep) driven over
    populations P = 10^3 .. 10^6 at a fixed cohort. The claim under test is
    the tentpole's: per-round wall-clock and peak host state bytes are flat
    in P, because every per-round quantity — cohort draw, gather/scatter,
    schedule, batch synthesis — is O(C), and the out-of-core store's LRU
    window bounds residency no matter how many clients have touched state.
    ``--fast`` sweeps the 10^3/10^4 points (CI); the committed artifact
    carries the full sweep."""
    import shutil
    import tempfile

    from repro.fed import population as pop_mod
    from repro.fed import round_step as rs_mod

    pops = POPULATIONS_FAST if fast else POPULATIONS_FULL
    rounds = 6 if fast else 10
    warmup, cohort, cr = 2, 16, 0.1
    # per-client chunks + a bounded LRU window: every round moves exactly
    # O(C) rows through the store no matter how many clients have touched
    # state, so BOTH wall-clock and peak residency are P-independent (a
    # multi-client chunk amortizes I/O when cohorts cluster, but at P >> C
    # each sampled id lands in its own chunk and the extra rows are pure
    # write amplification — the flatness sweep uses the honest worst case)
    chunk_clients, max_resident = 1, 2 * cohort
    acfg = AggregationConfig(strategy=strategy, cr=cr)
    traces0 = rs_mod.TRACE_COUNTS[("population", strategy)]
    step = None
    results = []
    for p in pops:
        t0 = time.perf_counter()
        pop = pop_mod.make_population(p, seed=3)
        registry_s = time.perf_counter() - t0
        cfg = pop_mod.PopulationRunConfig(cohort=cohort, rounds=rounds,
                                          seed=3)
        spill = tempfile.mkdtemp(prefix=f"bench_pop_{p}_")
        try:
            res, step, store = pop_mod.run_population_rounds(
                pop, cfg, acfg=acfg, step=step,
                chunk_clients=chunk_clients,
                max_resident_chunks=max_resident, spill_dir=spill)
        finally:
            shutil.rmtree(spill, ignore_errors=True)
        steady = res.wall_per_round[warmup:]
        total = sum(res.wall_per_round)
        cell = {
            "population": p,
            "s_per_round": statistics.median(steady),
            "s_per_round_min": min(steady),
            "registry_build_s": registry_s,
            "peak_state_bytes": int(res.peak_state_bytes),
            "gather_s": res.gather_seconds,
            "scatter_s": res.scatter_seconds,
            "gather_scatter_share": ((res.gather_seconds
                                      + res.scatter_seconds) / total),
            "chunk_loads": store.chunk_loads if store else 0,
            "chunk_spills": store.chunk_spills if store else 0,
            "final_loss": res.losses[-1],
        }
        results.append(cell)
        print(f"P={p:<8} {cell['s_per_round'] * 1e3:7.2f} ms/round "
              f"(min {cell['s_per_round_min'] * 1e3:6.2f})  "
              f"peak state {cell['peak_state_bytes'] / 1e6:7.1f} MB  "
              f"gather+scatter {cell['gather_scatter_share'] * 100:5.1f}%  "
              f"spills {cell['chunk_spills']}")
    traces = rs_mod.TRACE_COUNTS[("population", strategy)] - traces0
    base = results[0]
    for cell in results:
        cell["wall_ratio_vs_smallest"] = (cell["s_per_round"]
                                          / base["s_per_round"])
        cell["peak_ratio_vs_smallest"] = (cell["peak_state_bytes"]
                                          / base["peak_state_bytes"])
    print(f"population round program: {traces} trace(s) across the sweep")
    doc = {
        "schema": "bench_population/v1",
        "env": {"platform": jax.devices()[0].platform,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"strategy": strategy, "cohort": cohort, "rounds": rounds,
                   "warmup": warmup, "cr": cr,
                   "chunk_clients": chunk_clients,
                   "max_resident_chunks": max_resident, "fast": fast},
        "results": results,
        "population_traces": traces,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


# ------------------------------------------------------------ async engine
#: (label, bandwidth sd in Mbps around the 1.0 mean) — the floor clip at
#: 0.05 Mbps turns the high-sd draw into a long-tailed straggler mix
ASYNC_MIXES = (("mild", 0.2), ("extreme", 0.8))
ASYNC_STRATEGY = "eftopk"
ASYNC_PFAIL = 0.1


def _time_to_target(res, target: float) -> float:
    """Virtual seconds of simulated communication until the accuracy
    trajectory first crosses ``target`` (inf if it never does)."""
    cum = np.cumsum([t.actual for t in res.times.per_round])
    by_round = {r: i for i, r in enumerate(res.executed_rounds)}
    for r, acc in res.accuracies:
        if acc >= target:
            return float(cum[by_round[r]])
    return float("inf")


#: batched-dispatch probe shape: M in-flight per K-slot buffer — the
#: headline "jit dispatches per upload" configuration
ASYNC_PROBE_M = 32
ASYNC_PROBE_K = 8
#: async population sweep (reuses the sync sweep's P grid)
ASYNC_POP_FAST = POPULATIONS_FAST
ASYNC_POP_FULL = POPULATIONS_FULL


def run_async_dispatch_probe(fast: bool = False,
                             strategy: str = ASYNC_STRATEGY) -> dict:
    """Batched waves vs per-upload dispatch at M=32 in flight: the SAME
    seeded experiment runs with ``async_batch_dispatch`` on and off; the
    trajectories must be bit-identical (params + accuracies), and the
    batched run must issue >=3x fewer jit dispatches of the train program,
    compiling once per wave shape bucket (a small bounded set)."""
    from repro.fed import async_engine

    rounds = 6 if fast else 10
    base = dict(rounds=rounds, n_clients=64, participation=0.125,
                batch_size=8, beta=5.0, n_train=2048, n_test=400,
                dim=32, hidden=32, eval_every=2, seed=3,
                async_buffer_k=ASYNC_PROBE_K,
                async_concurrency=ASYNC_PROBE_M,
                async_p_fail_upload=ASYNC_PFAIL,
                async_upload_timeout_s=600.0)
    acfg = AggregationConfig(strategy=strategy, cr=0.05)
    key = ("async_train", strategy)
    t0 = async_engine.TRACE_COUNTS[key]
    res_b = run_fl(FLSimConfig(**base), acfg, engine="async")
    traces_batched = async_engine.TRACE_COUNTS[key] - t0
    t0 = async_engine.TRACE_COUNTS[key]
    res_s = run_fl(FLSimConfig(**base, async_batch_dispatch=False), acfg,
                   engine="async")
    traces_seq = async_engine.TRACE_COUNTS[key] - t0
    lb, ls = res_b.async_loop, res_s.async_loop
    bit_exact = bool(
        res_b.accuracies == res_s.accuracies
        and np.array_equal(np.asarray(lb.flat), np.asarray(ls.flat))
        and (res_b.final_residuals is None
             or np.array_equal(res_b.final_residuals,
                               res_s.final_residuals)))
    cell = {
        "strategy": strategy, "clients": base["n_clients"],
        "buffer_k": ASYNC_PROBE_K, "concurrency": ASYNC_PROBE_M,
        "rounds": rounds,
        "batched": {"train_calls": lb.train_calls,
                    "train_rows": lb.train_rows,
                    "train_traces": traces_batched,
                    "wave_buckets": sorted(lb.wave_buckets_used),
                    "forced_retires": lb.forced_retires,
                    "aborted_untrained": lb.aborted_untrained},
        "sequential": {"train_calls": ls.train_calls,
                       "train_rows": ls.train_rows,
                       "train_traces": traces_seq},
        "dispatch_ratio": ls.train_calls / lb.train_calls,
        "bit_exact": bit_exact,
    }
    print(f"dispatch M={ASYNC_PROBE_M}/K={ASYNC_PROBE_K}: "
          f"batched {lb.train_calls} train calls "
          f"({traces_batched} compiles, buckets "
          f"{sorted(lb.wave_buckets_used)}) vs sequential "
          f"{ls.train_calls} — {cell['dispatch_ratio']:.1f}x fewer, "
          f"bit_exact={bit_exact}")
    return cell


def run_async_population(fast: bool = False,
                         strategy: str = ASYNC_STRATEGY) -> list:
    """Async flatness sweep: the SAME compiled wave-train + merge programs
    driven by ``BufferedAsyncLoop`` over populations P = 10^3 .. 10^6 at a
    fixed buffer/concurrency. Per-flush wall-clock and peak host round
    state must be flat in P: O(1) rejection-sampled selection, O(K) sparse
    residual gather/scatter through a bounded-LRU ``ClientStateStore``, and
    the version ring replacing any P-sized parameter table."""
    import shutil
    import tempfile

    from repro.core import cost_model
    from repro.core.compression import flatten_tree, k_for_ratio
    from repro.fed import async_engine as ae
    from repro.fed import population as pop_mod
    from repro.fed import simulation as sim_mod

    pops = ASYNC_POP_FAST if fast else ASYNC_POP_FULL
    rounds = 12 if fast else 20
    warmup, k_buf, m_conc, cr = 2, 16, 32, 0.1
    acfg = AggregationConfig(strategy=strategy, cr=cr)
    dim, hidden, n_classes, bs, s_max, n_train = 16, 16, 5, 4, 2, 512
    params = sim_mod.mlp_init(jax.random.PRNGKey(3), dim, n_classes,
                              hidden=hidden)
    flat0, _ = flatten_tree(params)
    n_flat = int(flat0.shape[0])
    rngd = np.random.default_rng(7)
    x_all = jnp.asarray(rngd.normal(size=(n_train, dim)).astype(np.float32))
    y_all = jnp.asarray(rngd.integers(0, n_classes, n_train)
                        .astype(np.int32))
    k_ret = k_for_ratio(n_flat, cr)
    width = pop_mod.residual_width(n_flat, k_ret)
    # ONE pair of compiled programs reused across every P (their avals are
    # P-independent by construction — the jaxpr gate in tests asserts it)
    merge = ae.make_async_merge_step(acfg, residual_layout="topk_complement",
                                     width=width)
    wave_train = ae.make_wave_train_step(
        sim_mod.mlp_loss, params, lr=0.05,
        make_batches=lambda x: {"x": x_all[x["sample_idx"]],
                                "y": y_all[x["sample_idx"]]},
        strategy=strategy)

    def batch_plan(client: int, uid: int):
        r = np.random.default_rng((3, ae.BATCH_TAG, uid))
        return {"sample_idx": r.integers(n_train, size=(s_max, bs))
                .astype(np.int32),
                "step_mask": np.ones((s_max,), bool)}

    traces0 = ae.TRACE_COUNTS[("async_train", strategy)]
    cells = []
    for p in pops:
        t0 = time.perf_counter()
        pop = pop_mod.make_population(p, seed=3)
        registry_s = time.perf_counter() - t0
        spill = tempfile.mkdtemp(prefix=f"bench_async_pop_{p}_")
        marks = [time.perf_counter()]
        try:
            store = pop_mod.ClientStateStore(
                p, n_flat, layout="topk_complement", width=width,
                chunk_clients=1, max_resident_chunks=2 * k_buf,
                spill_dir=spill)
            loop = ae.BufferedAsyncLoop(
                n_clients=p, n_params=n_flat, buffer_k=k_buf,
                concurrency=m_conc, target_flushes=rounds, seed=3,
                alpha=0.5, stall_s=float("inf"), p_fail=ASYNC_PFAIL,
                retry=cost_model.RetryPolicy(timeout_s=600.0),
                links=pop.links, v_bytes=4.0 * n_flat,
                cr_eff_all=np.full(p, cr), ks_all=np.full(p, k_ret,
                                                          np.int32),
                coeff_table=None, fracs_all=pop.weights, merge=merge,
                wave_train=wave_train, batch_plan=batch_plan,
                residual_store=store,
                on_flush=lambda i, f, rt: marks.append(
                    time.perf_counter()))
            # fresh device copy per cell: the merge program donates its
            # params argument, so a shared flat0 would be consumed by the
            # first sweep point
            loop.run(jnp.array(flat0))
        finally:
            shutil.rmtree(spill, ignore_errors=True)
        per_flush = np.diff(marks)[warmup:]
        cell = {
            "population": p,
            "s_per_flush": float(statistics.median(per_flush)),
            "s_per_flush_min": float(per_flush.min()),
            "registry_build_s": registry_s,
            "peak_state_bytes": int(loop.peak_round_state_bytes),
            "train_calls": loop.train_calls,
            "wave_buckets": sorted(loop.wave_buckets_used),
            "chunk_loads": store.chunk_loads,
            "chunk_spills": store.chunk_spills,
        }
        cells.append(cell)
        print(f"P={p:<8} {cell['s_per_flush'] * 1e3:7.2f} ms/flush "
              f"(min {cell['s_per_flush_min'] * 1e3:6.2f})  "
              f"peak state {cell['peak_state_bytes'] / 1e6:7.2f} MB  "
              f"waves {loop.train_calls}  spills {store.chunk_spills}")
    base = cells[0]
    for cell in cells:
        # minima, not medians: at O(ms) flushes scheduler noise dominates
        # the median and only ever ADDS time (same convention as the
        # round-engine speedup at the top of this file)
        cell["wall_ratio_vs_smallest"] = (cell["s_per_flush_min"]
                                          / base["s_per_flush_min"])
        cell["peak_ratio_vs_smallest"] = (cell["peak_state_bytes"]
                                          / base["peak_state_bytes"])
    traces = ae.TRACE_COUNTS[("async_train", strategy)] - traces0
    print(f"async wave-train program: {traces} trace(s) across the sweep")
    return cells


def run_async_bench(fast: bool = False, out_path: str = "BENCH_async.json",
                    strategy: str = ASYNC_STRATEGY) -> dict:
    """Time-to-target-accuracy: synchronous deadline-drop vs async FedBuff.

    Per bandwidth mix, the same seeded experiment (dataset, partition,
    links, model init) runs through (a) the scan engine with the standard
    straggler mitigation — over-select, aggregate the first C·N arrivals,
    drop the rest at the deadline — plus round-level client failures, and
    (b) the async buffered engine with per-upload mid-transfer failures at
    the same rate. The metric is virtual communication time to reach 90%
    of the weaker run's best accuracy: the sync round is priced at the
    equalized-arrival duration of the aggregated set, the async flush at
    the event-loop time between flushes. The claim under test (the check
    gate): with a long-tailed bandwidth mix and failures, buffering K fast
    arrivals beats waiting on the deadline in >=1 mix.

    A ``chaos`` section smoke-tests the fault path at p_fail=0.6 with a
    tight per-upload timeout and a stall deadline (forced partial flushes):
    the run must complete every flush with ONE merge compile."""
    from repro.fed import async_engine
    from repro.ft.failures import FailureInjector
    from repro.ft.straggler import StragglerPolicy

    platform = jax.devices()[0].platform
    interpret = platform != "tpu"
    rounds = 12 if fast else 24
    # P=20 at 25% participation: the sync cohort is 5, and the async loop
    # over-provisions to M = min(2K, P - K) = 10 in flight per K=5-slot
    # buffer — the FedBuff regime (first K of M arrivals flush; a cohort-
    # sized population would pin M = K and the buffer would wait on its
    # slowest dispatch exactly like a sync round)
    # beta=5 keeps the Dirichlet partition mild: the heterogeneity under
    # test is the LINK mix, and min_size=batch must stay satisfiable for
    # 20 clients (beta=0.1 would resample forever at this n_train)
    # dataset size fixed across --fast: more data per client makes the MLP
    # converge inside async's pipeline-fill phase and the metric stops
    # resolving the steady state; the full mode only extends the horizon
    base = dict(rounds=rounds, n_clients=20, participation=0.25,
                batch_size=16, beta=5.0, n_train=2000, n_test=500,
                eval_every=1, seed=3)
    acfg = AggregationConfig(strategy=strategy, cr=0.05)
    results = []
    for label, bw_sd in ASYNC_MIXES:
        sim_sync = FLSimConfig(**base, link_bw_sd_mbps=bw_sd)
        res_sync = run_fl(sim_sync, acfg, engine="scan",
                          failure=FailureInjector(p_fail=ASYNC_PFAIL,
                                                  seed=base["seed"]),
                          straggler=StragglerPolicy())
        sim_async = FLSimConfig(**base, link_bw_sd_mbps=bw_sd,
                                async_p_fail_upload=ASYNC_PFAIL,
                                async_upload_timeout_s=600.0)
        res_async = run_fl(sim_async, acfg, engine="async")
        best_sync = max(a for _, a in res_sync.accuracies)
        best_async = max(a for _, a in res_async.accuracies)
        target = 0.9 * min(best_sync, best_async)
        t_sync = _time_to_target(res_sync, target)
        t_async = _time_to_target(res_async, target)
        cell = {
            "mix": label, "bw_sd_mbps": bw_sd, "p_fail": ASYNC_PFAIL,
            "backend": platform, "interpret": interpret,
            "target_accuracy": target,
            "sync": {"time_to_target_s": t_sync,
                     "total_comm_s": float(res_sync.times.actual),
                     "best_accuracy": best_sync},
            "async": {"time_to_target_s": t_async,
                      "total_comm_s": float(res_async.times.actual),
                      "best_accuracy": best_async},
            "speedup_time_to_target": t_sync / t_async,
        }
        results.append(cell)
        print(f"{label:<8} sd={bw_sd:.1f}  target {target:.3f}  "
              f"sync {t_sync:8.1f}s  async {t_async:8.1f}s  "
              f"speedup {cell['speedup_time_to_target']:.2f}x")

    # chaos smoke: heavy failures + tight timeout + stall deadline
    before = async_engine.TRACE_COUNTS[("async_merge", strategy)]
    sim_chaos = FLSimConfig(**base, link_bw_sd_mbps=0.8,
                            async_p_fail_upload=0.6, async_max_attempts=2,
                            async_upload_timeout_s=120.0,
                            async_stall_s=20.0)
    res_chaos = run_fl(sim_chaos, acfg, engine="async")
    durs = [t.actual for t in res_chaos.times.per_round]
    chaos = {
        "p_fail": 0.6, "max_attempts": 2, "timeout_s": 120.0,
        "stall_s": 20.0, "backend": platform, "interpret": interpret,
        "completed": len(res_chaos.executed_rounds) == rounds,
        "merge_traces": async_engine.TRACE_COUNTS[("async_merge", strategy)]
        - before,
        "flush_durations_nonnegative": bool(all(d >= 0 for d in durs)),
        "final_accuracy": res_chaos.final_accuracy,
    }
    print(f"chaos    p_fail=0.6 timeout=120s stall=20s: "
          f"{len(res_chaos.executed_rounds)}/{rounds} flushes, "
          f"{chaos['merge_traces']} merge trace(s), "
          f"acc {chaos['final_accuracy']:.3f}")

    print("-- batched dispatch probe --")
    dispatch = run_async_dispatch_probe(fast=fast, strategy=strategy)
    dispatch["backend"], dispatch["interpret"] = platform, interpret
    print("-- async population scaling --")
    population = run_async_population(fast=fast, strategy=strategy)
    for cell in population:
        cell["backend"], cell["interpret"] = platform, interpret

    doc = {
        "schema": "bench_async/v2",
        "env": {"platform": platform, "backend": platform,
                "interpret": interpret,
                "jax": jax.__version__,
                "cpu_count": os.cpu_count()},
        "config": {"strategy": strategy, "rounds": rounds, "cr": 0.05,
                   "p_fail": ASYNC_PFAIL, "fast": fast},
        "results": results,
        "chaos": chaos,
        "dispatch": dispatch,
        "population": population,
    }
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {out_path}")
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="K in {8,16}, fewer rounds (CI-speed)")
    ap.add_argument("--rounds", type=int, default=0)
    ap.add_argument("--out", default="BENCH_round.json")
    ap.add_argument("--strategy", default=None,
                    help="bench a single registered strategy instead of the "
                         "mode's default list (unknown names error, listing "
                         "what is registered)")
    ap.add_argument("--sim-scan", action="store_true",
                    help="run the multi-round benchmark (fused per-round "
                         "dispatch vs the one-compile scan engine) and "
                         "write BENCH_sim_scan.json")
    ap.add_argument("--mesh-scan", action="store_true",
                    help="benchmark the real-model mesh driver (scanned "
                         "multi-round program vs the legacy per-round-jit "
                         "loop) and write BENCH_mesh_scan.json")
    ap.add_argument("--kernels", action="store_true",
                    help="benchmark the traced-k Pallas megakernel pipeline "
                         "vs the unfused merge (roofline HBM bytes + "
                         "wall-clock + parity) and write BENCH_kernels.json")
    ap.add_argument("--async", dest="async_bench", action="store_true",
                    help="sync deadline-drop vs the async buffered engine "
                         "on time-to-target-accuracy over heterogeneous-"
                         "bandwidth mixes with upload failures, plus a "
                         "chaos smoke; writes BENCH_async.json")
    ap.add_argument("--population", action="store_true",
                    help="sweep the streaming-cohort engine over P = "
                         "10^3..10^6 registered clients (--fast: 10^3/10^4) "
                         "at a fixed cohort and write BENCH_population.json")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless fused beats legacy >=3x at "
                         "K=16 bcrs_opwa (with --sim-scan: scan dispatch "
                         "overhead >=2x lower than fused; with --kernels: "
                         "bit-exact, >=3x HBM traffic reduction, and a "
                         "1-compile kernel-routed scan; with --population: "
                         "wall-clock and peak state bytes <=1.25x the "
                         "smallest P, one compile across the sweep; with "
                         "--async: async wins time-to-target in >=1 mix "
                         "and the chaos run completes with 1 merge "
                         "compile)")
    args = ap.parse_args()
    if args.strategy is not None:
        global STRATEGIES, SCAN_STRATEGIES, MESH_STRATEGIES, KERNEL_STRATEGIES
        try:
            strat_mod.get(args.strategy)
        except ValueError as e:
            ap.error(str(e))
        only = (args.strategy,)
        STRATEGIES = SCAN_STRATEGIES = MESH_STRATEGIES = KERNEL_STRATEGIES = \
            only
    if args.async_bench:
        out = ("BENCH_async.json" if args.out == "BENCH_round.json"
               else args.out)
        strategy = args.strategy or ASYNC_STRATEGY
        doc = run_async_bench(fast=args.fast, out_path=out,
                              strategy=strategy)
        if args.check:
            if doc["env"]["interpret"]:
                print(f"WARNING: async cells ran on backend "
                      f"{doc['env']['backend']} (interpret-mode kernels) — "
                      "wall-clock columns are virtual-time/overhead "
                      "datapoints, not a hardware comparison; the check "
                      "gates only on event-stream invariants, dispatch "
                      "counts, and scaling ratios")
            wins = [c["mix"] for c in doc["results"]
                    if c["speedup_time_to_target"] > 1.0]
            ch = doc["chaos"]
            if (not wins or not ch["completed"] or ch["merge_traces"] != 1
                    or not ch["flush_durations_nonnegative"]):
                print(f"FAIL: async check (wins {wins}, chaos "
                      f"completed={ch['completed']} "
                      f"traces={ch['merge_traces']})")
                return 1
            dp = doc["dispatch"]
            if (dp["dispatch_ratio"] < 3.0 or not dp["bit_exact"]
                    or dp["batched"]["train_traces"]
                    != len(dp["batched"]["wave_buckets"])):
                print(f"FAIL: dispatch probe (ratio "
                      f"{dp['dispatch_ratio']:.2f}x, "
                      f"bit_exact={dp['bit_exact']}, "
                      f"traces={dp['batched']['train_traces']} vs buckets "
                      f"{dp['batched']['wave_buckets']})")
                return 1
            bad = [c for c in doc["population"]
                   if c["wall_ratio_vs_smallest"] > 1.25
                   or c["peak_ratio_vs_smallest"] > 1.25]
            if bad:
                print(f"FAIL: async population flatness "
                      f"(bad P {[c['population'] for c in bad]})")
                return 1
            pmax = doc["population"][-1]
            print(f"OK: async beats sync deadline-drop on time-to-target "
                  f"in {wins}; chaos run completed with 1 merge compile; "
                  f"batched dispatch {dp['dispatch_ratio']:.1f}x fewer "
                  f"train calls at M={ASYNC_PROBE_M} (bit-exact, "
                  f"{dp['batched']['train_traces']} compile(s)); async "
                  f"flat to P={pmax['population']} "
                  f"(wall {pmax['wall_ratio_vs_smallest']:.2f}x, peak "
                  f"state {pmax['peak_ratio_vs_smallest']:.2f}x)")
        return 0
    if args.population:
        out = ("BENCH_population.json" if args.out == "BENCH_round.json"
               else args.out)
        strategy = args.strategy or POP_STRATEGY
        doc = run_population(fast=args.fast, out_path=out, strategy=strategy)
        if args.check:
            bad = [c for c in doc["results"]
                   if c["wall_ratio_vs_smallest"] > 1.25
                   or c["peak_ratio_vs_smallest"] > 1.25]
            if bad or doc["population_traces"] != 1:
                print(f"FAIL: population flatness "
                      f"(bad P {[c['population'] for c in bad]}, "
                      f"traces {doc['population_traces']})")
                return 1
            pmax = doc["results"][-1]
            print(f"OK: flat to P={pmax['population']} "
                  f"(wall {pmax['wall_ratio_vs_smallest']:.2f}x, "
                  f"peak state {pmax['peak_ratio_vs_smallest']:.2f}x, "
                  "1 compile)")
        return 0
    if args.mesh_scan:
        out = ("BENCH_mesh_scan.json" if args.out == "BENCH_round.json"
               else args.out)
        doc = run_mesh_scan(fast=args.fast, out_path=out)
        if args.check:
            bad = [c for c in doc["results"]
                   if c["scan"]["mesh_scan_traces"] != 1
                   or c["loss_max_abs_diff"] != 0.0]
            if bad:
                print(f"FAIL: mesh-scan check "
                      f"{[c['strategy'] for c in bad]}")
                return 1
            print("OK: scanned mesh driver bit-exact with the per-round "
                  "loop, 1 trace per run")
        return 0
    if args.kernels:
        out = ("BENCH_kernels.json" if args.out == "BENCH_round.json"
               else args.out)
        doc = run_kernels(fast=args.fast, out_path=out)
        if args.check:
            interp = [c for c in doc["results"] if c.get("interpret")]
            if interp:
                print(f"WARNING: {len(interp)}/{len(doc['results'])} cells "
                      "ran the kernel route in Pallas interpret mode "
                      f"(backend {interp[0]['backend']}) — their wall-clock "
                      "columns are correctness/overhead datapoints, not a "
                      "hardware comparison; only the roofline bytes and "
                      "bit-exactness are checked")
            # packed codec wires must beat the idx32+f32 reference pair on
            # the per-survivor stream by their byte ratios
            wire_caps = {"qtopk": 5.0 / 8.0, "int4": 9.0 / 16.0}
            bad = [c for c in doc["results"]
                   if c["roofline"]["ratio"] < 3.0 or not c["bit_exact"]
                   or c["wire"]["pair_ratio"]
                   > wire_caps.get(c["strategy"], 1.0) + 1e-12]
            if bad or any(t != 1 for t in
                          doc["scan_traces_with_kernels"].values()):
                print(f"FAIL: kernels check "
                      f"(bad cells {[(c['strategy'], c['clients']) for c in bad]}, "
                      f"scan traces {doc['scan_traces_with_kernels']})")
                return 1
            print("OK: megakernel pipeline bit-exact (codec routes "
                  "included), >=3x HBM traffic reduction, packed wire "
                  "ratios within caps, 1-compile kernel-routed scans")
        return 0
    if args.sim_scan:
        out = ("BENCH_sim_scan.json" if args.out == "BENCH_round.json"
               else args.out)
        doc = run_sim_scan(fast=args.fast, out_path=out)
        if args.check:
            bad = [c for c in doc["results"]
                   if c["dispatch_overhead_ratio"] < 2.0]
            if bad:
                print(f"FAIL: dispatch overhead ratio < 2x in "
                      f"{[c['strategy'] for c in bad]}")
                return 1
            print("OK: scan dispatch overhead >=2x lower than fused")
        return 0
    doc = run(fast=args.fast, rounds=args.rounds, out_path=args.out)
    if args.check:
        cell = next(r for r in doc["results"]
                    if r["strategy"] == "bcrs_opwa" and r["clients"] == 16)
        if cell["speedup"] < 3.0:
            print(f"FAIL: bcrs_opwa K=16 speedup {cell['speedup']:.2f}x < 3x")
            return 1
        print(f"OK: bcrs_opwa K=16 speedup {cell['speedup']:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
