"""Paper Table 3 + Fig. 10: communication time to reach a target accuracy,
with Actual / Max (straggler) / Min accounting under the simulated links.

Expected reproduction of the paper's claims: BCRS reaches the target in a
fraction of TopK's accumulated actual time (paper: 2.02-3.37x speedup), and
the Max-vs-Min gap shows the straggler problem BCRS removes.
"""
from __future__ import annotations

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl


def run(target: float = 0.55, rounds: int = 40, seed: int = 0,
        verbose: bool = True):
    rows = []
    cases = [
        ("fedavg", dict(strategy="fedavg")),
        ("topk_cr0.1", dict(strategy="topk", cr=0.1)),
        ("topk_cr0.01", dict(strategy="topk", cr=0.01)),
        ("eftopk_cr0.1", dict(strategy="eftopk", cr=0.1)),
        ("bcrs_cr0.1", dict(strategy="bcrs", cr=0.1)),
        ("bcrs_cr0.01", dict(strategy="bcrs", cr=0.01)),
        ("bcrs_opwa_cr0.01", dict(strategy="bcrs_opwa", cr=0.01, gamma=5.0)),
    ]
    for name, kw in cases:
        sim = FLSimConfig(rounds=rounds, beta=0.1, seed=seed, eval_every=2)
        res = run_fl(sim, AggregationConfig(alpha=1.0, **kw))
        tta = res.time_to_accuracy(target)
        rows.append({
            "name": name,
            "time_to_target": tta,
            "actual": res.times.actual,
            "max": res.times.max,
            "min": res.times.min,
            "final_acc": res.final_accuracy,
        })
        if verbose:
            r = rows[-1]
            tta_s = f"{tta:.1f}s" if tta is not None else "not reached"
            print(f"table3 {name:18s} time_to_{target:.0%}={tta_s:>12s} "
                  f"actual={r['actual']:.1f}s max={r['max']:.1f}s "
                  f"min={r['min']:.1f}s acc={r['final_acc']:.3f}")
    return rows


def main():
    rows = run()
    print("\nname,us_per_call,derived")
    for r in rows:
        t = r["time_to_target"]
        print(f"table3/{r['name']},{(t or 0) * 1e6:.0f},"
              f"acc={r['final_acc']:.4f};actual={r['actual']:.1f}")
    return rows


if __name__ == "__main__":
    main()
