"""Serving example: batched generation with per-family KV/state caches —
one full-attention arch, the SSM (O(1)-state) arch, and the hybrid.

    PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys

for arch in ["stablelm-1.6b", "rwkv6-1.6b", "hymba-1.5b"]:
    print(f"\n=== {arch} ===")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
         "--reduced", "--batch", "2", "--prompt-len", "8", "--gen", "16"],
        check=True)
