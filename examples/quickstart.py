"""Quickstart: the paper's technique in 60 lines.

1. compress per-client updates with bandwidth-scheduled Top-K (BCRS)
2. aggregate with the overlap-aware parameter mask (OPWA)
3. compare against plain FedAvg on the same updates

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientLink, make_schedule, opwa_aggregate,
                        overlap_counts, topk_compress_dynamic)

N_CLIENTS, N_PARAMS = 5, 20_000
rng = np.random.default_rng(0)

# --- per-client model updates (stand-in for local SGD deltas)
updates = jnp.asarray(rng.normal(0, 1, (N_CLIENTS, N_PARAMS)), jnp.float32)
data_fracs = np.array([0.4, 0.3, 0.15, 0.1, 0.05])

# --- heterogeneous uplinks: 0.5 .. 2.5 Mbit/s
links = [ClientLink(bandwidth_bps=(0.5 + i * 0.5) * 1e6, latency_s=0.1)
         for i in range(N_CLIENTS)]

# --- BCRS: schedule per-client compression ratios + averaging coefficients
sched = make_schedule(links, data_fracs, v_bytes=4.0 * N_PARAMS,
                      cr_star=0.01, alpha=1.0)
print("scheduled CRs:       ", np.round(sched.crs, 4))
print("client coefficients: ", np.round(sched.coefficients, 4))
print(f"equalized round time: {sched.t_bench:.2f}s "
      "(every client finishes together — no stragglers)")

# --- compress with per-client ratios (traced-k bisection Top-K)
ks = jnp.asarray(np.maximum((sched.crs * N_PARAMS).astype(int), 1))
comp = jax.vmap(topk_compress_dynamic)(updates, ks)

counts = overlap_counts(comp.mask)
print(f"\nretained-parameter overlap: "
      f"{[int((counts == c).sum()) for c in range(N_CLIENTS + 1)]} "
      f"(count of params retained by 0..{N_CLIENTS} clients)")

# --- OPWA aggregation vs plain weighted average of the sparse updates.
# Paper Fig. 3: a parameter retained by only ONE client gets scaled by that
# client's coefficient (~1/K) under uniform averaging — its update signal is
# diminished. OPWA's gamma mask restores the magnitude the contributing
# client intended.
coeffs = jnp.asarray(sched.coefficients, jnp.float32)
agg_opwa = opwa_aggregate(comp.values, comp.mask, coeffs, gamma=5.0, d=1)
agg_plain = jnp.einsum("k,kn->n", coeffs, comp.values)

singleton = counts == 1
intended = jnp.sum(comp.values, axis=0)          # the one contributor's value
ratio_plain = float(jnp.linalg.norm(agg_plain[singleton])
                    / jnp.linalg.norm(intended[singleton]))
ratio_opwa = float(jnp.linalg.norm(agg_opwa[singleton])
                   / jnp.linalg.norm(intended[singleton]))
print(f"\nsignal retained on overlap-1 params (1.0 = what the contributing "
      f"client sent):\n  uniform averaging: {ratio_plain:.2f}   "
      f"OPWA (gamma=5): {ratio_opwa:.2f}")
