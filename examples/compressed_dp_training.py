"""Beyond-paper example: hierarchical BCRS/OPWA gradient compression for
multi-pod data-parallel training (DESIGN.md §2) — trains a reduced LM with
dense vs compressed pod sync and compares losses + exchanged bytes.

    PYTHONPATH=src python examples/compressed_dp_training.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.bcrs import pod_link_schedule
from repro.core.compression import k_for_ratio
from repro.data import synthetic_lm_tokens
from repro.dist.grad_sync import (init_compressed_state,
                                  make_compressed_train_step, make_train_step)
from repro.models import Model
from repro.optim import make_optimizer

ARCH = "stablelm-1.6b"
N_PODS, STEPS, BATCH, SEQ = 4, 20, 8, 128

cfg = get_config(ARCH).reduced()
model = Model(cfg)
rng = np.random.default_rng(0)
opt = make_optimizer("sgd", 5e-2)


def data(step):
    toks = synthetic_lm_tokens(BATCH, SEQ + 1, cfg.vocab_size,
                               np.random.default_rng(1000 + step))
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


params0 = model.init(jax.random.PRNGKey(0))
n_flat = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params0))

# --- dense baseline
dense_step = jax.jit(make_train_step(model, opt))
p, s = params0, opt.init(params0)
for i in range(STEPS):
    p, s, m = dense_step(p, s, data(i))
loss_dense = float(m["loss"])

# --- compressed pod sync: pods with heterogeneous DCN links, BCRS CRs
wire_cr = 0.05
crs = pod_link_schedule([200.0, 100.0, 50.0, 25.0], v_bytes=4.0 * n_flat,
                        cr_star=0.01, cr_max=wire_cr)
print(f"BCRS pod CRs (200/100/50/25 GB/s links): {np.round(crs, 4)}")
comp_step = jax.jit(make_compressed_train_step(
    model, opt, n_pods=N_PODS, wire_cr=wire_cr, gamma=2.0,
    min_leaf_size=4096))
pod_crs = jnp.asarray(crs, jnp.float32)
pod_coeffs = jnp.full((N_PODS,), 1.0 / N_PODS, jnp.float32)
p, s = params0, init_compressed_state(opt, params0, n_pods=N_PODS)
for i in range(STEPS):
    p, s, m = comp_step(p, s, data(i), pod_crs, pod_coeffs)
loss_comp = float(m["loss"])

# --- exchanged bytes per step (inter-pod)
dense_bytes = 4.0 * n_flat * 2 * (N_PODS - 1) / N_PODS          # ring AR
k_total = sum(k_for_ratio(int(np.prod(l.shape)), wire_cr)
              for l in jax.tree.leaves(params0)
              if int(np.prod(l.shape)) >= 4096)
comp_bytes = 8.0 * k_total * (N_PODS - 1) / N_PODS              # idx+val AG

print(f"\nfinal loss: dense={loss_dense:.4f} compressed={loss_comp:.4f}")
print(f"inter-pod bytes/step/device: dense={dense_bytes / 1e6:.2f}MB "
      f"compressed={comp_bytes / 1e6:.2f}MB "
      f"({dense_bytes / comp_bytes:.0f}x reduction)")
assert np.isfinite(loss_comp)
