"""End-to-end FL driver (paper §5 protocol): Dirichlet non-IID partitions,
simulated heterogeneous links, a few hundred aggregate local steps, all five
aggregation strategies compared on accuracy AND accumulated comm time.

    PYTHONPATH=src python examples/fl_noniid_sim.py [--rounds 40]
"""
import argparse

from repro.core.aggregation import AggregationConfig
from repro.fed.simulation import FLSimConfig, run_fl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--cr", type=float, default=0.01)
    args = ap.parse_args()

    print(f"FL sim: 10 clients, beta={args.beta} (severe non-IID), "
          f"CR={args.cr}, {args.rounds} rounds\n")
    results = {}
    for strat in ["fedavg", "topk", "eftopk", "bcrs", "bcrs_opwa"]:
        acfg = AggregationConfig(strategy=strat, cr=args.cr, alpha=1.0,
                                 gamma=5.0)
        sim = FLSimConfig(rounds=args.rounds, beta=args.beta, eval_every=4)
        res = run_fl(sim, acfg)
        results[strat] = res
        print(f"{strat:10s} final_acc={res.final_accuracy:.4f} "
              f"comm_actual={res.times.actual:8.1f}s "
              f"comm_max={res.times.max:8.1f}s")

    base = results["topk"].final_accuracy
    ours = results["bcrs_opwa"].final_accuracy
    print(f"\nBCRS+OPWA vs TopK at CR={args.cr}: "
          f"{ours:.4f} vs {base:.4f} ({ours - base:+.4f})")
    t_topk = results["topk"].times.actual
    t_bcrs = results["bcrs"].times.actual
    print(f"comm time BCRS vs TopK: {t_bcrs:.1f}s vs {t_topk:.1f}s "
          f"(equal by construction; accuracy gain is free)")


if __name__ == "__main__":
    main()
